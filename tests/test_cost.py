"""The static cost model (PR 7): VMEM budgeting, bytes/FLOPs estimates,
and analysis-driven autotune pruning.

Golden values are closed-form where tractable (matmul) and pinned from the
model elsewhere (flash_decode, lm_head_ce) — a change to the cost rules must
consciously update them. Seeded-defect specs check that VMEM_OVERFLOW blocks
the build on every backend and that REDUNDANT_FETCH fires on a walk that
revisits blocks non-consecutively. The pruning tests assert the load-bearing
contract: pruned candidates are NEVER built, and pruning never changes the
winner (under a deterministic timer).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from types import SimpleNamespace

from repro.core import (BACKENDS, Device, Scratch, Spec, Tile, autotune,
                        estimate_cost, prune_candidates, registered_ops,
                        vmem_budget, vmem_footprint)
from repro.core.analyze import (AnalysisError, DEFAULT_VMEM_BUDGET,
                                NEAR_LIMIT_FRAC)
from repro.core.lang import defines_namespace
from repro.kernels.flash_attention.kernel import flash_decode_builder
from repro.kernels.lm_head.kernel import lm_head_builder
from repro.kernels.matmul import matmul, matmul_builder

import repro.kernels  # noqa: F401 — registers the op families


# ---------------------------------------------------------------------------
# golden bytes/FLOPs/footprint at fixed shapes
# ---------------------------------------------------------------------------

def _matmul_defines(n=64, b=32):
    return dict(M=n, K=n, N=n, bm=b, bk=b, bn=b, dtype="float32")


def test_matmul_golden_cost():
    # M=N=K=64, 32^3 blocks, f32. Closed forms:
    #   flops     = 2*M*N*K (dot) + M*N*(K/bk) (accumulate) = 532480
    #   bytes_in  = 4*M*N*K/bn + 4*M*N*K/bm   (a and b refetch per j / per i)
    #   bytes_out = 4*M*N                      (c written once per (i, j))
    #   vmem      = 2*(bm*bk + bk*bn + bm*bn)*4 + bm*bn*4 (f32 scratch)
    D = _matmul_defines()
    rep = estimate_cost(matmul_builder(defines_namespace(D)),
                        defines_namespace(D))
    assert rep.flops == 2 * 64**3 + 64 * 64 * 2 == 532480
    assert rep.bytes_in == 4 * 64**3 // 32 * 2 == 65536
    assert rep.bytes_out == 4 * 64 * 64 == 16384
    assert rep.vmem_bytes == 3 * 2 * 32 * 32 * 4 + 32 * 32 * 4 == 28672
    assert rep.hbm_bytes == rep.bytes_in + rep.bytes_out
    assert rep.intensity == pytest.approx(rep.flops / rep.hbm_bytes)
    assert rep.findings == []


def test_flash_decode_golden_cost():
    D = dict(b=1, h=4, hk=2, skv=512, d=32, dv=32, block_kv=128,
             window=None, sm_scale=float(1 / np.sqrt(32)), dtype="float32")
    rep = estimate_cost(flash_decode_builder(defines_namespace(D)),
                        defines_namespace(D))
    assert rep.vmem_bytes == 68228
    assert rep.bytes_in == 532996
    assert rep.bytes_out == 512
    assert rep.flops == 273616
    assert rep.findings == []


def test_lm_head_ce_golden_cost():
    D = dict(R=256, d=128, V=512, vocab=500, block_r=128, block_v=256,
             block_k=128, emit_logits=False, dtype="float32")
    rep = estimate_cost(lm_head_builder(defines_namespace(D)),
                        defines_namespace(D))
    assert rep.vmem_bytes == 723968
    assert rep.bytes_in == 656384
    assert rep.bytes_out == 2048
    assert rep.flops == 34344448
    assert rep.findings == []


def test_registry_default_configs_cost_clean():
    """Every registered op's default derived config passes the cost model
    with zero findings — the shipped registry fits the default VMEM budget."""
    for name, op in sorted(registered_ops().items()):
        args, params = op.example(np.random.RandomState(0))
        _, _, params = op._resolve(params)
        _, defines, _ = op._prepare(tuple(args), params)
        rep = estimate_cost(op.builder(defines_namespace(defines)),
                            defines_namespace(defines))
        assert rep.findings == [], (name, rep.findings)
        assert rep.vmem_bytes <= NEAR_LIMIT_FRAC * DEFAULT_VMEM_BUDGET, name


# ---------------------------------------------------------------------------
# seeded defects: VMEM_OVERFLOW and REDUNDANT_FETCH
# ---------------------------------------------------------------------------

def _whole_array_builder(D):
    """One grid cell, whole-array tiles: footprint = 2 * n * n * 4 bytes."""
    def body(ctx, x, y):
        y[...] = x[...] * 2.0
    n = D.n
    return Spec(
        "whole", grid=(1,),
        inputs=[Tile("x", (n, n), jnp.float32, block=(n, n),
                     index=lambda i: (0, 0))],
        outputs=[Tile("y", (n, n), jnp.float32, block=(n, n),
                      index=lambda i: (0, 0))],
        body=body)


@pytest.mark.parametrize("backend", BACKENDS)
def test_seeded_vmem_overflow_rejected_on_build(backend):
    # 3000*3000*4 = 36 MB per tile, 72 MB resident > the 16 MB budget:
    # the BUILD must refuse on every backend, not just the pallas one.
    with pytest.raises(AnalysisError, match="VMEM_OVERFLOW"):
        Device(backend).build_kernel(_whole_array_builder, dict(n=3000))


def test_vmem_overflow_is_static():
    total, detail = vmem_footprint(
        _whole_array_builder(SimpleNamespace(n=3000)))
    assert total == 2 * 3000 * 3000 * 4
    assert set(detail) == {"x", "y"}
    rep = estimate_cost(_whole_array_builder(SimpleNamespace(n=3000)),
                        flops=False)
    assert [f.code for f in rep.findings] == ["VMEM_OVERFLOW"]
    assert rep.findings[0].severity == "error"


def test_vmem_budget_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_VMEM_BUDGET", raising=False)
    assert vmem_budget() == DEFAULT_VMEM_BUDGET
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "128M")
    assert vmem_budget() == 128 * 2**20
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "2G")
    assert vmem_budget() == 2 * 2**30
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    assert vmem_budget() == 4096
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "64K")
    assert vmem_budget() == 64 * 2**10
    for bad in ("garbage", "-1", "0", "1.5M"):
        monkeypatch.setenv("REPRO_VMEM_BUDGET", bad)
        with pytest.raises(ValueError):
            vmem_budget()


def test_raised_budget_admits_oversized_spec(monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "128M")
    k = Device("jnp").build_kernel(_whole_array_builder, dict(n=3000))
    out, = k.run(jnp.ones((3000, 3000), jnp.float32))
    assert float(out[0, 0]) == 2.0


def _refetch_builder(D):
    """Reduce sweep kk = 0..3 whose input map revisits block kk % 2: each
    outer cell fetches blocks 0,1,0,1 — 4 runs over 2 distinct blocks — a
    seeded refetch (the map moves off a block it needs again)."""
    def body(ctx, x, y):
        y[...] = x[...][:1]
    return Spec(
        "refetch", grid=(2, 4), reduce_axes=(1,),
        inputs=[Tile("x", (8, 4), jnp.float32, block=(2, 4),
                     index=lambda i, kk: (kk % 2, 0))],
        outputs=[Tile("y", (2, 4), jnp.float32, block=(1, 4),
                      index=lambda i, kk: (i, 0))],
        body=body)


def test_seeded_redundant_fetch_flagged():
    rep = estimate_cost(_refetch_builder(SimpleNamespace()), flops=False)
    codes = [f.code for f in rep.findings]
    assert "REDUNDANT_FETCH" in codes
    # 8 runs of a 2x4 f32 block: the refetches are costed, not just flagged
    assert rep.bytes_in == 8 * 2 * 4 * 4


# ---------------------------------------------------------------------------
# autotune pruning: pruned candidates never build, the winner never changes
# ---------------------------------------------------------------------------

def _model_timer(kernel, args, *, warmup=1, repeats=3):
    """Deterministic stand-in for ``_time_once``: seconds proportional to the
    static model's cost terms. A dominated candidate (>= on both terms, one
    strict) always times strictly worse, so pruning must not change the
    winner — which is exactly the contract under test."""
    rep = estimate_cost(kernel.spec, defines_namespace(kernel.defines))
    out = kernel.run(*args)
    return (rep.hbm_bytes + (rep.flops or 0)) * 1e-12, out


def test_autotune_prunes_dominated_never_builds_them(monkeypatch):
    monkeypatch.setattr("repro.core.tune._time_once", _model_timer)
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(64, 64), jnp.float32)
    b = jnp.asarray(rng.randn(64, 64), jnp.float32)
    defines = _matmul_defines()
    sweep = dict(bm=[32, 64], bn=[32, 64], bk=[32, 64])

    dev = Device("jnp")
    r = autotune(dev, matmul_builder, defines, sweep=sweep, args=(a, b),
                 repeats=1, cache=False)
    # bk=32 costs extra accumulate flops at equal bytes, and bm=bn=32 moves
    # strictly more bytes: 5 of 8 combos are dominated. The three bk=64
    # combos with a 64 block on bm or bn tie exactly (same bytes AND flops),
    # so dominance must NOT prune them — ties race it out on the clock.
    assert r["bk"] == 64 and 64 in (r["bm"], r["bn"])
    assert len(r.pruned) == 5 and len(r.trials) == 3
    assert all("prune[DOMINATED]" in reason for _, reason in r.pruned)
    # pruned candidates were never built: only the kept three were
    assert dev.stats.builds == 3

    dev2 = Device("jnp")
    r2 = autotune(dev2, matmul_builder, defines, sweep=sweep, args=(a, b),
                  repeats=1, cache=False, prune=False)
    assert dev2.stats.builds == 8 and len(r2.trials) == 8
    assert {k: r2[k] for k in sweep} == {k: r[k] for k in sweep}


def test_autotune_all_pruned_is_a_clear_error():
    defines = _matmul_defines()
    with pytest.raises(ValueError, match="statically pruned"):
        autotune(Device("jnp"), matmul_builder, defines,
                 sweep=dict(bm=[32, 64], bn=[32, 64], bk=[32, 64]),
                 args=(jnp.zeros((64, 64)), jnp.zeros((64, 64))),
                 budget=1024)  # nothing fits a 1 KB budget


def test_prune_candidates_vmem_reasons():
    kept, pruned = prune_candidates(
        matmul_builder, _matmul_defines(),
        dict(bm=[32, 64], bn=[32], bk=[32]), budget=25000)
    # bm=64 needs 2*(64*32)*4*... > 25000; bm=32 fits (28672 > 25000? no --
    # recompute: bm=32 footprint is 28672, so BOTH overflow a 25 KB budget)
    assert kept == []
    assert len(pruned) == 2
    assert all("prune[VMEM_OVERFLOW]" in r for _, r in pruned)

    kept, pruned = prune_candidates(
        matmul_builder, _matmul_defines(),
        dict(bm=[32, 64], bn=[32], bk=[32]), budget=DEFAULT_VMEM_BUDGET)
    assert len(kept) == 1 and kept[0]["bm"] == 64
    assert len(pruned) == 1 and "prune[DOMINATED]" in pruned[0][1]


def test_op_tune_prunes_flash_decode_same_winner(monkeypatch):
    monkeypatch.setattr("repro.core.tune._time_once", _model_timer)
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 4, 1, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 512, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 512, 32), jnp.float32)
    op = registered_ops()["flash_decode"]
    r = op.tune((q, k, v), backend="jnp", cache=False, repeats=1)
    r2 = op.tune((q, k, v), backend="jnp", cache=False, repeats=1,
                 prune=False)
    assert len(r.pruned) > 0
    assert len(r.trials) + len(r.pruned) >= len(r2.trials)
    assert r["block_kv"] == r2["block_kv"]


def test_op_tune_prunes_lm_head_ce_same_winner(monkeypatch):
    monkeypatch.setattr("repro.core.tune._time_once", _model_timer)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(256, 128), jnp.float32)
    w = jnp.asarray(rng.randn(128, 512), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 500, (256, 1)), jnp.int32)
    op = registered_ops()["lm_head_ce"]
    r = op.tune((x, w, labels), backend="jnp", cache=False, repeats=1,
                vocab=500)
    r2 = op.tune((x, w, labels), backend="jnp", cache=False, repeats=1,
                 vocab=500, prune=False)
    assert len(r.pruned) > 0
    assert {k: r[k] for k in op.sweep} == {k: r2[k] for k in op.sweep}


# ---------------------------------------------------------------------------
# winner hygiene: eviction and adoption under the budget
# ---------------------------------------------------------------------------

def test_lint_evicts_overflowing_persisted_winner(tmp_path, monkeypatch,
                                                  capsys):
    """A persisted winner whose footprint exceeds the CURRENT budget is
    flagged by ``tune_cli --lint`` and removed by ``--evict``."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(64, 64), jnp.float32)
    b = jnp.asarray(rng.randn(64, 64), jnp.float32)
    r = matmul.tune((a, b), backend="jnp", repeats=1,
                    sweep=dict(bm=[32], bn=[32], bk=[32]))
    assert r["bm"] == 32
    root = tmp_path / "autotune"
    assert len(list(root.glob("*.json"))) == 1

    from repro.tune_cli import main as tune_main
    assert tune_main(["--lint"]) == 0  # fits the default budget: clean

    monkeypatch.setenv("REPRO_VMEM_BUDGET", "16K")  # winner needs 28672 B
    assert tune_main(["--lint"]) == 1
    assert "VMEM_OVERFLOW" in capsys.readouterr().out
    assert tune_main(["--lint", "--evict"]) == 0
    assert list(root.glob("*.json")) == []


def test_adopt_winners_skips_overflowing_winner(tmp_path, monkeypatch):
    from repro.launch.tuning import adopt_winners

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(64, 64), jnp.float32)
    b = jnp.asarray(rng.randn(64, 64), jnp.float32)
    # the op's own sweep + backend key the cache entry cached_winner looks up
    r = matmul.tune((a, b), backend="jnp", repeats=1)
    import jax
    probe = jax.ShapeDtypeStruct
    probes = {"matmul": ((probe((64, 64), jnp.float32),
                          probe((64, 64), jnp.float32)),
                         dict(backend="jnp"))}
    saved = dict(matmul.defaults)
    try:
        applied = adopt_winners(probes)
        assert applied.get("matmul") == {k: r[k] for k in matmul.sweep}
        matmul.defaults.clear()
        matmul.defaults.update(saved)
        # every surviving 64^3 candidate needs > 16 KB resident VMEM: under
        # a 16K budget the persisted winner must NOT be adopted
        monkeypatch.setenv("REPRO_VMEM_BUDGET", "16K")
        applied = adopt_winners(probes)
        assert "matmul" not in applied
    finally:
        matmul.defaults.clear()
        matmul.defaults.update(saved)


# ---------------------------------------------------------------------------
# roofline report guards (satellite): corrupt artifacts, missing dirs
# ---------------------------------------------------------------------------

def test_roofline_skips_corrupt_artifacts(tmp_path, capsys):
    import json
    import sys
    sys.path.insert(0, ".")
    from benchmarks import roofline

    (tmp_path / "bad.json").write_text("{not json")
    (tmp_path / "list.json").write_text("[1, 2]")
    (tmp_path / "ok.json").write_text(json.dumps(dict(
        arch="llama3_2_1b", shape="decode_32k", mesh="1x1", kind="decode",
        chips=0, extrapolated=dict(flops=0.0, bytes_accessed=0.0,
                                   collective_total_bytes=0.0))))
    recs = roofline.load(str(tmp_path))
    assert len(recs) == 1
    out = capsys.readouterr().out
    assert "skipping" in out
    # zero chips + zero-byte terms: analyzed without a divide-by-zero crash
    a = roofline.analyze(recs[0])
    assert a["useful_ratio"] == 0.0 and a["roofline_fraction"] == 0.0
    md = roofline.markdown_table(recs)
    assert "llama3_2_1b" in md


def test_roofline_missing_dir_clear_exit(tmp_path, capsys):
    import sys
    sys.path.insert(0, ".")
    from benchmarks import roofline

    assert roofline.main(["--dir", str(tmp_path / "nope")]) == 1
    assert "no dry-run artifacts" in capsys.readouterr().out


def test_roofline_markdown_bare_filename(tmp_path, monkeypatch):
    import json
    import sys
    sys.path.insert(0, ".")
    from benchmarks import roofline

    (tmp_path / "a.json").write_text(json.dumps(dict(
        arch="llama3_2_1b", shape="decode_32k", mesh="1x1", kind="decode",
        chips=1, extrapolated=dict(flops=1e12, bytes_accessed=1e9,
                                   collective_total_bytes=0.0))))
    monkeypatch.chdir(tmp_path)
    # a bare filename has an empty dirname: must not crash on makedirs("")
    assert roofline.main(["--dir", str(tmp_path),
                          "--markdown", "out.md"]) == 0
    assert (tmp_path / "out.md").exists()
