"""The declarative op front-end + masked grid cells, tested as a contract.

Covers the PR-2 surface: ``ctx.cell_when`` backend equivalence (including
fully-skipped blocks), a registry-wide property test sweeping every
``define_op``-registered op across jnp/loops/pallas against its oracle,
flash-attention forward (unified language) + bespoke-backward gradient
checks, the persistent autotune cache (a warm cache performs ZERO sweep
builds/timings), oracle-based autotune validation, and the Memory/Kernel
cross-device guards.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, Device, Op, Scratch, Spec, Tile, autotune,
                        default_device, registered_ops)
from repro.kernels.flash_attention import flash_attention, mha_ref
from repro.kernels.matmul import matmul

# importing repro.kernels registers every op
import repro.kernels  # noqa: F401


def run_all_backends(builder, defines, arrays):
    outs = {}
    for be in BACKENDS:
        k = Device(be).build_kernel(builder, defines)
        outs[be] = [np.asarray(o) for o in k.run(*[jnp.asarray(a) for a in arrays])]
    return outs


# ---------------------------------------------------------------------------
# ctx.cell_when: masked/predicated grid cells
# ---------------------------------------------------------------------------

def causal_tile_builder(D):
    """Attention-style tile masking: out[qi] accumulates block sums of x only
    for ki < qi — every (qi, ki >= qi) cell is WHOLE-BLOCK skipped, and the
    qi=0 row is fully skipped (its output comes from the is_last flush of a
    never-accumulated scratch)."""

    def body(ctx, x, out):
        acc, = ctx.scratch
        qi = ctx.outer_id(0)
        ki = ctx.reduce_id(0)

        @ctx.when(ctx.is_first)
        def _init():
            acc[...] = jnp.zeros(acc.shape, jnp.float32)

        @ctx.cell_when(ki < qi)
        def _step():
            acc[...] += jnp.sum(x[...], keepdims=True)

        @ctx.when(ctx.is_last)
        def _fin():
            out[...] = acc[...]

    nq, bn = D.nq, D.bn
    return Spec(
        "causal_tiles", grid=(nq, nq), reduce_axes=(1,),
        scratch=[Scratch((1,), jnp.float32)],
        inputs=[Tile("x", (nq * bn,), jnp.float32, block=(bn,),
                     index=lambda qi, ki: (ki,))],
        outputs=[Tile("out", (nq,), jnp.float32, block=(1,),
                      index=lambda qi, ki: (qi,))],
        body=body)


def test_cell_when_backend_equivalence_with_fully_skipped_blocks():
    nq, bn = 5, 8
    x = np.random.RandomState(0).randn(nq * bn).astype(np.float32)
    bsums = x.reshape(nq, bn).sum(1)
    want = np.array([bsums[:qi].sum() for qi in range(nq)], np.float32)
    outs = run_all_backends(causal_tile_builder, dict(nq=nq, bn=bn), [x])
    for be, got in outs.items():
        np.testing.assert_allclose(got[0], want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"backend {be} diverged")


def test_cell_when_static_predicate():
    def builder(D):
        def body(ctx, x, o):
            o[...] = jnp.zeros(o.shape, jnp.float32)

            @ctx.cell_when(bool(D.on))
            def _maybe():
                o[...] = x[...]

        return Spec("static_cw", grid=(2,),
                    inputs=[Tile("x", (8,), jnp.float32, block=(4,))],
                    outputs=[Tile("o", (8,), jnp.float32, block=(4,))],
                    body=body)

    x = np.arange(8, dtype=np.float32)
    for on, want in [(1, x), (0, np.zeros(8, np.float32))]:
        outs = run_all_backends(builder, dict(on=on), [x])
        for be, got in outs.items():
            np.testing.assert_allclose(got[0], want, err_msg=f"on={on} {be}")


# ---------------------------------------------------------------------------
# registry-wide portability: every define_op op, all backends, vs oracle
# ---------------------------------------------------------------------------

def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-4, atol=3e-4)


def test_registry_has_the_four_op_families():
    names = set(registered_ops())
    assert {"matmul", "rmsnorm", "ssm_scan", "flash_attention"} <= names


@pytest.mark.parametrize("name", sorted(registered_ops()))
@pytest.mark.parametrize("backend", BACKENDS)
def test_every_registered_op_matches_its_ref_on(name, backend):
    op = registered_ops()[name]
    assert isinstance(op, Op)
    assert op.example is not None, f"op {name} must declare example inputs"
    args, params = op.example(np.random.RandomState(0))
    args = tuple(jnp.asarray(a) for a in args)
    got = op(*args, backend=backend, **params)
    ref = op.reference(*args, **params)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        **_tol(got.dtype), err_msg=f"op {name} diverged from ref on {backend}")


# ---------------------------------------------------------------------------
# flash attention: unified fwd on all backends + bespoke bwd gradients
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=True, window=16),
    dict(causal=True, prefix_len=24),
], ids=["causal", "window", "prefix"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_flash_fwd_unified_all_backends(kw, backend):
    b, h, hk, s, d = 1, 4, 2, 64, 32
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hk, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hk, s, d), jnp.float32)
    got = flash_attention(q, k, v, block_q=16, block_kv=16, backend=backend, **kw)
    ref = mha_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=True, window=16),
    dict(causal=True, prefix_len=24),
], ids=["causal", "window", "prefix"])
def test_flash_unified_fwd_bespoke_bwd_gradients(kw):
    b, h, s, d = 1, 2, 64, 32
    rng = np.random.RandomState(5)
    q, k, v = (jnp.asarray(rng.randn(b, h, s, d), jnp.float32) for _ in range(3))

    def loss_k(q, k, v):
        return (flash_attention(q, k, v, block_q=16, block_kv=16, **kw) ** 2).sum()

    def loss_r(q, k, v):
        return (mha_ref(q, k, v, **kw) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for name, a, b_ in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{name} mismatch ({kw})")


# ---------------------------------------------------------------------------
# persistent autotune cache: warm cache -> zero sweep builds / timings
# ---------------------------------------------------------------------------

def test_persistent_tune_cache_skips_resweep(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(32, 32), jnp.float32)
    b = jnp.asarray(rng.randn(32, 32), jnp.float32)
    sweep = {"bm": [8, 16], "bn": [16]}

    # prune=False: this test pins the exact unpruned trial count
    r1 = matmul.tune((a, b), sweep=sweep, backend="jnp", repeats=1,
                     prune=False)
    assert not r1.cached and len(r1.trials) == 2
    files = list((tmp_path / "autotune").glob("*.json"))
    assert len(files) == 1
    saved = json.loads(files[0].read_text())
    assert saved["op"] == "matmul" and saved["winner"]["bm"] == r1["bm"]

    # "second process": cold kernel caches would rebuild — the persistent
    # cache must answer before any candidate is built or timed
    dev = default_device("jnp", None)
    builds_before, hits_before = dev.stats.builds, dev.stats.cache_hits
    r2 = matmul.tune((a, b), sweep=sweep, backend="jnp", repeats=1)
    assert r2.cached and r2.trials == [] and r2.skipped == []
    assert dev.stats.builds == builds_before
    assert dev.stats.cache_hits == hits_before
    assert r2["bm"] == r1["bm"] and r2["bn"] == r1["bn"]
    assert r2["M"] == 32  # winner merged over the full base defines

    # a different tuning problem (other shape) must miss the cache
    a2 = jnp.asarray(rng.randn(16, 16), jnp.float32)
    r3 = matmul.tune((a2, a2), sweep=sweep, backend="jnp", repeats=1)
    assert not r3.cached

    # so must a NARROWER sweep: candidate sets are part of the identity —
    # a cached winner outside the caller's candidates would be nonsense
    r4 = matmul.tune((a, b), sweep={"bm": [8], "bn": [16]}, backend="jnp",
                     repeats=1)
    assert not r4.cached and r4["bm"] == 8


def test_warm_tune_cache_skips_oracle_too(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    calls = {"n": 0}
    real_ref = matmul.ref

    def counting_ref(*a, **kw):
        calls["n"] += 1
        return real_ref(*a, **kw)

    monkeypatch.setattr(matmul, "ref", counting_ref)
    rng = np.random.RandomState(4)
    a = jnp.asarray(rng.randn(16, 16), jnp.float32)
    sweep = {"bm": [8, 16]}
    matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1)
    assert calls["n"] == 1  # cold: oracle evaluated once for validation
    r = matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1)
    assert r.cached and calls["n"] == 1  # warm: no sweep, no oracle


def test_ssm_scan_degradation_guard():
    from repro.kernels.ssm_scan import ssm_scan_pallas

    L = dm = 997  # prime: chunk and d_block would collapse to 1
    x = jnp.zeros((1, L, dm), jnp.float32)
    dt = jnp.zeros((1, L, dm), jnp.float32)
    A = -jnp.ones((dm, 4), jnp.float32)
    B = jnp.zeros((1, L, 4), jnp.float32)
    C = jnp.zeros((1, L, 4), jnp.float32)
    D = jnp.zeros((dm,), jnp.float32)
    with pytest.raises(ValueError, match="degraded"):
        ssm_scan_pallas(x, dt, A, B, C, D)


def test_duplicate_op_name_rejected():
    from repro.core import define_op

    with pytest.raises(ValueError, match="already registered"):
        define_op("matmul", builder=lambda D: None, ref=None,
                  derive_defines=lambda a, p: {})
    # register=False stays out of the registry and out of the collision check
    op = define_op("matmul", builder=lambda D: None, ref=None,
                   derive_defines=lambda a, p: {}, register=False)
    assert op is not registered_ops()["matmul"]


def test_op_tune_validates_against_oracle_and_finite_best_seconds():
    rng = np.random.RandomState(1)
    a = jnp.asarray(rng.randn(32, 24), jnp.float32)
    b = jnp.asarray(rng.randn(24, 16), jnp.float32)
    r = matmul.tune((a, b), sweep={"bm": [8, 32], "bk": [8, 24]},
                    backend="jnp", cache=False, repeats=0,  # repeats=0 bugfix
                    prune=False)  # all 4 trials: the repeats=0 path per trial
    assert np.isfinite(r.best_seconds)
    assert len(r.trials) == 4


def _copy_plus_bn_builder(D):
    """Deliberately block-size-dependent (wrong) kernel for validation tests."""

    def body(ctx, x, o):
        o[...] = x[...] + float(D.bn)

    return Spec("buggy", grid=(D.n // D.bn,),
                inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,))],
                outputs=[Tile("o", (D.n,), jnp.float32, block=(D.bn,))],
                body=body)


def test_autotune_oracle_catches_first_candidate_bug():
    dev = Device("jnp")
    x = np.zeros(16, np.float32)
    # single candidate: the old first-candidate cross-check self-certifies
    r = autotune(dev, _copy_plus_bn_builder, dict(n=16), sweep={"bn": [4]},
                 args=(x,), repeats=1)
    assert r["bn"] == 4
    # with the oracle declared, the same sweep is rejected
    with pytest.raises(AssertionError):
        autotune(dev, _copy_plus_bn_builder, dict(n=16), sweep={"bn": [4]},
                 args=(x,), repeats=1, ref=lambda x_: x_)


# ---------------------------------------------------------------------------
# host-API guards: cross-device Memory, no-per-op-host-code acceptance
# ---------------------------------------------------------------------------

def test_memory_swap_rejects_cross_device_handles():
    d1, d2 = Device("jnp"), Device("loops")
    m1 = d1.malloc(np.ones(4, np.float32))
    m2 = d2.malloc(np.zeros(4, np.float32))
    with pytest.raises(ValueError, match="different devices"):
        m1.swap(m2)
    m3 = d1.malloc(np.zeros(4, np.float32))
    m1.swap(m3)  # same device still fine
    assert m1.to_host().sum() == 0


def test_kernel_rejects_cross_device_output_memory():
    def builder(D):
        def body(ctx, x, o):
            o[...] = x[...]

        return Spec("copy", grid=(1,),
                    inputs=[Tile("x", (4,), jnp.float32)],
                    outputs=[Tile("o", (4,), jnp.float32)],
                    body=body)

    d1, d2 = Device("jnp"), Device("jnp")
    k = d1.build_kernel(builder, {})
    x = d1.malloc(np.ones(4, np.float32))
    out_foreign = d2.malloc(np.zeros(4, np.float32))
    with pytest.raises(ValueError, match="output Memory belongs"):
        k(x, out_foreign)
    out = d1.malloc(np.zeros(4, np.float32))
    k(x, out)
    np.testing.assert_allclose(out.to_host(), 1.0)


def test_lowered_text_uses_prejitted_kernel():
    def builder(D):
        def body(ctx, x, o):
            o[...] = 2.0 * x[...]

        return Spec("dbl", grid=(1,),
                    inputs=[Tile("x", (4,), jnp.float32)],
                    outputs=[Tile("o", (4,), jnp.float32)],
                    body=body)

    k = Device("jnp").build_kernel(builder, {})
    txt = k.lowered_text(np.ones(4, np.float32))
    assert "module" in txt


def test_flash_bwd_uses_fitted_blocks():
    """Forward fits block sizes to the sequence; the backward must reuse the
    fitted sizes (regression: grad crashed on non-dividing shapes)."""
    b, h, s, d = 1, 2, 80, 32  # 80 % 64 != 0 -> fit_block degrades to 40
    rng = np.random.RandomState(11)
    q, k, v = (jnp.asarray(rng.randn(b, h, s, d), jnp.float32) for _ in range(3))
    gk = jax.grad(lambda q_: (flash_attention(
        q_, k, v, block_q=64, block_kv=64) ** 2).sum())(q)
    gr = jax.grad(lambda q_: (mha_ref(q_, k, v) ** 2).sum())(q)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=2e-3, atol=2e-3)


def test_array_params_rejected_on_differentiable_path_but_jit_safe_on_raw():
    """h0 cannot thread through custom_vjp statics (regression: silently
    dropped from the backward / tracer-freeze under jit); the functional
    path accepts it, including under jit."""
    from repro.kernels.ssm_scan import (selective_scan_ref, ssm_scan,
                                        ssm_scan_pallas)

    rng = np.random.RandomState(2)
    bt, L, dm, n = 1, 32, 8, 4
    x = jnp.asarray(rng.randn(bt, L, dm), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(bt, L, dm)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(dm, n)) + 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(bt, L, n), jnp.float32)
    C = jnp.asarray(rng.randn(bt, L, n), jnp.float32)
    D = jnp.asarray(rng.randn(dm), jnp.float32)
    h = jnp.asarray(rng.randn(bt, dm, n), jnp.float32)

    with pytest.raises(ValueError, match="not differentiable"):
        ssm_scan(x, dt, A, B, C, D, h0=h)

    y, hT = jax.jit(lambda h0: ssm_scan_pallas(
        x, dt, A, B, C, D, h0=h0, chunk=16))(h)
    ref_y, ref_h = selective_scan_ref(x, dt, A, B, C, D, h0=h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(ref_h),
                               rtol=2e-4, atol=2e-4)


def test_tune_cache_key_separates_interpret_modes():
    from repro.core import tune_cache_key

    d1, _ = tune_cache_key("op", dict(M=8), {"bm": [4]}, "pallas", False)
    d2, _ = tune_cache_key("op", dict(M=8), {"bm": [4]}, "pallas", True)
    assert d1 != d2  # debug sweeps must never answer for the compiled path


def test_unknown_params_rejected():
    with pytest.raises(TypeError, match="unexpected params"):
        matmul(jnp.ones((4, 4)), jnp.ones((4, 4)), blck_m=2)  # typo'd kwarg


def test_ops_are_declarations_not_wrappers():
    """matmul/rmsnorm/ssm_scan/flash_attention ARE Op instances — no per-op
    backend-dispatch or caching code survives in kernels/*/ops.py."""
    for name, op in registered_ops().items():
        assert isinstance(op, Op), name
        assert callable(op.builder) and callable(op.derive_defines), name


# ---------------------------------------------------------------------------
# purity: the tightened CI guard, mirrored as a test (word-boundary
# pallas_call under kernels/; jax.experimental.pallas only under core/)
# ---------------------------------------------------------------------------

def test_kernel_purity_and_pallas_import_containment():
    import pathlib
    import re

    root = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    bespoke, leaked = [], []
    for p in sorted(root.rglob("*.py")):
        rel = p.relative_to(root)
        text = p.read_text()
        # word boundary: catches `pl.pallas_call`, bare `pallas_call` and
        # `from jax.experimental.pallas import pallas_call as pc` aliasing
        if rel.parts[0] == "kernels" and re.search(r"\bpallas_call\b", text):
            bespoke.append(str(rel))
        if rel.parts[0] != "core" and "jax.experimental.pallas" in text:
            leaked.append(str(rel))
    assert bespoke == [], f"bespoke pallas_call sites: {bespoke}"
    assert leaked == [], \
        f"jax.experimental.pallas outside src/repro/core/: {leaked}"


# ---------------------------------------------------------------------------
# REPRO_BACKEND: the CI backend matrix's env pin for backend="auto"
# ---------------------------------------------------------------------------

def test_repro_backend_env_pins_auto(monkeypatch):
    rng = np.random.RandomState(9)
    a = jnp.asarray(rng.randn(14, 14), jnp.float32)  # unique shape: fresh build
    monkeypatch.setenv("REPRO_BACKEND", "loops")
    dev = default_device("loops", None)
    builds_before = dev.stats.builds
    got = matmul(a, a, block_m=7, block_n=7, block_k=14)  # backend="auto"
    assert dev.stats.builds == builds_before + 1  # built on the LOOPS device
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(matmul.reference(a, a)),
                               rtol=1e-4, atol=1e-4)
    # explicit backends are never overridden by the env pin
    got_j = matmul(a, a, block_m=7, block_n=7, block_k=14, backend="jnp")
    np.testing.assert_allclose(np.asarray(got_j), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


def test_repro_backend_env_rejects_unknown(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "cuda")
    with pytest.raises(ValueError, match="REPRO_BACKEND"):
        matmul(jnp.ones((4, 4)), jnp.ones((4, 4)))


# ---------------------------------------------------------------------------
# stream-output validation (the ssm_scan-enabling language extension)
# ---------------------------------------------------------------------------

def test_stream_output_duplicate_block_rejected():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("bad_stream", grid=(2, 2), reduce_axes=(1,),
                    inputs=[Tile("x", (4, 4), jnp.float32, block=(2, 2),
                                 index=lambda i, r: (i, r))],
                    outputs=[Tile("y", (4, 4), jnp.float32, block=(2, 2),
                                  index=lambda i, r: (i, 0), stream=True)],
                    body=body)

    with pytest.raises(ValueError, match="stream output.*more than once"):
        Device("jnp").build_kernel(bad, {})
