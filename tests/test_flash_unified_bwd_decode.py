"""The finished flash-attention port: unified backward + decode, plus the
language extension that enabled it.

Covers the PR-3 surface: ``Tile(reduce=...)`` per-output reduce granularity
(one kernel whose outputs accumulate over different subsets of the reduce
axes, on all three backends, plus its build-time validation),
``ctx.reduce_first/reduce_last``, flash-attention gradients through the ONE
fused dq/dk/dv unified kernel vs the oracle on jnp/loops/pallas,
``flash_decode`` edge cases (GQA head-group mapping, window smaller than a
kv block, non-dividing cache lengths, dynamic ``kv_len`` under jit), the
kernel-library purity contract (zero bespoke ``pallas_call`` sites), the
versioned autotune cache (stale/corrupt/mismatched entries are EVICTED, not
crashed on or reused), and the serving warmup that adopts persisted tune
winners through the op registry.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, Device, Op, SCHEMA_VERSION, Scratch, Spec,
                        Tile, default_device, registered_ops, tune_cache_key)
from repro.kernels.flash_attention import (decode_attention, decode_ref,
                                           flash_attention, flash_decode,
                                           mha_ref)

import repro.kernels  # noqa: F401 — registers every op


# ---------------------------------------------------------------------------
# Tile(reduce=...): per-output reduce granularity
# ---------------------------------------------------------------------------

def granularity_builder(D):
    """One kernel, three output granularities over grid (no, n0, n1) with
    reduce axes (1, 2): ``tot`` accumulates over both (scratch + is_last
    flush), ``per0`` keeps one block per n0 step and accumulates over n1 only
    (read-modify-write on its revisited block), ``strm`` streams one block
    per cell."""

    def body(ctx, x, tot, per0, strm):
        acc, = ctx.scratch
        s = x[...].sum()

        @ctx.when(ctx.is_first)
        def _init_tot():
            acc[...] = jnp.zeros(acc.shape, jnp.float32)

        @ctx.when(ctx.reduce_first(1))
        def _init_per0():
            per0[...] = jnp.zeros(per0.shape, jnp.float32)

        acc[...] = acc[...] + s
        per0[...] = per0[...] + s
        strm[...] = jnp.full((1, 1, 1), s)

        @ctx.when(ctx.is_last)
        def _fin():
            tot[...] = acc[...]

    no, n0, n1, bn = D.no, D.n0, D.n1, D.bn
    return Spec(
        "granularity", grid=(no, n0, n1), reduce_axes=(1, 2),
        scratch=[Scratch((1,), jnp.float32)],
        inputs=[Tile("x", (no, n0, n1 * bn), jnp.float32, block=(1, 1, bn),
                     index=lambda o, a, b: (o, a, b))],
        outputs=[
            Tile("tot", (no,), jnp.float32, block=(1,),
                 index=lambda o, a, b: (o,)),
            Tile("per0", (no, n0), jnp.float32, block=(1, 1),
                 index=lambda o, a, b: (o, a), reduce=(2,)),
            Tile("strm", (no, n0, n1), jnp.float32, block=(1, 1, 1),
                 index=lambda o, a, b: (o, a, b), stream=True),
        ],
        body=body)


@pytest.mark.parametrize("backend", BACKENDS)
def test_per_output_reduce_granularity_matches_numpy(backend):
    no, n0, n1, bn = 2, 3, 4, 5
    x = np.random.RandomState(0).randn(no, n0, n1 * bn).astype(np.float32)
    k = Device(backend).build_kernel(granularity_builder,
                                     dict(no=no, n0=n0, n1=n1, bn=bn))
    tot, per0, strm = [np.asarray(o) for o in k.run(x)]
    x4 = x.reshape(no, n0, n1, bn)
    np.testing.assert_allclose(tot, x.sum(axis=(1, 2)), rtol=1e-5)
    np.testing.assert_allclose(per0, x4.sum(axis=(2, 3)), rtol=1e-5)
    np.testing.assert_allclose(strm, x4.sum(axis=3), rtol=1e-5)


def _one_out_spec(tile):
    def body(ctx, x, y):
        y[...] = x[...]

    return Spec("g", grid=(2, 2, 2), reduce_axes=(1, 2),
                inputs=[Tile("x", (2, 2, 2), jnp.float32, block=(1, 1, 1),
                             index=lambda o, a, b: (o, a, b))],
                outputs=[tile], body=body)


def test_tile_reduce_must_be_subset_of_reduce_axes():
    with pytest.raises(ValueError, match="not a subset"):
        _one_out_spec(Tile("y", (2, 2), jnp.float32, block=(1, 1),
                           index=lambda o, a, b: (o, a), reduce=(0,)))


def test_tile_reduce_conflicts_with_stream():
    with pytest.raises(ValueError, match="stream=True means reduce=()"):
        _one_out_spec(Tile("y", (2, 2), jnp.float32, block=(1, 1),
                           index=lambda o, a, b: (o, a), reduce=(1,),
                           stream=True))


def test_index_map_must_not_use_accumulated_axes():
    # y accumulates over axis 2 but its index map uses axis 2's id
    with pytest.raises(ValueError, match="depends on reduce"):
        _one_out_spec(Tile("y", (2, 2), jnp.float32, block=(1, 1),
                           index=lambda o, a, b: (o, b), reduce=(2,)))


def test_partial_reduce_blocks_must_cover_output():
    # y has 4 blocks but (outer x slot-axis) cells only visit 2 of them
    with pytest.raises(ValueError, match="blocks visited but"):
        _one_out_spec(Tile("y", (2, 4), jnp.float32, block=(1, 1),
                           index=lambda o, a, b: (o, a), reduce=(2,)))


# ---------------------------------------------------------------------------
# flash backward: ONE fused dq/dk/dv kernel vs the oracle, every backend
# ---------------------------------------------------------------------------

def _grad_pair(kw, backend, *, h=2, hk=2, d=32, dv=32, s=64, dtype=jnp.float32,
               seed=7):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(1, h, s, d), dtype)
    k = jnp.asarray(rng.randn(1, hk, s, d), dtype)
    v = jnp.asarray(rng.randn(1, hk, s, dv), dtype)

    def loss_k(q, k, v):
        return (flash_attention(q, k, v, block_q=16, block_kv=16,
                                backend=backend, **kw) ** 2).sum()

    def loss_r(q, k, v):
        return (mha_ref(q, k, v, **kw) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    return gk, gr


@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=True, window=16),
    dict(causal=True, prefix_len=24),
    dict(causal=False),
], ids=["causal", "window", "prefix", "dense"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_flash_bwd_unified_matches_oracle_all_backends(kw, backend):
    gk, gr = _grad_pair(kw, backend)
    for name, a, b_ in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{name} mismatch ({kw}, {backend})")


@pytest.mark.parametrize("h,hk,d,dv", [
    (4, 2, 32, 32),     # GQA group reduction
    (4, 1, 32, 32),     # MQA
    (2, 2, 64, 32),     # MLA dims (dqk != dv)
])
@pytest.mark.parametrize("backend", BACKENDS)
def test_flash_bwd_unified_gqa_and_mla_dims(h, hk, d, dv, backend):
    gk, gr = _grad_pair(dict(causal=True), backend, h=h, hk=hk, d=d, dv=dv)
    for name, a, b_ in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{name} mismatch ({backend})")


def test_flash_bwd_bf16():
    gk, gr = _grad_pair(dict(causal=True), "jnp", dtype=jnp.bfloat16)
    for name, a, b_ in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=5e-2, atol=5e-2,
                                   err_msg=f"d{name} bf16 mismatch")


# ---------------------------------------------------------------------------
# flash_decode: the registered op, edge cases, every backend
# ---------------------------------------------------------------------------

def test_flash_decode_is_a_registered_op():
    assert isinstance(registered_ops()["flash_decode"], Op)
    assert registered_ops()["flash_decode"] is flash_decode


@pytest.mark.parametrize("backend", BACKENDS)
def test_flash_decode_gqa_head_group_mapping(backend):
    b, h, hk, s, d = 2, 8, 2, 128, 32    # 4 query heads share each kv head
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hk, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hk, s, d), jnp.float32)
    got = decode_attention(q, k, v, block_kv=32, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(decode_ref(q, k, v)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_flash_decode_window_smaller_than_kv_block(backend):
    b, h, s, d = 1, 2, 128, 32
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    got = decode_attention(q, k, v, window=7, block_kv=64, backend=backend)
    ref = decode_ref(q, k, v, window=7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_flash_decode_non_dividing_block_kv(backend):
    b, h, s, d = 1, 2, 96, 32            # 96 % 64 != 0 -> fit_block -> 48
    rng = np.random.RandomState(5)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    got = decode_attention(q, k, v, block_kv=64, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(decode_ref(q, k, v)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_flash_decode_partial_cache_kv_len(backend):
    b, h, s, d = 1, 2, 128, 32
    rng = np.random.RandomState(6)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    for n in (1, 33, 128):               # one token / mid-block / full
        got = decode_attention(q, k, v, kv_len=n, block_kv=32, backend=backend)
        ref = decode_ref(q, k[:, :, :n], v[:, :, :n])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4, err_msg=f"kv_len={n}")


def test_flash_decode_traced_kv_len_one_compiled_kernel():
    """The decode loop's growing length is a TRACED input, not a recompile."""
    b, h, s, d = 1, 2, 64, 16
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)

    @jax.jit
    def step(n):
        return decode_attention(q, k, v, kv_len=n, block_kv=16, backend="jnp")

    for n in (5, 17, 64):
        ref = decode_ref(q, k[:, :, :n], v[:, :, :n])
        np.testing.assert_allclose(np.asarray(step(jnp.int32(n))),
                                   np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_gqa_decode_layer_uses_registered_op():
    """The attention layer's pallas decode path equals its einsum path."""
    from repro.configs import get_config, reduced
    from repro.layers import attention as A
    from repro.layers.common import use_kernel_backend

    cfg = reduced(get_config("llama3_2_1b"))
    params = A.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    outs = {}
    for be in ("jnp", "pallas"):
        with use_kernel_backend(be):
            _, (k, v) = A.gqa_forward(params, x, cfg, return_kv=True)
            cache = A.gqa_prefill_cache(
                A.gqa_cache_init(cfg, b, s + 4, jnp.float32), k, v, cfg)
            ys, xt = [], x[:, -1:]
            for _ in range(3):
                yt, cache = A.gqa_decode(params, xt, cache, cfg)
                ys.append(yt)
            outs[be] = np.asarray(jnp.concatenate(ys, 1))
    np.testing.assert_allclose(outs["pallas"], outs["jnp"],
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# purity: the unified language is the only way to write a kernel
# ---------------------------------------------------------------------------

def test_kernel_library_has_zero_bespoke_pallas_calls():
    import pathlib

    root = pathlib.Path(repro.kernels.__file__).parent
    offenders = [str(p) for p in sorted(root.rglob("*.py"))
                 if "pl.pallas_call" in p.read_text()]
    assert offenders == [], f"bespoke pallas_call sites: {offenders}"


# ---------------------------------------------------------------------------
# autotune cache versioning + eviction
# ---------------------------------------------------------------------------

def _entry_path(tmp_path, op, args, sweep):
    """The cache file a tune of (op, args, sweep) reads/writes."""
    params = dict(op.defaults)
    defines = op.derive_defines(args, params)
    dev = default_device("jnp", None)
    digest, _ = tune_cache_key(op.name, defines, sweep, dev.backend,
                               dev.interpret)
    return tmp_path / "autotune" / f"{digest}.json"


def test_stale_schema_entries_evicted_not_reused(tmp_path, monkeypatch):
    from repro.kernels.matmul import matmul

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(32, 32), jnp.float32)
    sweep = {"bm": [8, 16]}
    r1 = matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1)
    assert not r1.cached
    path = _entry_path(tmp_path, matmul, (a, a), sweep)
    assert path.exists()

    # stamp an old schema version: the entry must be EVICTED (deleted) and
    # the tune re-swept — not crashed on, not silently reused
    entry = json.loads(path.read_text())
    entry["schema"] = SCHEMA_VERSION - 1
    entry["winner"] = {"bm": "bogus"}
    path.write_text(json.dumps(entry))
    assert matmul.cached_winner((a, a), sweep=sweep, backend="jnp") is None
    assert not path.exists()

    r2 = matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1,
                     prune=False)
    assert not r2.cached and len(r2.trials) == 2
    assert json.loads(path.read_text())["schema"] == SCHEMA_VERSION


def test_corrupt_and_mismatched_entries_evicted(tmp_path, monkeypatch):
    from repro.kernels.matmul import matmul

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    rng = np.random.RandomState(1)
    a = jnp.asarray(rng.randn(16, 16), jnp.float32)
    sweep = {"bm": [8, 16]}
    matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1)
    path = _entry_path(tmp_path, matmul, (a, a), sweep)

    # corrupt JSON -> evicted
    path.write_text("{not json")
    assert matmul.cached_winner((a, a), sweep=sweep, backend="jnp") is None
    assert not path.exists()

    # winner missing a swept key -> evicted
    matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1)
    entry = json.loads(path.read_text())
    del entry["winner"]["bm"]
    path.write_text(json.dumps(entry))
    assert matmul.cached_winner((a, a), sweep=sweep, backend="jnp") is None
    assert not path.exists()

    # payload disagreeing with its digest (hand-edited file) -> evicted
    matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1)
    entry = json.loads(path.read_text())
    entry["defines"]["M"] = "999"
    path.write_text(json.dumps(entry))
    assert matmul.cached_winner((a, a), sweep=sweep, backend="jnp") is None
    assert not path.exists()


def test_cached_winner_is_a_pure_lookup(tmp_path, monkeypatch):
    from repro.kernels.matmul import matmul

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    rng = np.random.RandomState(2)
    a = jnp.asarray(rng.randn(32, 32), jnp.float32)
    sweep = {"bm": [8, 16]}
    assert matmul.cached_winner((a, a), sweep=sweep, backend="jnp") is None
    r = matmul.tune((a, a), sweep=sweep, backend="jnp", repeats=1)

    dev = default_device("jnp", None)
    builds = dev.stats.builds
    hits = dev.stats.cache_hits
    winner = matmul.cached_winner((a, a), sweep=sweep, backend="jnp")
    assert winner == {"bm": r["bm"]}
    assert dev.stats.builds == builds and dev.stats.cache_hits == hits


def test_serve_warmup_adopts_persisted_winner(tmp_path, monkeypatch):
    from repro.configs import get_config, reduced
    from repro.launch.serve import apply_tuned_winners

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    cfg = reduced(get_config("llama3_2_1b"))
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, plen, max_len = 2, 16, 256
    assert apply_tuned_winners(cfg, b, plen, max_len) == {}  # cold: no winners

    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(b, h, 1, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, hk, max_len, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, hk, max_len, hd), jnp.float32)
    old_default = flash_decode.defaults["block_kv"]
    try:
        r = flash_decode.tune((q, k, v), repeats=1)  # declared sweep, persisted
        adopted = apply_tuned_winners(cfg, b, plen, max_len)
        assert adopted["flash_decode"]["block_kv"] == r["block_kv"]
        assert flash_decode.defaults["block_kv"] == r["block_kv"]

        # the LAYER call path (decode_attention with no explicit block_kv)
        # must build with the adopted winner, not a wrapper-level hardcode
        derived = {}
        orig = flash_decode.derive_defines
        monkeypatch.setattr(
            flash_decode, "derive_defines",
            lambda a, p: derived.setdefault("D", orig(a, p)))
        decode_attention(q, k, v, backend="jnp")
        assert derived["D"]["block_kv"] == r["block_kv"]
    finally:
        flash_decode.defaults["block_kv"] = old_default
