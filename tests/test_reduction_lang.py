"""Reduction semantics in the unified kernel language: sequential reduce axes
+ VMEM scratch must produce identical results on all three backend expansions
(the OCCA portability contract extended to grid-carried accumulation), plus
regression tests for the kernel-cache identity fix and autotune warmup=0."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import BACKENDS, Device, Scratch, Spec, Tile, autotune
from repro.kernels.matmul import matmul, matmul_builder, matmul_ref
from repro.kernels.rmsnorm import rmsnorm_unified
from repro.kernels.rmsnorm.ref import rmsnorm_ref

SETTINGS = dict(max_examples=10, deadline=None)


def run_all_backends(builder, defines, arrays):
    outs = {}
    for be in BACKENDS:
        dev = Device(be)
        k = dev.build_kernel(builder, defines)
        outs[be] = [np.asarray(o) for o in k.run(*[jnp.asarray(a) for a in arrays])]
    return outs


def assert_backends_agree(outs, rtol=1e-4, atol=1e-4):
    ref = outs["jnp"]
    for be, got in outs.items():
        for r, g in zip(ref, got):
            np.testing.assert_allclose(g, r, rtol=rtol, atol=atol,
                                       err_msg=f"backend {be} diverged")


# ---------------------------------------------------------------------------
# blocked matmul: the canonical reduce-axis kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    mi=st.integers(1, 3), ni=st.integers(1, 3), ki=st.integers(1, 4),
    bm=st.sampled_from([8, 16]), bn=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16]),
    seed=st.integers(0, 999),
)
def test_matmul_reduce_backend_equivalence(mi, ni, ki, bm, bn, bk, seed):
    M, N, K = mi * bm, ni * bn, ki * bk
    rng = np.random.RandomState(seed)
    a = rng.randn(M, K).astype(np.float32)
    b = rng.randn(K, N).astype(np.float32)
    outs = run_all_backends(
        matmul_builder,
        dict(M=M, K=K, N=N, bm=bm, bk=bk, bn=bn, dtype="float32",
             out_dtype="float32"),
        [a, b])
    assert_backends_agree(outs)
    np.testing.assert_allclose(outs["jnp"][0], a @ b, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 999), k=st.sampled_from([32, 48, 96]))
def test_matmul_op_wrapper_fits_blocks(seed, k):
    rng = np.random.RandomState(seed)
    a = rng.randn(24, k).astype(np.float32)
    b = rng.randn(k, 40).astype(np.float32)
    ref = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    for be in BACKENDS:
        got = np.asarray(matmul(jnp.asarray(a), jnp.asarray(b),
                                block_m=16, block_n=16, block_k=64, backend=be))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_matmul_noncanonical_output_index():
    """Reduce kernel whose output blocks land in transposed order."""

    def builder(D):
        def body(ctx, a, b, c):
            acc, = ctx.scratch

            @ctx.when(ctx.is_first)
            def _init():
                acc[...] = jnp.zeros(acc.shape, acc.dtype)

            acc[...] += jnp.dot(a[...], b[...], preferred_element_type=jnp.float32)

            @ctx.when(ctx.is_last)
            def _flush():
                c[...] = acc[...].astype(c.dtype)

        M, K, N, bm, bk, bn = D.M, D.K, D.N, D.bm, D.bk, D.bn
        g0, g1 = M // bm, N // bn
        assert g0 == g1, "transposed map needs a square block grid"
        return Spec(
            "matmul_t", grid=(g0, g1, K // bk),
            reduce_axes=(2,),
            scratch=[Scratch((bm, bn), jnp.float32)],
            inputs=[Tile("a", (M, K), jnp.float32, block=(bm, bk),
                         index=lambda i, j, kk: (j, kk)),       # note: j
                    Tile("b", (K, N), jnp.float32, block=(bk, bn),
                         index=lambda i, j, kk: (kk, i))],      # note: i
            outputs=[Tile("c", (M, N), jnp.float32, block=(bm, bn),
                          index=lambda i, j, kk: (j, i))],      # transposed
            body=body)

    rng = np.random.RandomState(3)
    a = rng.randn(32, 48).astype(np.float32)
    b = rng.randn(48, 32).astype(np.float32)
    outs = run_all_backends(
        builder, dict(M=32, K=48, N=32, bm=8, bk=16, bn=8), [a, b])
    assert_backends_agree(outs)
    np.testing.assert_allclose(outs["jnp"][0], a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_accumulates_directly_into_output():
    """No scratch at all: the body accumulates straight into the output ref
    across reduce steps. The ref must keep its contents between visits on
    every backend (loops regression: blocks were re-zeroed per step)."""

    def builder(D):
        def body(ctx, a, b, c):
            @ctx.when(ctx.is_first)
            def _init():
                c[...] = jnp.zeros(c.shape, c.dtype)

            c[...] += jnp.dot(a[...], b[...], preferred_element_type=jnp.float32)

        M, K, N, bm, bk, bn = D.M, D.K, D.N, D.bm, D.bk, D.bn
        return Spec(
            "matmul_noscr", grid=(M // bm, N // bn, K // bk), reduce_axes=(2,),
            inputs=[Tile("a", (M, K), jnp.float32, block=(bm, bk),
                         index=lambda i, j, kk: (i, kk)),
                    Tile("b", (K, N), jnp.float32, block=(bk, bn),
                         index=lambda i, j, kk: (kk, j))],
            outputs=[Tile("c", (M, N), jnp.float32, block=(bm, bn),
                          index=lambda i, j, kk: (i, j))],
            body=body)

    rng = np.random.RandomState(5)
    a = rng.randn(16, 24).astype(np.float32)
    b = rng.randn(24, 16).astype(np.float32)
    outs = run_all_backends(builder, dict(M=16, K=24, N=16, bm=8, bk=8, bn=8),
                            [a, b])
    assert_backends_agree(outs)
    np.testing.assert_allclose(outs["jnp"][0], a @ b, rtol=1e-4, atol=1e-4)


def test_full_reduction_single_output_block():
    """All grid axes reducing: a grid-carried global sum into one block."""

    def builder(D):
        def body(ctx, x, out):
            acc, = ctx.scratch

            @ctx.when(ctx.is_first)
            def _init():
                acc[...] = jnp.zeros(acc.shape, acc.dtype)

            acc[...] += jnp.sum(x[...], keepdims=True)

            @ctx.when(ctx.is_last)
            def _flush():
                out[...] = acc[...]

        return Spec(
            "gsum", grid=(D.n // D.bn,), reduce_axes=(0,),
            scratch=[Scratch((1,), jnp.float32)],
            inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,),
                         index=lambda r: (r,))],
            outputs=[Tile("out", (1,), jnp.float32, block=(1,),
                          index=lambda r: (0,))],
            body=body)

    x = np.random.RandomState(11).randn(96).astype(np.float32)
    outs = run_all_backends(builder, dict(n=96, bn=16), [x])
    assert_backends_agree(outs)
    np.testing.assert_allclose(outs["jnp"][0], [x.sum()], rtol=1e-4, atol=1e-4)


def test_reduce_id_and_dims_exposed():
    recorded = {}

    def builder(D):
        def body(ctx, x, out):
            acc, = ctx.scratch
            recorded["dim"] = ctx.reduce_dim(0)

            @ctx.when(ctx.is_first)
            def _init():
                acc[...] = jnp.zeros(acc.shape, acc.dtype)

            # weight each reduce step by its position: sum_r r * block_sum_r
            acc[...] += ctx.reduce_id(0).astype(jnp.float32) * jnp.sum(
                x[...], keepdims=True)

            @ctx.when(ctx.is_last)
            def _flush():
                out[...] = acc[...]

        return Spec(
            "wsum", grid=(4,), reduce_axes=(0,),
            scratch=[Scratch((1,), jnp.float32)],
            inputs=[Tile("x", (16,), jnp.float32, block=(4,), index=lambda r: (r,))],
            outputs=[Tile("out", (1,), jnp.float32, block=(1,), index=lambda r: (0,))],
            body=body)

    x = np.arange(16, dtype=np.float32)
    outs = run_all_backends(builder, {}, [x])
    assert_backends_agree(outs)
    want = sum(r * x[4 * r: 4 * r + 4].sum() for r in range(4))
    np.testing.assert_allclose(outs["jnp"][0], [want], rtol=1e-5)
    assert recorded["dim"] == 4


# ---------------------------------------------------------------------------
# rmsnorm in the unified language
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    rows=st.sampled_from([4, 60, 256]),
    d=st.sampled_from([64, 512]),
    block_rows=st.sampled_from([1, 7, 64, 256]),
    seed=st.integers(0, 99),
)
def test_rmsnorm_unified_backend_equivalence(rows, d, block_rows, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, d), jnp.float32)
    w = jnp.asarray(rng.randn(d), jnp.float32)
    ref = np.asarray(rmsnorm_ref(x, w))
    for be in BACKENDS:
        got = np.asarray(rmsnorm_unified(x, w, block_rows=block_rows, backend=be))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5,
                                   err_msg=f"backend {be} diverged")


def test_empty_arrays_short_circuit():
    assert matmul(jnp.zeros((0, 8)), jnp.zeros((8, 4))).shape == (0, 4)
    out = matmul(jnp.zeros((4, 0)), jnp.zeros((0, 4)))  # K == 0 contracts
    assert out.shape == (4, 4)
    np.testing.assert_allclose(np.asarray(out), 0.0)
    assert rmsnorm_unified(jnp.zeros((0, 8)), jnp.ones(8)).shape == (0, 8)


# ---------------------------------------------------------------------------
# validation: the relaxed exactly-once rule
# ---------------------------------------------------------------------------

def test_revisit_without_reduce_axis_still_rejected():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("bad", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,),
                                  index=lambda i: (0,))],
                    body=body)

    with pytest.raises(ValueError, match="visited more than once"):
        Device("jnp").build_kernel(bad, {})


def test_output_index_depending_on_reduce_axis_rejected():
    def bad(D):
        def body(ctx, a, c):
            c[...] = a[...]

        return Spec("bad_r", grid=(2, 2), reduce_axes=(1,),
                    inputs=[Tile("a", (8, 8), jnp.float32, block=(4, 4),
                                 index=lambda i, kk: (i, kk))],
                    outputs=[Tile("c", (8, 8), jnp.float32, block=(4, 4),
                                  index=lambda i, kk: (i, kk))],
                    body=body)

    with pytest.raises(ValueError, match="depends on reduce"):
        Device("jnp").build_kernel(bad, {})


def test_non_trailing_reduce_axis_rejected():
    def bad(D):
        def body(ctx, a, c):
            c[...] = a[...]

        return Spec("bad_axis", grid=(2, 2), reduce_axes=(0,),
                    inputs=[Tile("a", (8, 8), jnp.float32, block=(4, 4))],
                    outputs=[Tile("c", (8, 8), jnp.float32, block=(4, 4))],
                    body=body)

    with pytest.raises(ValueError, match="trailing"):
        Device("jnp").build_kernel(bad, {})


# ---------------------------------------------------------------------------
# kernel-cache identity (regression: closures sharing a __qualname__)
# ---------------------------------------------------------------------------

def _make_scale_builder(alpha):
    def builder(D):
        def body(ctx, x, o):
            o[...] = alpha * x[...]

        return Spec("scale", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("o", (16,), jnp.float32, block=(4,))],
                    body=body)

    return builder


def test_cache_distinguishes_factory_closures():
    dev = Device("jnp")
    b2, b3 = _make_scale_builder(2.0), _make_scale_builder(3.0)
    assert b2.__qualname__ == b3.__qualname__  # the old cache key collided
    k2 = dev.build_kernel(b2, {})
    k3 = dev.build_kernel(b3, {})
    assert k2 is not k3, "distinct closures must not share cached kernels"
    assert dev.stats.builds == 2 and dev.stats.cache_hits == 0
    x = np.ones(16, np.float32)
    np.testing.assert_allclose(np.asarray(k2.run(x)[0]), 2.0)
    np.testing.assert_allclose(np.asarray(k3.run(x)[0]), 3.0)
    # same closure object still hits the cache
    assert dev.build_kernel(b2, {}) is k2
    assert dev.stats.cache_hits == 1


def test_cache_hits_for_bound_method_builders():
    class KernelFamily:
        def __init__(self, alpha):
            self.alpha = alpha

        def builder(self, D):
            alpha = self.alpha

            def body(ctx, x, o):
                o[...] = alpha * x[...]

            return Spec("mscale", grid=(4,),
                        inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                        outputs=[Tile("o", (16,), jnp.float32, block=(4,))],
                        body=body)

    dev = Device("jnp")
    fam2, fam3 = KernelFamily(2.0), KernelFamily(3.0)
    k2 = dev.build_kernel(fam2.builder, {})
    # fam2.builder is a fresh bound-method object each access: must still hit
    assert dev.build_kernel(fam2.builder, {}) is k2
    assert dev.stats.cache_hits == 1
    # a different instance is a different kernel
    k3 = dev.build_kernel(fam3.builder, {})
    assert k3 is not k2
    x = np.ones(16, np.float32)
    np.testing.assert_allclose(np.asarray(k2.run(x)[0]), 2.0)
    np.testing.assert_allclose(np.asarray(k3.run(x)[0]), 3.0)


def test_cache_keys_instances_by_identity_not_eq():
    class EqByName:
        """Custom __eq__/__hash__ that ignore the state the builder uses."""

        def __init__(self, name, scale):
            self.name, self.scale = name, scale

        def __eq__(self, other):
            return isinstance(other, EqByName) and self.name == other.name

        def __hash__(self):
            return hash(self.name)

        def builder(self, D):
            scale = self.scale

            def body(ctx, x, o):
                o[...] = scale * x[...]

            return Spec("escale", grid=(4,),
                        inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                        outputs=[Tile("o", (16,), jnp.float32, block=(4,))],
                        body=body)

    dev = Device("jnp")
    f2, f3 = EqByName("same", 2.0), EqByName("same", 3.0)
    assert f2 == f3  # equal per __eq__, but different kernels
    k2 = dev.build_kernel(f2.builder, {})
    k3 = dev.build_kernel(f3.builder, {})
    assert k3 is not k2
    x = np.ones(16, np.float32)
    np.testing.assert_allclose(np.asarray(k2.run(x)[0]), 2.0)
    np.testing.assert_allclose(np.asarray(k3.run(x)[0]), 3.0)


def test_cache_does_not_pin_dead_builders():
    import gc

    dev = Device("jnp")
    dev.build_kernel(_make_scale_builder(4.0), {})
    gc.collect()
    assert len(dev._cache) == 0, "weak cache must drop GC'd builders"


# ---------------------------------------------------------------------------
# autotune: warmup=0 regression + reduce-kernel sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("warmup", [0, 1])
def test_autotune_warmup_paths(warmup):
    dev = Device("jnp")
    rng = np.random.RandomState(0)
    a = rng.randn(32, 32).astype(np.float32)
    b = rng.randn(32, 32).astype(np.float32)
    base = dict(M=32, K=32, N=32, bm=16, bn=16, dtype="float32",
                out_dtype="float32")
    result = autotune(dev, matmul_builder, base,
                      sweep={"bk": [5, 8, 16, 32]},    # 5 is invalid (32 % 5)
                      args=(a, b), warmup=warmup, repeats=1, prune=False)
    assert result["bk"] in (8, 16, 32)
    assert len(result.trials) == 3
    assert len(result.skipped) == 1 and result.skipped[0][0]["bk"] == 5
    k = dev.build_kernel(matmul_builder, dict(base, **{"bk": result["bk"]}))
    np.testing.assert_allclose(np.asarray(k.run(a, b)[0]), a @ b,
                               rtol=1e-4, atol=1e-4)
