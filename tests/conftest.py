"""Test bootstrap: make ``src/`` and the tests dir importable regardless of
how pytest was invoked (``PYTHONPATH=src`` stays the documented tier-1
command, but plain ``python -m pytest`` must work too)."""

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")

for p in (_HERE, _SRC):
    if p not in sys.path:
        sys.path.insert(0, p)
