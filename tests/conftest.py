"""Test bootstrap: make ``src/`` and the tests dir importable regardless of
how pytest was invoked (``PYTHONPATH=src`` stays the documented tier-1
command, but plain ``python -m pytest`` must work too)."""

import os
import sys

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")

for p in (_HERE, _SRC):
    if p not in sys.path:
        sys.path.insert(0, p)


@pytest.fixture
def mesh8():
    """An 8-way ("model",) mesh when the process actually has 8+ devices.

    XLA's host-device count is fixed before ``import jax`` (the CI ``mesh``
    leg exports ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
    in a plain single-device run the in-process mesh tests skip and the
    subprocess-based parity tests cover the shard_map path instead."""
    import jax

    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8 before jax "
                    "imports — the scripts/ci.sh mesh leg does)")
    return jax.make_mesh((8,), ("model",))
