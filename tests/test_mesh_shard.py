"""Shard-aware kernel language: ShardAxis declarations, the analyzer's
cross-shard checks, the cost model's interconnect column, and ring flash
attention — local single-process form vs the real ``shard_map`` ring on 8
simulated host devices (subprocess, since XLA's device count is fixed
before jax imports; the ``mesh8`` fixture covers the in-process path when
the CI mesh leg forces 8 devices)."""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnalysisError, ShardAxis, Spec, Tile, estimate_cost
from repro.core.lang import defines_namespace
from repro.kernels.flash_attention import flash_attention, ring_flash, \
    ring_flash_attention


def _ring_specs(which="fwd", **over):
    """The real ring spec(s) at smoke shapes, via the op's own derivation."""
    from repro.kernels.flash_attention.kernel import (ring_flash_bwd_builder,
                                                      ring_flash_fwd_builder)
    rng = np.random.RandomState(0)
    args, params = ring_flash.example(rng)
    _, _, params = ring_flash._resolve(dict(params, **over))
    _, defines, _ = ring_flash._prepare(tuple(args), params)
    D = defines_namespace(defines)
    builder = ring_flash_fwd_builder if which == "fwd" else ring_flash_bwd_builder
    return builder(D), D


# ---------------------------------------------------------------------------
# local (single-process) ring vs the unified flash kernel
# ---------------------------------------------------------------------------

def _qkv(rng, b=1, h=4, hk=2, s=128, d=32):
    return (rng.randn(b, h, s, d).astype("float32"),
            rng.randn(b, hk, s, d).astype("float32"),
            rng.randn(b, hk, s, d).astype("float32"))


def test_local_ring_matches_flash_gqa_fwd_and_grads():
    q, k, v = _qkv(np.random.RandomState(0))
    kw = dict(causal=True, block_q=32, block_kv=32, backend="jnp")
    ref = flash_attention(q, k, v, **kw)
    got = ring_flash_attention(q, k, v, ring_steps=4, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)

    def loss(fn):
        return lambda q_, k_, v_: (fn(q_, k_, v_) ** 2).sum()

    g_ref = jax.grad(loss(lambda *a: flash_attention(*a, **kw)),
                     argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(loss(lambda *a: ring_flash_attention(
        *a, ring_steps=4, **kw)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


def test_local_ring_non_dividing_block_kv():
    # chunk length 32 with block_kv=40: fit_block degrades inside each step
    q, k, v = _qkv(np.random.RandomState(1), s=160)
    kw = dict(causal=True, block_q=64, block_kv=40, backend="jnp")
    ref = flash_attention(q, k, v, **kw)
    got = ring_flash_attention(q, k, v, ring_steps=5, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_local_ring_window_and_prefix():
    q, k, v = _qkv(np.random.RandomState(2))
    for extra in (dict(window=48), dict(prefix_len=24)):
        kw = dict(causal=True, block_q=32, block_kv=32, backend="jnp", **extra)
        ref = flash_attention(q, k, v, **kw)
        got = ring_flash_attention(q, k, v, ring_steps=4, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4, err_msg=str(extra))


def test_local_ring_rejects_non_dividing_steps():
    q, k, v = _qkv(np.random.RandomState(3))
    with pytest.raises(ValueError, match="does not divide"):
        ring_flash_attention(q, k, v, ring_steps=3)


# ---------------------------------------------------------------------------
# ShardAxis declaration: structural validation at Spec construction
# ---------------------------------------------------------------------------

def test_shard_axis_must_bind_a_reduce_axis():
    spec, _ = _ring_specs("fwd")
    with pytest.raises(ValueError, match="reduce"):
        dataclasses.replace(
            spec, shard=dataclasses.replace(spec.shard, axis=0))


def test_shard_axis_rotate_must_name_inputs():
    spec, _ = _ring_specs("fwd")
    with pytest.raises(ValueError, match="rotate"):
        dataclasses.replace(
            spec, shard=dataclasses.replace(spec.shard, rotate=("nope",)))


def test_shard_axis_rejects_unknown_collective():
    with pytest.raises(ValueError, match="collective"):
        ShardAxis(mesh_axis="model", axis=0, extent=2, collective="allgather")


# ---------------------------------------------------------------------------
# analyzer: cross-shard findings fire on seeded-defect bindings only
# ---------------------------------------------------------------------------

def test_ring_without_rotation_is_collective_undeclared():
    spec, _ = _ring_specs("fwd")
    with pytest.raises(AnalysisError) as ei:
        dataclasses.replace(
            spec, shard=dataclasses.replace(spec.shard, rotate=()))
    assert {f.code for f in ei.value.findings} == {"COLLECTIVE_UNDECLARED"}


def test_accumulating_output_without_collective_is_undeclared():
    spec, _ = _ring_specs("fwd")
    with pytest.raises(AnalysisError) as ei:
        dataclasses.replace(
            spec, shard=dataclasses.replace(spec.shard, collective=None,
                                            rotate=()))
    codes = {f.code for f in ei.value.findings}
    assert codes == {"COLLECTIVE_UNDECLARED"}
    # both accumulating outputs (o, lse) are flagged
    assert {f.subject for f in ei.value.findings} == {"o", "lse"}


def test_slot_output_not_declared_sharded_is_mesh_race():
    spec, _ = _ring_specs("bwd")
    with pytest.raises(AnalysisError) as ei:
        dataclasses.replace(
            spec, shard=dataclasses.replace(spec.shard, sharded_outputs=()))
    codes = {f.code for f in ei.value.findings}
    assert codes == {"RACE_MESH_WRITE"}
    assert {f.subject for f in ei.value.findings} == {"dk", "dv"}


def test_shipped_ring_specs_are_clean():
    for which in ("fwd", "bwd"):
        spec, _ = _ring_specs(which)   # construction runs the analyzer
        assert spec.shard is not None and spec.shard.extent == 4


# ---------------------------------------------------------------------------
# cost model: interconnect bytes per declared collective
# ---------------------------------------------------------------------------

def test_ring_comm_bytes_priced_per_shard():
    spec, D = _ring_specs("fwd")
    rep = estimate_cost(spec, D)
    n = spec.shard.extent
    kv_bytes = sum(int(np.prod(t.shape)) * 4
                   for t in spec.inputs if t.name in ("k", "v"))
    assert rep.comm_bytes == (n - 1) * kv_bytes
    assert set(rep.comm_detail) == {"k", "v"}
    assert "comm" in str(rep)


def test_unbound_spec_has_zero_comm():
    from repro.kernels.flash_attention.kernel import flash_fwd_builder
    rng = np.random.RandomState(0)
    args, params = flash_attention.example(rng)
    _, _, params = flash_attention._resolve(params)
    _, defines, _ = flash_attention._prepare(tuple(args), params)
    D = defines_namespace(defines)
    rep = estimate_cost(flash_fwd_builder(D), D)
    assert rep.comm_bytes == 0 and "comm" not in str(rep)


# ---------------------------------------------------------------------------
# the real shard_map ring: 8 simulated host devices (subprocess)
# ---------------------------------------------------------------------------

_RING_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.flash_attention import flash_attention, ring_flash_attention
import repro.layers.attention as attn
from repro.parallel.context import Rules, use_rules

mesh = jax.make_mesh((8,), ("model",))
rng = np.random.RandomState(0)
q = rng.randn(1, 4, 256, 32).astype("float32")
k = rng.randn(1, 2, 256, 32).astype("float32")
v = rng.randn(1, 2, 256, 32).astype("float32")
sh = NamedSharding(mesh, P(None, None, "model", None))
qd, kd, vd = (jax.device_put(a, sh) for a in (q, k, v))
kw = dict(causal=True, block_q=32, block_kv=32, backend="jnp")

ref = flash_attention(q, k, v, **kw)
got = ring_flash_attention(qd, kd, vd, mesh=mesh, **kw)
fwd = float(jnp.abs(ref - np.asarray(got)).max())
sim = ring_flash_attention(q, k, v, ring_steps=8, **kw)
sim_vs_mesh = float(jnp.abs(np.asarray(sim) - np.asarray(got)).max())

g_ref = jax.grad(lambda *a: (flash_attention(*a, **kw) ** 2).sum(),
                 argnums=(0, 1, 2))(q, k, v)
g_got = jax.grad(lambda *a: (ring_flash_attention(
    *a, mesh=mesh, **kw) ** 2).sum(), argnums=(0, 1, 2))(qd, kd, vd)
grads = [float(jnp.abs(a - np.asarray(b)).max())
         for a, b in zip(g_ref, g_got)]

# layer routing: gqa_forward takes the declared ring under Rules(ring_axis=)
class Cfg:
    d_model = 64; n_heads = 4; n_kv_heads = 2; resolved_head_dim = 16
    pos_embed = "rope"; rope_theta = 1e4; window = None
params = attn.gqa_init(jax.random.PRNGKey(0), Cfg, jnp.float32)
x = jnp.asarray(rng.randn(2, 64, 64), jnp.float32)
y0 = attn.gqa_forward(params, x, Cfg)
with use_rules(Rules(batch_axes=(), mesh=mesh, ring_axis="model")):
    y1 = attn.gqa_forward(params, x, Cfg)
layer = float(jnp.abs(y0 - np.asarray(y1)).max())
print(json.dumps(dict(devices=jax.device_count(), fwd=fwd, grads=grads,
                      sim_vs_mesh=sim_vs_mesh, layer=layer)))
"""


def test_ring_shard_map_matches_single_device_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _RING_SUB],
                         capture_output=True, text=True, timeout=420, env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    rec = json.loads(res.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["fwd"] < 1e-5, rec
    assert rec["sim_vs_mesh"] < 1e-5, rec
    assert all(g < 1e-4 for g in rec["grads"]), rec
    assert rec["layer"] < 1e-4, rec


def test_ring_flash_mesh8_fwd_and_bwd(mesh8):
    """In-process shard_map parity when the CI mesh leg forces 8 devices."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    q, k, v = _qkv(np.random.RandomState(4), s=256)
    sh = NamedSharding(mesh8, P(None, None, "model", None))
    qd, kd, vd = (jax.device_put(a, sh) for a in (q, k, v))
    kw = dict(causal=True, block_q=32, block_kv=32, backend="jnp")
    ref = flash_attention(q, k, v, **kw)
    got = ring_flash_attention(qd, kd, vd, mesh=mesh8, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    g_ref = jax.grad(lambda *a: (flash_attention(*a, **kw) ** 2).sum(),
                     argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(lambda *a: (ring_flash_attention(
        *a, mesh=mesh8, **kw) ** 2).sum(), argnums=(0, 1, 2))(qd, kd, vd)
    for a, b in zip(g_ref, g_got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


def test_ring_flash_attention_rejects_contradictory_steps(mesh8):
    q, k, v = _qkv(np.random.RandomState(5), s=256)
    with pytest.raises(ValueError, match="contradicts"):
        ring_flash_attention(q, k, v, mesh=mesh8, ring_steps=4)


# ---------------------------------------------------------------------------
# satellites: shardings dedupe + greedy serve step
# ---------------------------------------------------------------------------

def test_make_shardings_returns_params_shape():
    from repro.configs import get_config, reduced
    from repro.models import LM
    from repro.parallel.steps import make_shardings
    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _, _, rules, params_shape = make_shardings(model, mesh)
    want = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    assert jax.tree.map(lambda a, b: (a.shape, a.dtype) == (b.shape, b.dtype),
                        params_shape, want)
    assert rules.ring_axis is None          # ring is opt-in
    _, _, ring_rules, _ = make_shardings(model, mesh, ring=True)
    assert ring_rules.ring_axis is None     # 1-way model axis: nothing to ring


def test_serve_step_greedy_routes_through_greedy_step():
    from repro.configs import get_config, reduced
    from repro.models import LM
    from repro.parallel.steps import build_serve_step
    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.RandomState(6).randint(
        0, cfg.vocab_size, (2, 1)))

    step, sh = build_serve_step(model, mesh, batch=2, max_len=8,
                                greedy=False)
    assert sh["greedy"] is False
    cache = model.init_cache(2, 8)
    logits, _ = step(params, cache, tokens)

    # greedy is the DEFAULT now (flipped with the serving engine)
    gstep, gsh = build_serve_step(model, mesh, batch=2, max_len=8)
    assert gsh["greedy"] is True
    nxt, glogits, _ = gstep(params, model.init_cache(2, 8), tokens)
    np.testing.assert_allclose(np.asarray(glogits), np.asarray(logits),
                               rtol=1e-5, atol=1e-5)
    want = np.argmax(np.asarray(logits)[:, :cfg.vocab_size], axis=-1)
    np.testing.assert_array_equal(np.asarray(nxt), want)
