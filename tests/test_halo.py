"""Halo/overlap input tiles: the test matrix for ``Tile(halo=..., wrap=...)``.

The language fetches each input block plus a per-axis fringe (periodic wrap
or edge clamp) on ALL THREE backend expansions — the OCCA "shared memory with
halo" stencil pattern, portable by construction. The matrix covers: wrap vs
clamp correctness against a numpy oracle, halo radius larger than the block,
asymmetric halos, structural misuse (ValueError at Tile/Spec construction),
out-of-bounds halos (analyzer error on every backend), and the cost model's
halo amplification — pinned golden for the fd2d window bytes.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalysisError, BACKENDS, Device, Spec, Tile,
                        estimate_cost)
from repro.apps.fd2d import fd2d_builder


# ---------------------------------------------------------------------------
# oracle + a minimal halo kernel
# ---------------------------------------------------------------------------

def _pad_oracle(u, r, wrap):
    """numpy: the (h + 2r0, w + 2r1) padded field a halo fetch must see."""
    mode = "wrap" if wrap else "edge"
    return np.pad(np.asarray(u), [(ri, ri) for ri in r], mode=mode)


def window_sum_builder(D):
    """out[i, j] = sum of the (2 r0 + 1) x (2 r1 + 1) window around (i, j)."""
    r0, r1, bh, bw = D.r0, D.r1, D.bh, D.bw

    def body(ctx, u, out):
        win = u[...]                        # (bh + 2 r0, bw + 2 r1)
        acc = jnp.zeros((bh, bw), jnp.float32)
        for di in range(2 * r0 + 1):
            for dj in range(2 * r1 + 1):
                acc = acc + win[di:di + bh, dj:dj + bw]
        out[...] = acc

    return Spec(
        "window_sum", grid=(D.h // bh, D.w // bw),
        inputs=[Tile("u", (D.h, D.w), jnp.float32, block=(bh, bw),
                     halo=(r0, r1), wrap=D.wrap)],
        outputs=[Tile("out", (D.h, D.w), jnp.float32, block=(bh, bw))],
        body=body)


def window_sum_ref(u, r0, r1, wrap):
    pad = _pad_oracle(u, (r0, r1), wrap)
    h, w = u.shape
    acc = np.zeros((h, w), np.float32)
    for di in range(2 * r0 + 1):
        for dj in range(2 * r1 + 1):
            acc += pad[di:di + h, dj:dj + w]
    return acc


# ---------------------------------------------------------------------------
# correctness matrix: wrap x clamp x block shapes x radii, all backends
# ---------------------------------------------------------------------------

CASES = [
    # (h, w, bh, bw, r0, r1)
    (12, 16, 4, 8, 1, 1),     # blocks divide, symmetric small halo
    (12, 16, 4, 8, 2, 3),     # asymmetric halo
    (12, 16, 12, 16, 2, 2),   # single block (whole field) + halo
    (8, 8, 2, 4, 3, 1),       # r0 > bh: window wider than the block
    (6, 10, 3, 5, 5, 9),      # r == extent - 1 (max in-bounds radius)
    (9, 14, 3, 7, 1, 2),      # odd extents / non-power-of-two blocks
]


@pytest.mark.parametrize("wrap", [True, False], ids=["wrap", "clamp"])
@pytest.mark.parametrize("case", CASES, ids=lambda c: "x".join(map(str, c)))
def test_halo_matches_oracle_on_every_backend(case, wrap):
    h, w, bh, bw, r0, r1 = case
    u = np.random.default_rng(hash(case) % 2**31).standard_normal(
        (h, w)).astype(np.float32)
    want = window_sum_ref(u, r0, r1, wrap)
    defines = dict(h=h, w=w, bh=bh, bw=bw, r0=r0, r1=r1, wrap=wrap)
    for backend in BACKENDS:
        (got,) = Device(backend).build_kernel(
            window_sum_builder, defines).run(jnp.asarray(u))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-5, err_msg=backend)


@pytest.mark.parametrize("wrap", [True, False], ids=["wrap", "clamp"])
def test_halo_bit_exact_across_backends(wrap):
    u = np.random.default_rng(7).standard_normal((12, 16)).astype(np.float32)
    defines = dict(h=12, w=16, bh=4, bw=8, r0=2, r1=2, wrap=wrap)
    outs = [np.asarray(Device(b).build_kernel(window_sum_builder, defines)
                       .run(jnp.asarray(u))[0]) for b in BACKENDS]
    for b, o in zip(BACKENDS[1:], outs[1:]):
        np.testing.assert_array_equal(outs[0], o, err_msg=b)


# ---------------------------------------------------------------------------
# structural misuse: rejected at Tile/Spec construction (backend-independent)
# ---------------------------------------------------------------------------

def test_halo_rank_mismatch_rejected():
    with pytest.raises(ValueError, match="halo"):
        Tile("u", (8, 8), jnp.float32, block=(4, 4), halo=(1,)).resolved_halo()


def test_negative_halo_rejected():
    with pytest.raises(ValueError, match="halo"):
        Tile("u", (8, 8), jnp.float32, block=(4, 4),
             halo=(-1, 0)).resolved_halo()


def test_halo_without_block_rejected():
    with pytest.raises(ValueError, match="block"):
        Tile("u", (8, 8), jnp.float32, halo=(1, 1)).resolved_halo()


def test_halo_on_output_rejected():
    def body(ctx, u, out):
        out[...] = u[...]

    with pytest.raises(ValueError, match="input-only"):
        Spec("bad", grid=(2,),
             inputs=[Tile("u", (8,), jnp.float32, block=(4,))],
             outputs=[Tile("out", (8,), jnp.float32, block=(4,), halo=(1,))],
             body=body)


# ---------------------------------------------------------------------------
# out-of-bounds halo: the analyzer rejects it on EVERY backend (BOUNDS_HALO)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_oob_halo_rejected_by_analyzer(backend):
    defines = dict(h=8, w=8, bh=4, bw=4, r0=9, r1=0, wrap=True)
    with pytest.raises(AnalysisError, match="BOUNDS_HALO"):
        Device(backend).build_kernel(window_sum_builder, defines)


def test_oob_halo_names_axis_and_extent():
    defines = dict(h=8, w=16, bh=4, bw=4, r0=0, r1=17, wrap=False)
    with pytest.raises(AnalysisError, match="halo radius 17 on axis 1"):
        Device("jnp").build_kernel(window_sum_builder, defines)


# ---------------------------------------------------------------------------
# cost model: halo amplification is charged, and pinned for fd2d
# ---------------------------------------------------------------------------

def _spec(builder, defines):
    from repro.core.lang import defines_namespace
    return builder(defines_namespace(defines))


def test_cost_charges_halo_window_bytes():
    spec = _spec(window_sum_builder,
                 dict(h=8, w=8, bh=4, bw=4, r0=2, r1=2, wrap=True))
    rep = estimate_cost(spec)
    # 4 grid cells, each fetching an (8, 8) float32 window: 4x amplification
    # over the bare (4, 4) blocks.
    assert rep.bytes_in == 4 * 8 * 8 * 4
    # the window is double-buffered in VMEM (multi-cell grid)
    assert rep.vmem_detail["u"] == 2 * 8 * 8 * 4


def test_fd2d_halo_amplification_golden():
    """Pinned golden: fd2d's per-step HBM traffic with halo tiles.

    32x32 field, 8x8 blocks, r=1: 16 cells fetch a 10x10 u1 window (1.5625x
    amplification), a bare 8x8 u2 block, and write an 8x8 u3 block — NOT
    16 whole-field fetches (the pre-halo builder cached the entire field
    per cell: 16 * 4096 B for u1 alone)."""
    defines = dict(w=32, h=32, bh=8, bw=8, r=1, dt=0.1, dx=0.0625,
                   weights=(1.0, -2.0, 1.0), dtype="float32")
    rep = estimate_cost(_spec(fd2d_builder, defines))
    cells = 16
    u1_window = 10 * 10 * 4
    bare = 8 * 8 * 4
    assert rep.bytes_in == cells * (u1_window + bare) == 10496
    assert rep.bytes_out == cells * bare == 4096
    assert rep.flops and rep.flops > 0  # body traces cleanly through the halo


def test_fallback_cost_counts_whole_array_inputs_once():
    """Regression: the no-walk fallback used to charge whole-array inputs
    once PER GRID CELL — a shared (nq, nq) dmat priced as if every cell
    re-fetched it, inflating bytes_in grid-fold and skewing prune choices."""

    def shared_builder(D):
        def body(ctx, x, dmat, out):
            out[...] = x[...] * dmat[0, 0]

        return Spec(
            "shared", grid=(D.n // D.bn,),
            inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,)),
                    Tile("dmat", (4, 4), jnp.float32)],
            outputs=[Tile("out", (D.n,), jnp.float32, block=(D.bn,))],
            body=body)

    spec = _spec(shared_builder, dict(n=64, bn=8))
    walked = estimate_cost(spec, walk=True)
    fallback = estimate_cost(spec, walk=False)
    dmat_bytes = 4 * 4 * 4
    # both paths: x streamed once (64 floats), dmat counted ONCE
    assert walked.bytes_in == 64 * 4 + dmat_bytes
    assert fallback.bytes_in == walked.bytes_in
    assert fallback.bytes_out == walked.bytes_out == 64 * 4


def test_fallback_cost_still_amplifies_halo_blocks():
    spec = _spec(window_sum_builder,
                 dict(h=8, w=8, bh=4, bw=4, r0=2, r1=2, wrap=True))
    fallback = estimate_cost(spec, walk=False)
    assert fallback.bytes_in == 4 * 8 * 8 * 4  # 4 cells x full window
