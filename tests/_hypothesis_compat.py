"""Hypothesis shim: real hypothesis when installed, fixed examples otherwise.

The tier-1 suite must *collect* (and pass) on machines without the
``hypothesis`` package. Import ``given / settings / strategies`` from this
module instead of ``hypothesis``: when the real library is present it is
re-exported untouched; when it is absent, ``@given`` degrades to running the
test body over a small deterministic set of examples drawn from each
strategy's boundary/interior values. Coverage is thinner than real
property-based testing, but collection never hard-fails and every test still
exercises representative inputs.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import zlib

    HAVE_HYPOTHESIS = False
    _MAX_FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, examples):
            seen, uniq = set(), []
            for e in examples:
                key = (type(e).__name__, repr(e))
                if key not in seen:
                    seen.add(key)
                    uniq.append(e)
            self._examples = uniq

        def pick(self, i):
            return self._examples[i % len(self._examples)]

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=(1 << 31) - 1):
            span = max_value - min_value
            return _Strategy([
                min_value, max_value,
                min_value + span // 2,
                min_value + span // 3,
                min_value + (2 * span) // 3,
            ])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            lo, hi = float(min_value), float(max_value)
            return _Strategy([lo, hi, (lo + hi) / 2.0,
                              lo + 0.25 * (hi - lo), lo + 0.75 * (hi - lo)])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(list(elements))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    strategies = _Strategies()

    def settings(*_args, **kwargs):
        max_examples = kwargs.get("max_examples")

        def deco(fn):
            if max_examples is not None:
                fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_compat_max_examples",
                                _MAX_FALLBACK_EXAMPLES), _MAX_FALLBACK_EXAMPLES)
                for i in range(n):
                    drawn = {
                        # de-correlate columns so e.g. two integer strategies
                        # don't always draw the same boundary together
                        name: s.pick(i + zlib.crc32(name.encode()) % 7)
                        for name, s in strats.items()
                    }
                    fn(*args, **drawn, **kwargs)

            # pytest resolves undeclared params as fixtures: present a
            # signature with the drawn params removed (like real hypothesis)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats])
            del wrapper.__wrapped__
            return wrapper

        return deco
