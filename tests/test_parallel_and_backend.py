"""Parallel-substrate and kernel-backend tests.

- model forward parity: kernel_backend="pallas" (interpret) vs "jnp" oracle
  through REAL models (flash attention / rmsnorm / ssm_scan inside the LM);
- cache partition specs (head-dim fallback, seq sharding for batch=1);
- collective-bytes HLO parser;
- a miniature dry-run in a subprocess (8 fake devices, 2x2x2 mesh) proving
  the lower+compile path end-to-end without the 512-device sweep.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.layers.common import use_kernel_backend
from repro.models import LM


@pytest.mark.parametrize("arch", ["llama3_2_1b", "falcon_mamba_7b"])
def test_model_forward_pallas_kernels_match_jnp(arch):
    cfg = reduced(get_config(arch))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.RandomState(1).randint(
        0, cfg.vocab_size, (2, 16)))
    with use_kernel_backend("jnp"):
        ref, _ = model.forward(params, tokens)
    with use_kernel_backend("pallas"):
        got, _ = model.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_model_grads_pallas_kernels_match_jnp():
    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = {"tokens": jnp.asarray(np.random.RandomState(3).randint(
        0, cfg.vocab_size, (2, 16)))}

    def loss(params, backend):
        with use_kernel_backend(backend):
            return model.loss(params, batch)[0]

    g_ref = jax.grad(lambda p: loss(p, "jnp"))(params)
    g_pal = jax.grad(lambda p: loss(p, "pallas"))(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# cache partition specs
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_cache_specs_seq_fallback_when_few_kv_heads():
    from repro.parallel.steps import cache_pspecs
    # granite: kv=8 < model 16 -> cache SEQ dim shards over model (§Perf it2:
    # head_dim sharding forced GSPMD to replicate the cache per decode step)
    cfg = get_config("granite_3_8b")
    model = LM(cfg)
    specs = cache_pspecs(model, FakeMesh({"data": 16, "model": 16}),
                         batch=128, max_len=32768)
    k_spec = specs["stacks"][0]["k"]
    assert k_spec == P(None, ("data",), None, "model", None), k_spec


def test_cache_specs_seq_sharding_for_batch1():
    from repro.parallel.steps import cache_pspecs
    cfg = get_config("mixtral_8x22b")   # window cache, batch 1
    model = LM(cfg)
    specs = cache_pspecs(model, FakeMesh({"data": 16, "model": 16}),
                         batch=1, max_len=524288)
    k_spec = specs["stacks"][0]["k"]
    # batch unshardable -> window seq shards over data AND model
    assert k_spec == P(None, None, None, ("data", "model"), None), k_spec


def test_cache_specs_mla_lora_sharding():
    from repro.parallel.steps import cache_pspecs
    cfg = get_config("deepseek_v2_lite")
    model = LM(cfg)
    specs = cache_pspecs(model, FakeMesh({"data": 16, "model": 16}),
                         batch=128, max_len=32768)
    assert specs["stacks"][-1]["ckv"] == P(None, ("data",), None, "model")


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = textwrap.dedent("""
      %p0 = f32[16,128]{1,0} parameter(0)
      %b0 = bf16[8,256]{1,0} convert(%p0)
      %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}
      %ag = (bf16[8,256]{1,0}, bf16[8,256]{1,0}) all-gather-start(%b0), dimensions={0}
      %agd = bf16[64,256]{1,0} all-gather-done(%ag)
      %cp = bf16[8,256]{1,0} collective-permute(%b0), source_target_pairs={{0,1}}
    """)
    out = collective_bytes(hlo)
    assert out["bytes"]["all-reduce"] == 16 * 128 * 4
    assert out["bytes"]["all-gather"] == 8 * 256 * 2      # start only
    assert out["bytes"]["collective-permute"] == 8 * 256 * 2
    assert out["counts"]["all-reduce"] == 1


# ---------------------------------------------------------------------------
# miniature dry-run (subprocess so XLA sees 8 devices)
# ---------------------------------------------------------------------------

_MINI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models import LM
from repro.optim import AdamW, WarmupCosine
from repro.parallel.steps import build_serve_step, build_train_step

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced(get_config("%ARCH%"))
model = LM(cfg, remat="full")
bs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
if cfg.frontend:
    bs["prefix_embeddings"] = jax.ShapeDtypeStruct(
        (8, cfg.num_prefix_embeddings, cfg.d_model), jnp.dtype(cfg.dtype))
opt = AdamW(schedule=WarmupCosine())
step_fn, sh = build_train_step(model, opt, mesh, zero1=True, batch_shapes=bs)
p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
o = jax.eval_shape(opt.init, p)
ctr = step_fn.lower(p, o, bs).compile()

serve_fn, ssh = build_serve_step(model, mesh, batch=8, max_len=64)
cache = jax.eval_shape(lambda: model.init_cache(8, 64))
csr = serve_fn.lower(p, cache, jax.ShapeDtypeStruct((8, 1), jnp.int32)).compile()
ca = ctr.cost_analysis()
if isinstance(ca, (list, tuple)): ca = ca[0]
print(json.dumps({"train_flops": float(ca.get("flops", 0)), "ok": True}))
"""


@pytest.mark.parametrize("arch", ["llama3_2_1b", "zamba2_7b",
                                  "deepseek_v2_lite"])
def test_mini_multipod_dryrun_subprocess(arch):
    code = _MINI.replace("%ARCH%", arch)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420, env=env)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["train_flops"] > 0


# ---------------------------------------------------------------------------
# pipeline parallelism (GPipe over a "pipe" mesh axis; subprocess, 8 devices)
# ---------------------------------------------------------------------------

_PIPE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.pipeline import pipeline_apply, split_stages

mesh = jax.make_mesh((4,), ("pipe",))
L, d, M, mb = 8, 16, 6, 4
rng = np.random.RandomState(0)
stacked = {"w": jnp.asarray(rng.randn(L, d, d) * 0.2, jnp.float32),
           "b": jnp.asarray(rng.randn(L, d) * 0.1, jnp.float32)}
x = jnp.asarray(rng.randn(M, mb, d), jnp.float32)

def layer(p, x):
    return x + jnp.tanh(x @ p["w"] + p["b"])

def stage_fn(params_i, x):   # params_i: (L/S, ...)
    def body(x, lp):
        return layer(lp, x), None
    x, _ = jax.lax.scan(body, x, params_i)
    return x

# sequential reference
def seq_apply(stacked, x):
    def body(x, lp):
        return layer(lp, x), None
    y, _ = jax.lax.scan(body, x, stacked)
    return y

stages = split_stages(stacked, 4)
stages = jax.device_put(stages, jax.tree.map(
    lambda _: NamedSharding(mesh, P("pipe")), stages))

y_pipe = pipeline_apply(stage_fn, stages, x, mesh=mesh)
y_seq = jax.vmap(lambda xb: seq_apply(stacked, xb))(x)
err_fwd = float(jnp.abs(y_pipe - y_seq).max())

# gradients through the pipeline must match the sequential model
def loss_pipe(stages):
    return (pipeline_apply(stage_fn, stages, x, mesh=mesh) ** 2).sum()

def loss_seq(stacked):
    return (jax.vmap(lambda xb: seq_apply(stacked, xb))(x) ** 2).sum()

g_pipe = jax.grad(loss_pipe)(stages)
g_seq = split_stages(jax.grad(loss_seq)(stacked), 4)
err_g = max(float(jnp.abs(a - jax.device_put(b, a.sharding)).max())
            for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_seq)))
print(json.dumps({"ok": True, "err_fwd": err_fwd, "err_grad": err_g}))
"""


def test_pipeline_parallel_matches_sequential_subprocess():
    out = _run_pipe_sub(_PIPE)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"]
    assert rec["err_fwd"] < 1e-5, rec
    assert rec["err_grad"] < 1e-4, rec


def _run_pipe_sub(code, timeout=420):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_mamba2_pallas_kernel_route_matches_ssd():
    """zamba2 backbone through the fused ssm kernel == the SSD jnp path."""
    cfg = reduced(get_config("zamba2_7b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(4))
    tokens = jnp.asarray(np.random.RandomState(5).randint(
        0, cfg.vocab_size, (2, 16)))
    with use_kernel_backend("jnp"):
        ref, _ = model.forward(params, tokens)
    with use_kernel_backend("pallas"):
        got, _ = model.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
