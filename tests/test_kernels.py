"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention import (decode_attention, decode_ref,
                                           flash_attention, mha_ref)
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.ssm_scan import (selective_scan_assoc, selective_scan_ref,
                                    ssm_scan)

SETTINGS = dict(max_examples=10, deadline=None)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,hk", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(h, hk, causal, dtype):
    b, s, d = 2, 128, 64
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, h, s, d), dtype)
    k = jnp.asarray(rng.randn(b, hk, s, d), dtype)
    v = jnp.asarray(rng.randn(b, hk, s, d), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_kv=32)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    b, h, s, d = 1, 2, 128, 64
    rng = np.random.RandomState(1)
    q, k, v = (jnp.asarray(rng.randn(b, h, s, d), jnp.float32) for _ in range(3))
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_kv=32)
    ref = mha_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    s=st.sampled_from([64, 128, 192]),
    d=st.sampled_from([32, 64, 128]),
    bq=st.sampled_from([32, 64]),
    seed=st.integers(0, 99),
)
def test_flash_attention_shape_sweep(s, d, bq, seed):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(1, 2, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, s, d), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bq)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_flash_attention_gradients_match_ref():
    b, h, s, d = 1, 2, 64, 32
    rng = np.random.RandomState(2)
    q, k, v = (jnp.asarray(rng.randn(b, h, s, d), jnp.float32) for _ in range(3))

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, block_q=32, block_kv=32) ** 2).sum()

    def loss_ref(q, k, v):
        return (mha_ref(q, k, v) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-3, atol=1e-3)


def test_flash_decode_matches_ref():
    b, h, hk, s, d = 2, 8, 2, 256, 64
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hk, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hk, s, d), jnp.float32)
    got = decode_attention(q, k, v, block_kv=64)
    ref = decode_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_sliding_window():
    b, h, s, d = 1, 4, 256, 64
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    got = decode_attention(q, k, v, window=32, block_kv=64)
    ref = decode_ref(q, k, v, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_prefill_then_decode_consistency():
    """decode(q_last, cache) == last row of prefill attention."""
    b, h, s, d = 1, 2, 128, 32
    rng = np.random.RandomState(5)
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    full = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    dec = decode_attention(q[:, :, -1:], k, v, block_kv=32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1:]),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

def _ssm_inputs(bt, L, dm, n, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(bt, L, dm), jnp.float32)
    delta = jnp.asarray(np.log1p(np.exp(rng.randn(bt, L, dm))), jnp.float32) * 0.1
    A = -jnp.asarray(np.abs(rng.randn(dm, n)) + 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(bt, L, n), jnp.float32)
    C = jnp.asarray(rng.randn(bt, L, n), jnp.float32)
    D = jnp.asarray(rng.randn(dm), jnp.float32)
    return x, delta, A, B, C, D


@settings(**SETTINGS)
@given(
    L=st.sampled_from([32, 64, 128]),
    dm=st.sampled_from([16, 64]),
    n=st.sampled_from([4, 16]),
    seed=st.integers(0, 99),
)
def test_ssm_scan_pallas_matches_sequential_ref(L, dm, n, seed):
    args = _ssm_inputs(1, L, dm, n, seed)
    got = ssm_scan(*args)
    ref, _ = selective_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssm_assoc_scan_matches_sequential():
    args = _ssm_inputs(2, 96, 32, 8, 7)
    y1, h1 = selective_scan_ref(*args)
    y2, h2 = selective_scan_assoc(*args)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)


def test_ssm_scan_state_carry_chunked():
    """Chunked kernel must equal one long scan (state carries across chunks)."""
    args = _ssm_inputs(1, 128, 16, 4, 11)
    from repro.kernels.ssm_scan import ssm_scan_pallas
    y, hT = ssm_scan_pallas(*args, chunk=16)
    ref_y, ref_h = selective_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(ref_h), rtol=2e-4, atol=2e-4)


def test_ssm_scan_gradients():
    args = _ssm_inputs(1, 32, 8, 4, 13)

    def loss_k(*a):
        return (ssm_scan(*a) ** 2).sum()

    def loss_r(*a):
        return (selective_scan_ref(*a)[0] ** 2).sum()

    g1 = jax.grad(loss_k, argnums=tuple(range(6)))(*args)
    g2 = jax.grad(loss_r, argnums=tuple(range(6)))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    rows=st.sampled_from([4, 64, 300]),
    d=st.sampled_from([64, 256, 1024]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 99),
)
def test_rmsnorm_matches_ref(rows, d, dtype, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, d), jnp.dtype(dtype))
    w = jnp.asarray(rng.randn(d), jnp.float32)
    got = rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    tol = _tol(jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_rmsnorm_3d_and_grad():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(2, 8, 128), jnp.float32)
    w = jnp.asarray(rng.randn(128), jnp.float32)
    np.testing.assert_allclose(np.asarray(rmsnorm(x, w)),
                               np.asarray(rmsnorm_ref(x, w)), rtol=1e-5, atol=1e-5)
    g1 = jax.grad(lambda x_: rmsnorm(x_, w).sum())(x)
    g2 = jax.grad(lambda x_: rmsnorm_ref(x_, w).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash BACKWARD Pallas kernel (dq/dk/dv from lse stats, no O(S^2) residuals)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,hk,d,dv,causal,window,prefix", [
    (4, 4, 64, 64, True, None, 0),
    (4, 2, 64, 64, True, None, 0),        # GQA group reduction
    (8, 1, 32, 32, True, None, 0),        # MQA
    (2, 2, 64, 64, True, 32, 0),          # sliding window
    (2, 2, 64, 64, False, None, 0),       # non-causal
    (2, 2, 64, 64, True, None, 48),       # prefix-LM
    (4, 4, 192, 128, True, None, 0),      # MLA dims (dqk != dv)
])
def test_flash_bwd_kernel_matches_oracle(h, hk, d, dv, causal, window, prefix):
    b, s = 2, 128
    rng = np.random.RandomState(42)
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hk, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hk, s, dv), jnp.float32)

    def loss_k(q, k, v):
        return (flash_attention(q, k, v, causal=causal, window=window,
                                prefix_len=prefix, block_q=32,
                                block_kv=32) ** 2).sum()

    def loss_r(q, k, v):
        return (mha_ref(q, k, v, causal=causal, window=window,
                        prefix_len=prefix) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for name, a, b_ in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{name} mismatch")


def test_flash_fwd_lse_stats():
    from repro.kernels.flash_attention import flash_attention_fwd
    b, h, s, d = 1, 2, 64, 32
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    _, lse = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_kv=32)
    # reference lse
    s_ = np.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    s_ = np.where(mask, s_, -np.inf)
    ref = np.log(np.exp(s_ - s_.max(-1, keepdims=True)).sum(-1)) + s_.max(-1)
    np.testing.assert_allclose(np.asarray(lse), ref, rtol=1e-4, atol=1e-4)
