"""The kernel static analyzer (repro.core.analyze).

Five deliberately-broken specs — one per finding class — must each be
rejected with its distinct finding code on every backend's build path, and
the entire shipped registry (including the directly-built flash/lm-head
backward kernels) must produce ZERO findings: the analyzer is only useful
if it is precise enough to gate every real build.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BACKENDS, AnalysisError, AnalysisWarning, Device,
                        Scratch, Spec, Tile, analysis_mode, analyze_spec,
                        set_analysis_mode)
from repro.core.lang import defines_namespace


def _build_all_backends(builder, defines, **kw):
    """Build on every backend expansion; returns the per-backend exception."""
    errs = {}
    for be in BACKENDS:
        with pytest.raises(AnalysisError) as ei:
            Device(be).build_kernel(builder, defines, **kw)
        errs[be] = ei.value
    return errs


def _codes(err):
    return {f.code for f in err.findings}


# ---------------------------------------------------------------------------
# the five seeded bad specs, one distinct finding code each
# ---------------------------------------------------------------------------

def test_parallel_axis_race_rejected():
    """Two cells of a parallel (outer) axis map to one output block."""

    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("race", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,),
                                  index=lambda i: (i // 2,))],
                    body=body)

    for err in _build_all_backends(bad, {}).values():
        assert _codes(err) == {"RACE_PARALLEL_WRITE"}
        assert "visited more than once" in str(err)


def test_unwritten_block_rejected():
    """Half the output's blocks are never visited by any grid cell."""

    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("holes", grid=(2,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,),
                                 index=lambda i: (i,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,),
                                  index=lambda i: (i,))],
                    body=body)

    for err in _build_all_backends(bad, {}).values():
        assert _codes(err) == {"COVERAGE_UNWRITTEN"}
        assert "leave garbage" in str(err)


def test_scratch_read_before_init_rejected():
    """Accumulating scratch with no first-visit init: reads undefined VMEM."""

    def bad(D):
        def body(ctx, x, out):
            acc, = ctx.scratch
            acc[...] += jnp.sum(x[...], keepdims=True)  # no is_first init

            @ctx.when(ctx.is_last)
            def _flush():
                out[...] = acc[...]

        return Spec("noinit", grid=(4,), reduce_axes=(0,),
                    scratch=[Scratch((1,), jnp.float32)],
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,),
                                 index=lambda r: (r,))],
                    outputs=[Tile("out", (1,), jnp.float32, block=(1,),
                                  index=lambda r: (0,))],
                    body=body)

    for err in _build_all_backends(bad, {}).values():
        assert _codes(err) == {"LIVENESS_SCRATCH_UNINIT"}


def test_skippable_write_without_init_rejected_strict():
    """An output written ONLY under a grid-dependent cell_when: blocks whose
    guard skips are left undefined on a real TPU (PR 3's dk/dv hazard)."""

    def bad(D):
        def body(ctx, x, y):
            @ctx.cell_when(ctx.outer_id(0) % 2 == 0)
            def _maybe():
                y[...] = x[...] * 2.0

        return Spec("skippy", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,))],
                    body=body)

    for err in _build_all_backends(bad, {}, analyze="strict").values():
        assert _codes(err) == {"COVERAGE_SKIP_NO_INIT"}
    # coverage findings are the warn-by-default class: the default mode
    # surfaces them as AnalysisWarning, not a build failure
    with pytest.warns(AnalysisWarning, match="COVERAGE_SKIP_NO_INIT"):
        Device("jnp").build_kernel(bad, {})


def test_parallel_reduce_axis_with_carried_state_rejected():
    """dimension_semantics marks the reduce axis "parallel" while scratch
    carries the accumulation along it — the pipeline could reorder visits."""

    def bad(D):
        def body(ctx, x, out):
            acc, = ctx.scratch

            @ctx.when(ctx.is_first)
            def _init():
                acc[...] = jnp.zeros(acc.shape, acc.dtype)

            acc[...] += jnp.sum(x[...], keepdims=True)

            @ctx.when(ctx.is_last)
            def _flush():
                out[...] = acc[...]

        return Spec("badsem", grid=(4,), reduce_axes=(0,),
                    dimension_semantics=("parallel",),
                    scratch=[Scratch((1,), jnp.float32)],
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,),
                                 index=lambda r: (r,))],
                    outputs=[Tile("out", (1,), jnp.float32, block=(1,),
                                  index=lambda r: (0,))],
                    body=body)

    for err in _build_all_backends(bad, {}).values():
        assert _codes(err) == {"SEMANTICS_PARALLEL_CARRIED"}


# ---------------------------------------------------------------------------
# index-map bounds: offending cell AND axis in the message (inputs + outputs)
# ---------------------------------------------------------------------------

def test_output_index_out_of_bounds_reports_cell_and_axis():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("oob", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,),
                                  index=lambda i: (i + 1,))],
                    body=body)

    with pytest.raises(AnalysisError) as ei:
        Device("jnp").build_kernel(bad, {})
    assert _codes(ei.value) == {"BOUNDS_INDEX"}
    msg = str(ei.value)
    assert "cell (3,)" in msg and "axis 0" in msg and "block index 4" in msg


def test_input_index_out_of_bounds_reports_cell_and_axis():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("oob_in", grid=(2, 2),
                    inputs=[Tile("x", (8, 8), jnp.float32, block=(4, 4),
                                 index=lambda i, j: (i, j + 2))],
                    outputs=[Tile("y", (8, 8), jnp.float32, block=(4, 4))],
                    body=body)

    with pytest.raises(AnalysisError) as ei:
        Device("jnp").build_kernel(bad, {})
    assert _codes(ei.value) == {"BOUNDS_INDEX"}
    msg = str(ei.value)
    assert "cell (0, 0)" in msg and "axis 1" in msg


def test_scratch_shape_validated():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("scr0", grid=(4,),
                    scratch=[Scratch((0,), jnp.float32)],
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,))],
                    body=body)

    with pytest.raises(AnalysisError) as ei:
        Device("jnp").build_kernel(bad, {})
    assert _codes(ei.value) == {"BOUNDS_SCRATCH"}


# ---------------------------------------------------------------------------
# strictness knob
# ---------------------------------------------------------------------------

def _noinit_builder(D):
    def body(ctx, x, out):
        acc, = ctx.scratch
        acc[...] += jnp.sum(x[...], keepdims=True)

        @ctx.when(ctx.is_last)
        def _flush():
            out[...] = acc[...]

    return Spec("noinit_knob", grid=(4,), reduce_axes=(0,),
                scratch=[Scratch((1,), jnp.float32)],
                inputs=[Tile("x", (16,), jnp.float32, block=(4,),
                             index=lambda r: (r,))],
                outputs=[Tile("out", (1,), jnp.float32, block=(1,),
                              index=lambda r: (0,))],
                body=body)


def test_analyze_off_skips_body_analysis():
    kern = Device("jnp").build_kernel(_noinit_builder, {}, analyze="off")
    assert kern is not None  # zero-filled jnp expansion still runs


def test_analyze_warn_mode_downgrades_errors():
    with pytest.warns(AnalysisWarning, match="LIVENESS_SCRATCH_UNINIT"):
        Device("loops").build_kernel(_noinit_builder, {}, analyze="warn")


def test_set_analysis_mode_round_trips(monkeypatch):
    assert analysis_mode() == "error"  # the default
    prev = set_analysis_mode("strict")
    try:
        assert analysis_mode() == "strict"
    finally:
        set_analysis_mode(prev)
    monkeypatch.setenv("REPRO_ANALYZE", "warn")
    assert analysis_mode() == "warn"
    monkeypatch.setenv("REPRO_ANALYZE", "bogus")
    with pytest.raises(ValueError, match="bogus"):
        analysis_mode()
    with pytest.raises(ValueError, match="analyze mode"):
        set_analysis_mode("bogus")


def test_dimension_semantics_validated():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("sem_len", grid=(4,), dimension_semantics=("parallel",) * 2,
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,))],
                    body=body)

    with pytest.raises(ValueError, match="dimension_semantics"):
        Device("jnp").build_kernel(bad, {})


# ---------------------------------------------------------------------------
# nested when/cell_when: predicates must compose (AND) on every expansion
# ---------------------------------------------------------------------------

def test_nested_when_inside_cell_when_agrees_across_backends():
    """A when nested under a cell_when runs iff BOTH predicates hold — the
    analyzer traces both guards; this pins the run-time composition too."""

    def builder(D):
        def body(ctx, x, y):
            y[...] = x[...]  # guaranteed init: skipped cells keep x

            @ctx.cell_when(ctx.outer_id(0) % 2 == 0)
            def _even_cells():
                @ctx.when(x[0] > 0.0)
                def _positive_lead():
                    y[...] = x[...] * 2.0

        return Spec("nested", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,))],
                    body=body)

    x = np.asarray([1, 2, 3, 4, -1, -2, -3, -4,
                    5, 6, 7, 8, -5, -6, -7, -8], np.float32)
    want = x.copy()
    for i in range(4):
        blk = x[4 * i: 4 * i + 4]
        if i % 2 == 0 and blk[0] > 0:
            want[4 * i: 4 * i + 4] = blk * 2
    outs = {}
    for be in BACKENDS:
        k = Device(be).build_kernel(builder, {})
        outs[be] = np.asarray(k.run(jnp.asarray(x))[0])
        np.testing.assert_array_equal(outs[be], want,
                                      err_msg=f"backend {be} diverged")
    # and exact cross-backend agreement (not just tolerance-close)
    np.testing.assert_array_equal(outs["jnp"], outs["loops"])
    np.testing.assert_array_equal(outs["jnp"], outs["pallas"])


# ---------------------------------------------------------------------------
# zero false positives: the whole shipped registry must analyze clean
# ---------------------------------------------------------------------------

def test_registry_sweeps_clean():
    """Every registered op (and the directly-built flash/lm-head backward
    kernels), across its full autotune candidate sweep: zero findings."""
    import repro.kernels  # noqa: F401 — registers the op families
    from repro.core import registered_ops
    from repro.lint_kernels import lint_op

    ops = registered_ops()
    assert ops, "registry is empty?"
    for name in sorted(ops):
        result = lint_op(ops[name], np.random.RandomState(0))
        assert result["checked"] > 0, f"{name}: nothing analyzed"
        assert result["findings"] == [], (
            f"{name}: analyzer false positives {result['findings']}")


def test_analyze_spec_reports_without_raising():
    """analyze_spec is the non-throwing surface lint/tooling consume."""

    def good(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("idty", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,))],
                    body=body)

    report = analyze_spec(good(defines_namespace({})), defines_namespace({}))
    assert report.ok and report.errors == []
