"""Core kernel-language tests: the paper's portability claim as a test matrix.

Every kernel source must produce identical results on all three backend
expansions (jnp / loops / pallas-interpret) — the OCCA OpenMP/OpenCL/CUDA
equivalence, reproduced as property-based tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import BACKENDS, Device, Spec, Tile

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# kernels under test
# ---------------------------------------------------------------------------

def saxpy_builder(D):
    def body(ctx, x, y, out):
        out[...] = D.alpha * x[...] + y[...]

    return Spec(
        "saxpy", grid=(D.n // D.bn,),
        inputs=[Tile("x", (D.n,), D.dtype, block=(D.bn,)),
                Tile("y", (D.n,), D.dtype, block=(D.bn,))],
        outputs=[Tile("out", (D.n,), D.dtype, block=(D.bn,))],
        body=body)


def stencil_builder(D):
    def body(ctx, u, out):
        bi = ctx.outer_id(0)
        full = ctx.cache(u)                     # occaShared manual cache
        lap = -2.0 * full + jnp.roll(full, 1, 0) + jnp.roll(full, -1, 0)
        ctx.barrier()                           # no-op by construction
        out[...] = jax.lax.dynamic_slice_in_dim(lap, bi * D.bn, D.bn, 0)

    return Spec(
        "stencil", grid=(D.n // D.bn,),
        inputs=[Tile("u", (D.n,), jnp.float32)],
        outputs=[Tile("out", (D.n,), jnp.float32, block=(D.bn,))],
        body=body)


def blockmm_builder(D):
    def body(ctx, a, b, c):
        c[...] = jnp.dot(a[...], b[...], preferred_element_type=jnp.float32)

    M, K, N, bm, bn = D.M, D.K, D.N, D.bm, D.bn
    return Spec(
        "blockmm", grid=(M // bm, N // bn),
        inputs=[Tile("a", (M, K), jnp.float32, block=(bm, K), index=lambda i, j: (i, 0)),
                Tile("b", (K, N), jnp.float32, block=(K, bn), index=lambda i, j: (0, j))],
        outputs=[Tile("c", (M, N), jnp.float32, block=(bm, bn))],
        body=body)


def reduce_builder(D):
    """Per-block sum reduction: non-trivial out index map (grid 1D, out 2D)."""

    def body(ctx, x, out):
        out[...] = jnp.sum(x[...], keepdims=True)

    return Spec(
        "reduce", grid=(D.n // D.bn,),
        inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,))],
        outputs=[Tile("out", (D.n // D.bn,), jnp.float32, block=(1,))],
        body=body)


def lanes_builder(D):
    """Uses lane ids (occaInnerId analogue) + backend flag (occaCPU/GPU)."""

    def body(ctx, x, out):
        lanes = ctx.lane_ids(D.bn)
        bi = ctx.outer_id(0)
        gid = bi * D.bn + lanes                 # occaGlobalId
        val = x[...] + gid.astype(jnp.float32)
        # platform-dependent path must NOT change results, only codegen:
        if ctx.is_pallas:
            out[...] = val
        else:
            out[...] = val * 1.0

    return Spec(
        "lanes", grid=(D.n // D.bn,),
        inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,))],
        outputs=[Tile("out", (D.n,), jnp.float32, block=(D.bn,))],
        body=body)


def run_all_backends(builder, defines, arrays):
    outs = {}
    for be in BACKENDS:
        dev = Device(be)
        k = dev.build_kernel(builder, defines)
        outs[be] = [np.asarray(o) for o in k.run(*[jnp.asarray(a) for a in arrays])]
    return outs


def assert_backends_agree(outs, rtol=1e-5, atol=1e-5):
    ref = outs["jnp"]
    for be, got in outs.items():
        for r, g in zip(ref, got):
            np.testing.assert_allclose(g, r, rtol=rtol, atol=atol,
                                       err_msg=f"backend {be} diverged")


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 6),
    bn=st.sampled_from([4, 8, 16]),
    alpha=st.floats(-4, 4, allow_nan=False, width=32),
    dtype=st.sampled_from(["float32", "int32"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_saxpy_backend_equivalence(nblocks, bn, alpha, dtype, seed):
    n = nblocks * bn
    rng = np.random.RandomState(seed)
    if dtype == "int32":
        x = rng.randint(-100, 100, n).astype(np.int32)
        y = rng.randint(-100, 100, n).astype(np.int32)
        alpha = int(alpha)
    else:
        x = rng.randn(n).astype(np.float32)
        y = rng.randn(n).astype(np.float32)
    outs = run_all_backends(saxpy_builder, dict(n=n, bn=bn, alpha=alpha, dtype=dtype), [x, y])
    assert_backends_agree(outs)
    np.testing.assert_allclose(outs["jnp"][0], (alpha * x + y).astype(dtype), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(nblocks=st.integers(1, 5), bn=st.sampled_from([4, 8]), seed=st.integers(0, 999))
def test_stencil_backend_equivalence(nblocks, bn, seed):
    n = nblocks * bn
    u = np.random.RandomState(seed).randn(n).astype(np.float32)
    outs = run_all_backends(stencil_builder, dict(n=n, bn=bn), [u])
    assert_backends_agree(outs)
    ref = -2 * u + np.roll(u, 1) + np.roll(u, -1)
    np.testing.assert_allclose(outs["jnp"][0], ref, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    mi=st.integers(1, 3), ni=st.integers(1, 3), k=st.sampled_from([8, 24]),
    bm=st.sampled_from([8, 16]), bn=st.sampled_from([8, 16]),
    seed=st.integers(0, 999),
)
def test_blockmm_backend_equivalence(mi, ni, k, bm, bn, seed):
    M, N = mi * bm, ni * bn
    rng = np.random.RandomState(seed)
    a = rng.randn(M, k).astype(np.float32)
    b = rng.randn(k, N).astype(np.float32)
    outs = run_all_backends(blockmm_builder, dict(M=M, K=k, N=N, bm=bm, bn=bn), [a, b])
    assert_backends_agree(outs, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs["jnp"][0], a @ b, rtol=1e-3, atol=1e-3)


def test_reduce_noncanonical_index():
    n, bn = 64, 8
    x = np.random.RandomState(7).randn(n).astype(np.float32)
    outs = run_all_backends(reduce_builder, dict(n=n, bn=bn), [x])
    assert_backends_agree(outs)
    np.testing.assert_allclose(outs["jnp"][0], x.reshape(-1, bn).sum(1), rtol=1e-5, atol=1e-5)


def test_lane_ids_and_platform_flags():
    n, bn = 32, 8
    x = np.random.RandomState(9).randn(n).astype(np.float32)
    outs = run_all_backends(lanes_builder, dict(n=n, bn=bn), [x])
    assert_backends_agree(outs)
    np.testing.assert_allclose(outs["jnp"][0], x + np.arange(n), rtol=1e-5)


# ---------------------------------------------------------------------------
# host API behaviour (paper §2)
# ---------------------------------------------------------------------------

def test_build_cache_and_defines_specialization():
    dev = Device("jnp")
    k1 = dev.build_kernel(saxpy_builder, dict(n=32, bn=8, alpha=2.0, dtype="float32"))
    k2 = dev.build_kernel(saxpy_builder, dict(n=32, bn=8, alpha=2.0, dtype="float32"))
    k3 = dev.build_kernel(saxpy_builder, dict(n=32, bn=8, alpha=3.0, dtype="float32"))
    assert k1 is k2, "identical defines must hit the kernel cache"
    assert k3 is not k1, "different defines must rebuild (runtime specialization)"
    assert dev.stats.builds == 2 and dev.stats.cache_hits == 1
    x = np.ones(32, np.float32)
    np.testing.assert_allclose(np.asarray(k1.run(x, x)[0]), 3.0 * x)
    np.testing.assert_allclose(np.asarray(k3.run(x, x)[0]), 4.0 * x)


def test_memory_swap_and_host_roundtrip():
    dev = Device("jnp")
    a = dev.malloc(np.arange(4, dtype=np.float32))
    b = dev.malloc(np.zeros(4, np.float32))
    a.swap(b)
    assert a.to_host().sum() == 0 and b.to_host().sum() == 6
    b.from_host(np.full(4, 2.0, np.float32))
    np.testing.assert_allclose(b.to_host(), 2.0)
    with pytest.raises(ValueError):
        b.from_host(np.zeros(5, np.float32))


def test_kernel_call_rebinds_output_memory():
    dev = Device("loops")
    k = dev.build_kernel(saxpy_builder, dict(n=16, bn=8, alpha=1.0, dtype="float32"))
    x = dev.malloc(np.ones(16, np.float32))
    y = dev.malloc(np.ones(16, np.float32))
    out = dev.malloc(np.zeros(16, np.float32))
    k(x, y, out)
    np.testing.assert_allclose(out.to_host(), 2.0)


def test_output_block_coverage_validation():
    def bad_builder(D):
        def body(ctx, x, y):
            y[...] = x[...]
        # grid of 4 cells all mapping to out block 0 -> must be rejected
        return Spec("bad", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,),
                                  index=lambda i: (0,))],
                    body=body)

    with pytest.raises(ValueError, match="visited more than once"):
        Device("jnp").build_kernel(bad_builder, {})


def test_nondivisible_block_rejected():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]
        return Spec("bad2", grid=(3,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(5,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(5,))],
                    body=body)

    with pytest.raises(ValueError, match="does not divide"):
        Device("jnp").build_kernel(bad, {})


# ---------------------------------------------------------------------------
# autotuning (the paper's setThreadArray tuning loop)
# ---------------------------------------------------------------------------

def test_autotune_picks_valid_block_and_preserves_results():
    from repro.core import autotune

    dev = Device("jnp")
    x = np.random.RandomState(0).randn(256).astype(np.float32)
    y = np.random.RandomState(1).randn(256).astype(np.float32)
    base = dict(n=256, alpha=1.5, dtype="float32")
    result = autotune(dev, saxpy_builder, base,
                      sweep={"bn": [7, 16, 64, 256]},   # 7 is invalid (256%7)
                      args=(x, y), repeats=2)
    assert result["bn"] in (16, 64, 256)
    assert len(result.trials) == 3                       # invalid one skipped
    k = dev.build_kernel(saxpy_builder, dict(result))
    np.testing.assert_allclose(np.asarray(k.run(x, y)[0]), 1.5 * x + y,
                               rtol=1e-5)
