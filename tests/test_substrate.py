"""Substrate tests: device memory, optimizer, data determinism,
checkpointing, recovery, watchdog, sharding rules, elastic mesh choice."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.configs import get_config, reduced
from repro.data import Prefetcher, SyntheticLMData, TextLMData, make_corpus
from repro.models import LM
from repro.optim import AdamW, WarmupCosine, global_norm
from repro.parallel import rules as R
from repro.runtime import ChaosError, FailureInjector, StepWatchdog, \
    choose_mesh_shape
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainLoop


# ---------------------------------------------------------------------------
# device memory
# ---------------------------------------------------------------------------

def test_malloc_casts_array_to_requested_dtype():
    from repro.core import Device

    dev = Device("jnp")
    m = dev.malloc(np.arange(4, dtype=np.int32), jnp.float32)
    assert m.dtype == jnp.float32          # dtype was silently dropped before
    np.testing.assert_allclose(m.to_host(), [0.0, 1.0, 2.0, 3.0])
    # no dtype -> keep the array's own
    assert dev.malloc(np.arange(4, dtype=np.int32)).dtype == jnp.int32
    # shape forms unchanged
    assert dev.malloc((2, 3)).shape == (2, 3)
    assert dev.malloc(5, jnp.int32).dtype == jnp.int32


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_formula():
    opt = AdamW(schedule=WarmupCosine(peak_lr=1e-2, warmup_steps=0,
                                      total_steps=10, final_frac=1.0),
                b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    state = opt.init(p)
    newp, state, _ = opt.update(g, state, p)
    # reference: m=0.1g/0.1 -> g ; v=0.01g^2/0.01 -> g^2; delta = g/|g| = sign
    want = np.asarray([1.0, -2.0]) - 1e-2 * np.asarray(
        [0.5 / (0.5 + 1e-8), 0.25 / (0.25 + 1e-8)])
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


def test_adamw_clipping_and_wd():
    opt = AdamW(schedule=WarmupCosine(peak_lr=1e-3, warmup_steps=0,
                                      total_steps=10), clip_norm=0.1,
                weight_decay=0.5)
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}
    st_ = opt.init(p)
    newp, _, m = opt.update(g, st_, p)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert np.all(np.isfinite(np.asarray(newp["w"])))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_global_norm_property(seed):
    rng = np.random.RandomState(seed)
    tree = {"a": jnp.asarray(rng.randn(7)), "b": [jnp.asarray(rng.randn(3, 2))]}
    flat = np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(tree)])
    np.testing.assert_allclose(float(global_norm(tree)),
                               np.linalg.norm(flat), rtol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    mk = lambda h: SyntheticLMData(vocab_size=97, seq_len=17, global_batch=8,
                                   seed=3, num_hosts=2, host_id=h)
    a, a2, b = mk(0), mk(0), mk(1)
    assert (a.batch(5) == a2.batch(5)).all()          # deterministic
    assert not (a.batch(5) == b.batch(5)).all()       # hosts disjoint
    assert not (a.batch(5) == a.batch(6)).all()       # steps differ
    assert a.batch(5).shape == (4, 17)
    assert a.batch(0).min() >= 0 and a.batch(0).max() < 97


def test_data_has_learnable_structure():
    d = SyntheticLMData(vocab_size=64, seq_len=256, global_batch=4, seed=0,
                        order_strength=0.95)
    b = d.batch(0)
    # successor distribution must be concentrated (markov structure)
    follows = {}
    for row in b:
        for t in range(len(row) - 1):
            follows.setdefault(row[t], []).append(row[t + 1])
    concentrations = [len(set(v)) / len(v) for v in follows.values()
                      if len(v) >= 8]
    assert np.mean(concentrations) < 0.8


def test_prefetcher_propagates_errors():
    class Bad:
        def batch(self, step):
            raise RuntimeError("boom")

    p = Prefetcher(Bad())
    with pytest.raises(RuntimeError, match="boom"):
        p.next()
    p.close()


def test_text_pipeline():
    t = TextLMData(make_corpus(5000, seed=1), seq_len=32, global_batch=4)
    b = t.batch(0)
    assert b.shape == (4, 32) and b.max() < 256
    assert (t.batch(3) == t.batch(3)).all()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(7, jnp.int32)},
            "d": [jnp.ones(2), jnp.zeros(3)]}
    save_tree(tree, str(tmp_path / "ck"))
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    got = restore_tree(template, str(tmp_path / "ck"))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, {"x": jnp.full(3, float(s))}, async_=False)
    assert mgr.latest_step() == 30
    assert mgr.all_steps() == [20, 30]          # step 10 GC'd
    _, tree, meta = mgr.restore({"x": jax.ShapeDtypeStruct((3,), jnp.float32)})
    assert float(np.asarray(tree["x"])[0]) == 30.0
    assert meta["step"] == 30


def test_checkpoint_async_save_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones(4)}, async_=True)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_tree({"x": jnp.ones(4)}, str(tmp_path / "ck"))
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_tree({"x": jax.ShapeDtypeStruct((5,), jnp.float32)},
                     str(tmp_path / "ck"))


# ---------------------------------------------------------------------------
# training loop: loss decreases, resume, recovery
# ---------------------------------------------------------------------------

def _loop(tmp_path, steps, **kw):
    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    return TrainLoop(model=model, mesh=make_local_mesh(model=1),
                     global_batch=8, seq_len=32, steps=steps,
                     ckpt_dir=str(tmp_path / "ck"), ckpt_every=10,
                     verbose=False, **kw)


def test_training_loss_decreases(tmp_path):
    out = _loop(tmp_path, 40).run()
    h = out["history"]
    assert np.mean(h[-5:]) < np.mean(h[:5]) - 0.3, (h[:5], h[-5:])


def test_training_resume_continues(tmp_path):
    loop = _loop(tmp_path, 20)
    loop.run()
    out = _loop(tmp_path, 30).run()   # resumes from step 20
    assert out["final_step"] == 30
    assert len(out["history"]) == 10  # only 10 new steps


def test_training_recovers_from_injected_failure(tmp_path):
    loop = _loop(tmp_path, 25, injector=FailureInjector([15]))
    out = loop.run()
    assert out["final_step"] == 25
    assert len(out["history"]) > 25 - 10  # re-ran some steps after restore


def test_training_gives_up_after_max_retries(tmp_path):
    inj = FailureInjector([5], fail_once=False)
    inj.fail_at = {5}
    loop = _loop(tmp_path, 10, injector=inj, max_retries=2)
    loop.injector.fail_once = False
    with pytest.raises(ChaosError):
        loop.run()


# ---------------------------------------------------------------------------
# watchdog + elastic
# ---------------------------------------------------------------------------

def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=20, sigma=3.0, min_samples=5)
    for i in range(10):
        assert not wd.observe(i, 1.0 + 0.01 * (i % 3))
    assert wd.observe(10, 5.0)
    assert wd.flagged and wd.flagged[0][0] == 10


def test_watchdog_absolute_deadline():
    wd = StepWatchdog(absolute_deadline_s=2.0, min_samples=100)
    assert wd.observe(0, 3.0)


def test_choose_mesh_shape_elastic():
    assert choose_mesh_shape(512, model=16, pod=2) == (2, 16, 16)
    assert choose_mesh_shape(256, model=16) == (16, 16)
    assert choose_mesh_shape(240, model=16) == (15, 16)  # lost a host
    assert choose_mesh_shape(17, model=16) == (1, 16)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_divisibility_and_layout():
    cfg = get_config("llama3_2_1b")
    model = LM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    specs = R.param_specs(params, cfg, FakeMesh())
    # embedding shards vocab; stacked attn weights shard their output dim
    assert specs["embed"] == P("model", None)
    stack = specs["stacks"][0]
    assert stack["attn"]["wq"] == P(None, None, "model")
    assert stack["attn"]["wo"] == P(None, "model", None)
    assert stack["norm1"] == P(None, None)
    # kv proj for llama: Hk*hd = 512, divisible by 16 -> sharded
    assert stack["attn"]["wk"] == P(None, None, "model")


def test_param_specs_moe_ep_vs_tp():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch, expect_ep in (("deepseek_v2_lite", True), ("mixtral_8x22b", False)):
        cfg = get_config(arch)
        model = LM(cfg)
        params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        specs = R.param_specs(params, cfg, FakeMesh())
        moe_stack = specs["stacks"][-1]["moe"]
        wg = moe_stack["w_gate"]
        if expect_ep:    # 64 experts % 16 == 0 -> expert-parallel
            assert wg == P(None, "model", None, None), (arch, wg)
        else:            # 8 experts -> TP over ffn dim
            assert wg == P(None, None, None, "model"), (arch, wg)


def test_zero1_shards_largest_dim():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config("llama3_2_1b")
    model = LM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = R.param_specs(params, cfg, FakeMesh())
    z = R.zero1_specs(specs, params, FakeMesh())
    # embed: (V, d) was ("model", None) -> d=2048 now data-sharded
    assert z["embed"] == P("model", "data")


def test_spec_bytes_per_device():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    shapes = {"w": jax.ShapeDtypeStruct((1600, 320), jnp.float32)}
    specs = {"w": P("model", None)}
    b = R.spec_bytes_per_device(shapes, specs, FakeMesh())
    assert b == 100 * 320 * 4
