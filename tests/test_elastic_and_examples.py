"""Elastic-rescale end-to-end (subprocess, 8 fake devices) + example smokes."""

import json
import os
import subprocess
import sys

import pytest

_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.models import LM
from repro.optim import AdamW, WarmupCosine
from repro.parallel.steps import build_train_step
from repro.runtime import choose_mesh_shape

cfg = reduced(get_config("llama3_2_1b"))
model = LM(cfg)
opt = AdamW(schedule=WarmupCosine(peak_lr=1e-3, warmup_steps=2, total_steps=20))
bs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}

def setup(devices, shape):
    mesh = Mesh(np.array(devices).reshape(shape), ("data", "model"))
    step_fn, sh = build_train_step(model, opt, mesh, batch_shapes=bs)
    return mesh, step_fn, sh

# phase 1: train 3 steps on the full 8-device mesh (4 data x 2 model)
mesh, step_fn, sh = setup(jax.devices(), (4, 2))
params = jax.device_put(model.init(jax.random.PRNGKey(0)), sh["params"])
opt_state = jax.device_put(opt.init(params), sh["opt"])
batch = jax.device_put({"tokens": jnp.zeros((8, 32), jnp.int32)}, sh["batch"])
for _ in range(3):
    params, opt_state, loss, _ = step_fn(params, opt_state, batch)
loss_full = float(loss)

mgr = CheckpointManager("/tmp/elastic_ck", keep=1)
mgr.save(3, (params, opt_state), async_=False)

# phase 2: "lose" half the devices -> 2x2 mesh, reshard-on-restore, continue
surv = jax.devices()[:4]
assert choose_mesh_shape(4, model=2) == (2, 2)
mesh2, step_fn2, sh2 = setup(surv, (2, 2))
template = (jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
            jax.eval_shape(lambda: opt.init(
                jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))))))
step, (params2, opt2), _ = mgr.restore(
    template, shardings=(sh2["params"], sh2["opt"]))
assert step == 3
batch2 = jax.device_put({"tokens": jnp.zeros((8, 32), jnp.int32)}, sh2["batch"])
params2, opt2, loss2, _ = step_fn2(params2, opt2, batch2)

# phase 3: determinism check — same step on the full mesh gives same loss
params, opt_state, loss3, _ = step_fn(params, opt_state, batch)
print(json.dumps({"ok": True, "loss_small_mesh": float(loss2),
                  "loss_full_mesh": float(loss3)}))
assert abs(float(loss2) - float(loss3)) < 1e-3, (float(loss2), float(loss3))
"""


def _run_sub(code, timeout=420):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_elastic_reshard_on_restore_subprocess():
    out = _run_sub(_ELASTIC)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"]
    # training continues identically after losing half the devices
    assert abs(rec["loss_small_mesh"] - rec["loss_full_mesh"]) < 1e-3


@pytest.mark.parametrize("script,args", [
    ("examples/quickstart.py", []),
    ("examples/sem_solve.py", ["--n", "3", "--elems", "2"]),
    ("examples/fd_wave.py", ["--backend", "jnp", "--size", "64",
                             "--steps", "50"]),
])
def test_example_scripts_run(script, args):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    path = os.path.join(os.path.dirname(__file__), "..", script)
    res = subprocess.run([sys.executable, path] + args, capture_output=True,
                         text=True, timeout=420, env=env)
    assert res.returncode == 0, res.stderr[-2000:]
