"""Rolling-window decode through the unified kernel (the last backend-
conditional attention path is gone).

``flash_decode`` takes a ``slot_pos`` input tile — each cache slot's absolute
position — and masks data-dependently, so ``gqa_decode`` with ``cfg.window``
runs the SAME kernel on the pallas path instead of falling back to a masked
grouped einsum. Covers: kernel vs masked-einsum vs full-history oracle across
wrap boundaries on all three backends (property-tested), window smaller than
a kv block, non-dividing cache lengths, GQA/MQA grouping, a jitted multi-step
decode loop reusing ONE compiled kernel, the layer path with the einsum
fallback hard-disabled, pre-hooks that must not eat a shared kwargs dict,
cache-overflow guards (prefill / eager decode_step / generate), and the
serving warmup probing windowed decode shapes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import BACKENDS, default_device
from repro.kernels.flash_attention import (decode_attention, decode_ref,
                                           flash_decode, mha_ref)
from repro.layers import attention as A
from repro.layers.common import use_kernel_backend
from repro.models import LM

from tests._hypothesis_compat import given, settings, strategies as st

import repro.kernels  # noqa: F401 — registers the op families


def _rolling(k_full, v_full, m):
    """Scatter a (b, hk, t, d) history into a rotated m-slot cache.

    Returns (k_cache, v_cache, slot_pos) with slot = pos % m — exactly the
    layout gqa_prefill_cache/gqa_decode maintain for cfg.window caches."""
    b, hk, t, d = k_full.shape
    kc = np.zeros((b, hk, m, k_full.shape[3]), k_full.dtype)
    vc = np.zeros((b, hk, m, v_full.shape[3]), v_full.dtype)
    sp = np.full((m,), -1, np.int32)
    for p in range(t):
        s = p % m
        kc[:, :, s] = k_full[:, :, p]
        vc[:, :, s] = v_full[:, :, p]
        sp[s] = p
    return jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(sp)


# ---------------------------------------------------------------------------
# kernel vs masked einsum vs full-history oracle, all three expansions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@settings(max_examples=6, deadline=None)
@given(m=st.integers(min_value=6, max_value=20),
       dt=st.integers(min_value=-5, max_value=30),
       heads=st.sampled_from([(2, 2), (4, 2), (4, 1)]),  # MHA / GQA / MQA
       bkv=st.integers(min_value=3, max_value=16))
def test_rotated_decode_matches_history_and_einsum(backend, m, dt, heads, bkv):
    """Across the wrap boundary (t < W, t == W, t >> W), non-dividing cache
    lengths (fit_block clamps bkv to a divisor of m) and head-group counts,
    the kernel == the slot_pos masked einsum == windowed attention over the
    FULL history."""
    h, hk = heads
    b, d = 1, 8
    t = max(1, m + dt)                     # query decodes token t-1
    rng = np.random.RandomState(m * 101 + t * 7 + h)
    k_full = rng.randn(b, hk, t, d).astype(np.float32)
    v_full = rng.randn(b, hk, t, d).astype(np.float32)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    kc, vc, sp = _rolling(k_full, v_full, m)

    # oracle 1: windowed causal attention over the full, un-rotated history
    want = mha_ref(q, jnp.asarray(k_full), jnp.asarray(v_full), causal=True,
                   window=m)
    # oracle 2: the slot_pos masked grouped einsum (decode_ref rotated path)
    ein = decode_ref(q, kc, vc, window=m, kv_len=t, slot_pos=sp)
    np.testing.assert_allclose(np.asarray(ein), np.asarray(want),
                               rtol=2e-4, atol=2e-4,
                               err_msg=f"decode_ref diverged (m={m}, t={t})")
    got = decode_attention(q, kc, vc, window=m, kv_len=t, slot_pos=sp,
                           block_kv=bkv, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4,
                               err_msg=f"kernel diverged (m={m}, t={t}, "
                                       f"bkv={bkv}, {backend})")


@pytest.mark.parametrize("backend", BACKENDS)
def test_rotated_decode_window_smaller_than_kv_block(backend):
    """window < block_kv: stale slots inside a live block must be masked by
    the slot_pos window term, not a block-level skip."""
    b, h, m, d, W = 1, 2, 16, 8, 5        # cache of 16 slots, window 5
    t = 27                                 # wrapped
    rng = np.random.RandomState(9)
    k_full = rng.randn(b, h, t, d).astype(np.float32)
    v_full = rng.randn(b, h, t, d).astype(np.float32)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    kc, vc, sp = _rolling(k_full, v_full, m)
    want = mha_ref(q, jnp.asarray(k_full), jnp.asarray(v_full), causal=True,
                   window=W)
    got = decode_attention(q, kc, vc, window=W, kv_len=t, slot_pos=sp,
                           block_kv=16, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_windowed_decode_loop_reuses_one_compiled_kernel():
    """A jitted decode step with traced kv_len + slot_pos builds the kernel
    ONCE and stays correct across the wrap boundary."""
    b, h, m, d = 1, 2, 8, 8
    rng = np.random.RandomState(11)
    t_max = 3 * m
    k_full = rng.randn(b, h, t_max, d).astype(np.float32)
    v_full = rng.randn(b, h, t_max, d).astype(np.float32)
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)

    @jax.jit
    def step(kc, vc, sp, n):
        return decode_attention(q, kc, vc, window=m, kv_len=n, slot_pos=sp,
                                block_kv=4, backend="jnp")

    dev = default_device("jnp", None)
    builds0 = dev.stats.builds
    for t in (1, m - 1, m, m + 1, 2 * m, t_max):
        kc, vc, sp = _rolling(k_full[:, :, :t], v_full[:, :, :t], m)
        got = step(kc, vc, sp, jnp.int32(t))
        want = mha_ref(q, jnp.asarray(k_full[:, :, :t]),
                       jnp.asarray(v_full[:, :, :t]), causal=True, window=m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=f"t={t}")
    assert dev.stats.builds - builds0 == 1, \
        "the growing/wrapping cache must not retrace or rebuild the kernel"


# ---------------------------------------------------------------------------
# layer path: gqa_decode with cfg.window runs the kernel, not the einsum
# ---------------------------------------------------------------------------

def _windowed_cfg(window=8):
    return dataclasses.replace(reduced(get_config("llama3_2_1b")),
                               window=window)


def test_gqa_decode_windowed_pallas_matches_jnp_across_wrap():
    cfg = _windowed_cfg(window=8)
    params = A.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    outs = {}
    for be in ("jnp", "pallas"):
        with use_kernel_backend(be):
            _, (k, v) = A.gqa_forward(params, x, cfg, return_kv=True)
            cache = A.gqa_prefill_cache(
                A.gqa_cache_init(cfg, b, 32, jnp.float32), k, v, cfg)
            ys, xt = [], x[:, -1:]
            for _ in range(8):              # crosses the W=8 wrap
                yt, cache = A.gqa_decode(params, xt, cache, cfg)
                ys.append(yt)
            outs[be] = np.asarray(jnp.concatenate(ys, 1))
    np.testing.assert_allclose(outs["pallas"], outs["jnp"],
                               rtol=2e-4, atol=2e-4)


def test_gqa_decode_windowed_issues_no_einsum_on_pallas(monkeypatch):
    """The acceptance criterion made executable: with cfg.window set and the
    pallas backend, the grouped-einsum fallback must never run."""
    cfg = _windowed_cfg(window=8)
    params = A.gqa_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    b = 2
    x = jax.random.normal(jax.random.PRNGKey(3), (b, 4, cfg.d_model))

    def boom(*a, **kw):
        raise AssertionError("grouped-einsum fallback ran on the pallas path")

    monkeypatch.setattr(A, "decode_ref", boom)   # the layer's einsum branch
    with use_kernel_backend("pallas"):
        _, (k, v) = A.gqa_forward(params, x, cfg, return_kv=True)
        cache = A.gqa_prefill_cache(
            A.gqa_cache_init(cfg, b, 16, jnp.float32), k, v, cfg)
        xt = x[:, -1:]
        for _ in range(6):                  # through the wrap, einsum-free
            yt, cache = A.gqa_decode(params, xt, cache, cfg)
    assert np.isfinite(np.asarray(yt)).all()


# ---------------------------------------------------------------------------
# pre hooks must not eat keys from a shared kwargs/params dict
# ---------------------------------------------------------------------------

def test_decode_pre_does_not_mutate_shared_params():
    from repro.kernels.flash_attention.ops import _decode_pre

    rng = np.random.RandomState(13)
    q = jnp.asarray(rng.randn(1, 2, 1, 8), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 16, 8), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 16, 8), jnp.float32)

    params = dict(flash_decode.defaults, kv_len=5, slot_pos=None)
    _decode_pre((q, k, v), params)
    _decode_pre((q, k, v), params)          # second call sees the SAME dict
    assert params["kv_len"] == 5, "pre hook ate kv_len from a reused dict"

    # end-to-end: one kwargs dict, two calls, identical results
    kw = dict(kv_len=5, block_kv=8, backend="jnp")
    o1 = decode_attention(q, k, v, **kw)
    o2 = decode_attention(q, k, v, **kw)
    assert kw == dict(kv_len=5, block_kv=8, backend="jnp")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    want = decode_ref(q, k, v, kv_len=5)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_pre_does_not_mutate_shared_params():
    from repro.kernels.ssm_scan.ops import _pre as ssm_pre

    rng = np.random.RandomState(14)
    bt, L, dm, n = 1, 8, 4, 2
    args = (jnp.asarray(rng.randn(bt, L, dm), jnp.float32),
            jnp.asarray(np.abs(rng.randn(bt, L, dm)) * 0.1, jnp.float32),
            -jnp.asarray(np.abs(rng.randn(dm, n)) + 0.1, jnp.float32),
            jnp.asarray(rng.randn(bt, L, n), jnp.float32),
            jnp.asarray(rng.randn(bt, L, n), jnp.float32),
            jnp.asarray(rng.randn(dm), jnp.float32))
    h0 = jnp.ones((bt, dm, n), jnp.float32)
    params = {"h0": h0}
    ssm_pre(args, params)
    assert params.get("h0") is h0, "ssm pre hook ate h0 from a reused dict"


# ---------------------------------------------------------------------------
# cache overflow is an explicit error, not a silent slot-(m-1) overwrite
# ---------------------------------------------------------------------------

def _tiny_lm():
    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(5))
    return cfg, model, params


def test_prefill_longer_than_max_len_raises():
    cfg, model, params = _tiny_lm()
    tokens = jnp.asarray(np.random.RandomState(6).randint(
        0, cfg.vocab_size, (1, 8)))
    with pytest.raises(ValueError, match="cache overflow"):
        model.prefill(params, tokens, max_len=4)


def test_decode_past_capacity_raises_eagerly():
    cfg, model, params = _tiny_lm()
    rng = np.random.RandomState(7)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 4)))
    _, cache = model.prefill(params, tokens, max_len=5)
    assert model.cache_capacity(cache) == 5
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 1)))
    _, cache = model.decode_step(params, tok, cache)      # pos 4 -> 5: fits
    with pytest.raises(ValueError, match="cache overflow"):
        model.decode_step(params, tok, cache)             # pos 5 >= cap 5

    # rolling-window archs are exempt: the cache rotates, never overflows
    wcfg = _windowed_cfg(window=4)
    wmodel = LM(wcfg)
    wparams = wmodel.init(jax.random.PRNGKey(8))
    _, wcache = wmodel.prefill(wparams, tokens, max_len=5)
    assert wmodel.cache_capacity(wcache) is None
    for _ in range(4):                      # decode well past max_len
        _, wcache = wmodel.decode_step(wparams, tok, wcache)


def test_generate_overflow_guard():
    from repro.launch.serve import generate

    cfg, model, params = _tiny_lm()
    prompts = np.random.RandomState(9).randint(
        0, cfg.vocab_size, (1, 4)).astype(np.int32)
    with pytest.raises(ValueError, match="cache overflow"):
        generate(model, params, prompts, gen_tokens=4, max_len=6)


# ---------------------------------------------------------------------------
# serving warmup probes windowed decode shapes
# ---------------------------------------------------------------------------

def test_warmup_adopts_windowed_decode_winner(tmp_path, monkeypatch):
    from repro.launch.serve import apply_tuned_winners

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    cfg = _windowed_cfg(window=128)         # the declared sweep's smallest
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, plen, max_len = 2, 16, 256
    m = min(max_len, cfg.window)            # the serving cache length
    assert apply_tuned_winners(cfg, b, plen, max_len) == {}  # cold cache

    rng = np.random.RandomState(10)
    q = jnp.asarray(rng.randn(b, h, 1, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, hk, m, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, hk, m, hd), jnp.float32)
    old_default = flash_decode.defaults["block_kv"]
    try:
        r = flash_decode.tune((q, k, v), window=cfg.window, repeats=1)
        adopted = apply_tuned_winners(cfg, b, plen, max_len)
        assert adopted["flash_decode"]["block_kv"] == r["block_kv"]
        assert flash_decode.defaults["block_kv"] == r["block_kv"]
    finally:
        flash_decode.defaults["block_kv"] = old_default
