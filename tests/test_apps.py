"""Paper §4 applications: backend equivalence + physics correctness."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import dg_swe, fd2d, sem
from repro.core import BACKENDS


# ---------------------------------------------------------------------------
# §4.1 finite difference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("radius", [1, 2])
def test_fd_kernel_matches_reference(backend, radius):
    app = fd2d.FDWave(model=backend, width=32, height=48, radius=radius,
                      block=(16, 16))
    u1, u2 = app.o_u1.data, app.o_u2.data
    app.fd2d(app.o_u1, app.o_u2, app.o_u3)
    ref = fd2d.reference_step(u1, u2, app.weights, app.dx, app.dt)
    np.testing.assert_allclose(app.o_u3.to_host(), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fd_backends_agree_over_time():
    sols = {}
    for be in BACKENDS:
        sols[be] = fd2d.FDWave(model=be, width=32, height=32, radius=1,
                               block=(8, 8)).run(20).solution
    for be in BACKENDS:
        np.testing.assert_allclose(sols[be], sols["jnp"], rtol=1e-4, atol=1e-4)


def test_fd_converges_to_analytic_standing_wave():
    # error should drop ~4x when resolution doubles (2nd order)
    errs = []
    for nx in (32, 64):
        app = fd2d.FDWave(model="jnp", width=nx, height=nx, radius=1,
                          block=(8, 8), cfl=0.25)
        steps = int(0.5 / app.dt)
        app.run(steps)
        errs.append(np.abs(app.solution - app.analytic()).max())
    assert errs[1] < errs[0] / 2.5, errs


# ---------------------------------------------------------------------------
# §4.2 spectral elements
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_sem_kernel_matches_oracle(backend):
    op = sem.SEMOperator(model=backend, ex=2, ey=2, ez=1, n=3, deform=0.12)
    rng = np.random.RandomState(0)
    u = rng.randn(op.E, op.nq, op.nq, op.nq).astype(np.float32)
    got = np.asarray(op.apply_local(u))
    ref = np.asarray(sem.apply_ref(jnp.asarray(u), op.o_geo.data, op.o_dmat.data))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_sem_constant_field_hits_only_mass():
    # grad(const)=0 so A u = alpha * M * u exactly (deformed mesh too)
    op = sem.SEMOperator(model="jnp", ex=2, ey=1, ez=2, n=4, deform=0.15,
                         alpha=2.5)
    u = np.ones((op.E, op.nq, op.nq, op.nq), np.float32)
    au = np.asarray(op.apply_local(u))
    np.testing.assert_allclose(au, 2.5 * op.mass, rtol=1e-4, atol=1e-5)


def test_sem_assembled_operator_is_symmetric_and_spd():
    op = sem.SEMOperator(model="jnp", ex=2, ey=2, ez=2, n=3, deform=0.1)
    rng = np.random.RandomState(1)
    u = jnp.asarray(rng.randn(op.nglob).astype(np.float32))
    v = jnp.asarray(rng.randn(op.nglob).astype(np.float32))
    Au = op.apply_global(u)
    Av = op.apply_global(v)
    uAv = float(jnp.vdot(u, Av))
    vAu = float(jnp.vdot(v, Au))
    assert abs(uAv - vAu) < 1e-2 * max(1.0, abs(uAv)), (uAv, vAu)
    assert float(jnp.vdot(u, Au)) > 0  # SPD for kappa>0, alpha>0


def test_sem_kappa_variable_coefficient():
    kappa = lambda x, y, z: 1.0 + 0.5 * np.sin(np.pi * x) * np.cos(np.pi * y)
    op = sem.SEMOperator(model="loops", ex=2, ey=2, ez=1, n=3, kappa=kappa)
    u = np.random.RandomState(2).randn(op.E, op.nq, op.nq, op.nq).astype(np.float32)
    ref = np.asarray(sem.apply_ref(jnp.asarray(u), op.o_geo.data, op.o_dmat.data))
    np.testing.assert_allclose(np.asarray(op.apply_local(u)), ref,
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# §4.3 DG shallow water (volume kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_dg_volume_matches_oracle(backend):
    app = dg_swe.DGVolume(model=backend, nx=4, ny=4, n=3, jitter=0.2)
    rng = np.random.RandomState(3)
    Q = np.stack([
        2.0 + 0.1 * rng.randn(app.E, app.np_),
        0.3 * rng.randn(app.E, app.np_),
        0.3 * rng.randn(app.E, app.np_),
    ], axis=-1).astype(np.float32)
    got = np.asarray(app.rhs_volume(Q))
    ref = np.asarray(dg_swe.volume_ref(jnp.asarray(Q), app.o_geom.data,
                                       app.o_db.data, app.o_dr.data,
                                       app.o_ds.data))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_dg_lake_at_rest_well_balanced():
    # linear bathymetry, h + B = const, zero momentum -> volume RHS exactly 0
    bath = lambda x, y: 0.2 * x + 0.1 * y + 0.5
    app = dg_swe.DGVolume(model="jnp", nx=4, ny=4, n=4, bathymetry=bath,
                          jitter=0.15)
    eta = 2.0
    h = eta - app.B
    Q = np.stack([h, np.zeros_like(h), np.zeros_like(h)], -1).astype(np.float32)
    rhs = np.asarray(app.rhs_volume(Q))
    assert np.abs(rhs).max() < 5e-4, np.abs(rhs).max()


def test_dg_constant_state_zero_rhs():
    app = dg_swe.DGVolume(model="loops", nx=3, ny=3, n=2, jitter=0.0)
    Q = np.stack([np.full((app.E, app.np_), 1.7),
                  np.zeros((app.E, app.np_)),
                  np.zeros((app.E, app.np_))], -1).astype(np.float32)
    rhs = np.asarray(app.rhs_volume(Q))
    assert np.abs(rhs).max() < 1e-4


# ---------------------------------------------------------------------------
# §4.3 full SWE solver (volume + surface + LSERK)
# ---------------------------------------------------------------------------

def test_swe_full_rhs_well_balanced_with_walls():
    from repro.apps.dg_swe import SWESolver
    bath = lambda x, y: 0.15 * x + 0.1 * y + 0.4
    sol = SWESolver(model="jnp", nx=4, ny=4, n=3, jitter=0.0, bathymetry=bath)
    h = 2.0 - sol.B
    Q = np.stack([h, np.zeros_like(h), np.zeros_like(h)], -1).astype(np.float32)
    rhs = np.asarray(sol.rhs(jnp.asarray(Q)))
    assert np.abs(rhs).max() < 5e-4, np.abs(rhs).max()


def test_swe_timestepping_stable_and_conservative():
    from repro.apps.dg_swe import SWESolver
    sol = SWESolver(model="jnp", nx=4, ny=4, n=3, jitter=0.0)
    x, y = sol.mesh["x"], sol.mesh["y"]
    h0 = 1.0 + 0.1 * np.exp(-20 * (x ** 2 + y ** 2))
    Q = jnp.asarray(np.stack([h0, 0 * h0, 0 * h0], -1), jnp.float32)
    m0 = float(sol.mass(Q))
    for _ in range(50):
        Q = sol.step(Q, 2e-4)
    m1 = float(sol.mass(Q))
    assert np.isfinite(np.asarray(Q)).all()
    assert abs(m1 - m0) / m0 < 1e-5   # wall BC conserves water volume
    assert 0.9 < float(Q[..., 0].min()) and float(Q[..., 0].max()) < 1.2


@pytest.mark.parametrize("backend", ["jnp", "loops", "pallas"])
def test_swe_surface_kernel_backend_equivalence(backend):
    from repro.apps.dg_swe import SWESolver
    ref = SWESolver(model="jnp", nx=3, ny=3, n=2, jitter=0.0)
    got = SWESolver(model=backend, nx=3, ny=3, n=2, jitter=0.0)
    rng = np.random.RandomState(0)
    Q = jnp.asarray(np.stack([2.0 + 0.05 * rng.randn(ref.E, ref.np_),
                              0.1 * rng.randn(ref.E, ref.np_),
                              0.1 * rng.randn(ref.E, ref.np_)], -1), jnp.float32)
    np.testing.assert_allclose(np.asarray(got.rhs(Q)), np.asarray(ref.rhs(Q)),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# autotune adoption: `tune_cli --apps` probes share cache keys with drivers
# ---------------------------------------------------------------------------

def test_tune_apps_winner_adopted_by_sem_driver(tmp_path, monkeypatch):
    """The --apps probe and SEMOperator construction must produce the SAME
    tuning-problem cache key: a winner persisted from the probe is adopted
    by the next driver build (eb=None). The sweep is pinned to one candidate
    that differs from the fitted default, so adoption is observable."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    from repro.kernels.apps import sem_apply as sem_op
    from repro.tune_cli import _app_probes

    name, arrays, params = next(p for p in _app_probes()
                                if p[0] == "sem_apply")
    monkeypatch.setattr(sem_op, "sweep", dict(eb=[2]))
    r = sem_op.tune(arrays, backend="jnp", repeats=1, **params)
    assert r["eb"] == 2 and not r.cached
    tuned = sem.SEMOperator(model="jnp", ex=2, ey=2, ez=2, n=1, deform=0.1)
    assert tuned.eb == 2          # adopted the persisted winner, not E-fitted
    untuned = sem.SEMOperator(model="loops", ex=2, ey=2, ez=2, n=1, deform=0.1)
    assert untuned.eb == 8        # other backend: cache miss, default fit
