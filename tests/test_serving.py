"""Continuous-batching serving: paged decode parity, allocator/scheduler
invariants, and the engine vs the static-batch oracle.

The load-bearing claim: ``flash_decode_paged`` reading KV through a block
table is BIT-IDENTICAL to contiguous ``flash_decode`` when the page size
equals its kv block size — paged pages stream through the same online-
softmax accumulation in the same logical order, and fully-masked blocks
are exact no-ops. Everything above it (layer, model, Engine) inherits that
parity, so a mixed-length engine run with mid-flight slot refill and
preemption must reproduce the per-sequence static-batch tokens exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import BACKENDS
from repro.kernels.flash_attention import (decode_attention,
                                           paged_decode_attention,
                                           paged_decode_ref)
from repro.models import LM
from repro.serving import Engine, PageAllocator, Scheduler

from tests._hypothesis_compat import given, settings, strategies as st

import repro.kernels  # noqa: F401 — registers the op families


def _paged_from_contiguous(rng, kc, vc, page):
    """Scatter (b, hk, cap, d) contiguous caches into a SHUFFLED page pool.
    Returns (k_pages, v_pages, block_table); page 0 stays the null page."""
    b, hk, cap, d = kc.shape
    nsp = cap // page
    npages = b * nsp + 1
    perm = rng.permutation(np.arange(1, npages))[:b * nsp].reshape(b, nsp)
    kp = np.zeros((npages, hk, page, d), kc.dtype)
    vp = np.zeros((npages, hk, page, vc.shape[-1]), vc.dtype)
    for bi in range(b):
        for j in range(nsp):
            kp[perm[bi, j]] = kc[bi, :, j * page:(j + 1) * page]
            vp[perm[bi, j]] = vc[bi, :, j * page:(j + 1) * page]
    return kp, vp, perm.astype(np.int32)


# ---------------------------------------------------------------------------
# kernel-level bit-parity: paged vs contiguous, all three expansions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@settings(max_examples=5, deadline=None)
@given(page=st.sampled_from([4, 8, 16]),
       extra=st.integers(min_value=0, max_value=13),
       g=st.sampled_from([1, 2, 4]),
       window=st.sampled_from([None, 48]))
def test_paged_decode_bitwise_matches_contiguous(backend, page, extra, g,
                                                 window):
    """Every sequence's paged output must equal the contiguous kernel run
    at block_kv == page — bitwise, including non-dividing kv lengths."""
    b, hk, d = 2, 2, 32
    h = hk * g
    rng = np.random.default_rng(page * 100 + extra * 7 + g)
    cap = 4 * page                        # pool capacity per sequence
    kv_len = np.minimum(
        np.array([cap - extra, 2 * page + 1], np.int32), cap)
    kv_len = np.maximum(kv_len, 1)
    q = rng.standard_normal((b, h, 1, d), np.float32)
    kc = rng.standard_normal((b, hk, cap, d), np.float32)
    vc = rng.standard_normal((b, hk, cap, d), np.float32)
    kp, vp, table = _paged_from_contiguous(rng, kc, vc, page)

    got = np.asarray(paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
        block_table=table, kv_len=kv_len, window=window, backend=backend))
    for bi in range(b):
        exp = np.asarray(decode_attention(
            jnp.asarray(q[bi:bi + 1]), jnp.asarray(kc[bi:bi + 1]),
            jnp.asarray(vc[bi:bi + 1]), kv_len=int(kv_len[bi]),
            window=window, block_kv=page, backend=backend))
        if backend == "jnp":
            # the fully-jitted jnp expansion lets XLA fuse the gather into
            # the surrounding graph, which can reassociate a rounding step;
            # loops/pallas execute block-by-block and stay bit-exact
            np.testing.assert_allclose(got[bi:bi + 1], exp,
                                       rtol=1e-5, atol=1e-6)
        else:
            assert (got[bi:bi + 1] == exp).all(), (
                f"paged != contiguous bitwise at row {bi} "
                f"(page={page}, kv_len={int(kv_len[bi])}, g={g}, "
                f"window={window}, backend={backend})")


@pytest.mark.parametrize("backend", BACKENDS)
def test_paged_decode_matches_ref_with_pos_pages(backend):
    """Rotated layouts: explicit pos_pages (with -1 holes) drive the mask
    identically in the op and the oracle."""
    b, h, hk, d, page = 1, 4, 2, 32, 8
    rng = np.random.default_rng(3)
    nsp, npages = 3, 5
    q = rng.standard_normal((b, h, 1, d), np.float32)
    kp = rng.standard_normal((npages, hk, page, d), np.float32)
    vp = rng.standard_normal((npages, hk, page, d), np.float32)
    table = np.array([[2, 4, 1]], np.int32)
    pos = np.full((npages, page), -1, np.int32)
    # pages hold positions out of slot order, with holes. The kernel's
    # block-skip shortcut assumes logical order only while q_pos < capacity
    # (the rolling-cache contract flash_decode shares), so a rotated layout
    # is exercised with kv_len > capacity — every block runs, the mask does
    # the work.
    pos[2, :5] = np.arange(5)
    pos[4, :8] = np.arange(5, 13)
    pos[1, :3] = np.arange(13, 16)
    kv_len = np.array([3 * page + 1], np.int32)
    got = np.asarray(paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), block_table=table,
        kv_len=kv_len, pos_pages=pos, backend=backend))
    exp = np.asarray(paged_decode_ref(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), block_table=table,
        kv_len=kv_len, pos_pages=pos))
    np.testing.assert_allclose(got, exp, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# allocator / scheduler: no page leaked, none double-owned
# ---------------------------------------------------------------------------

def test_allocator_all_or_nothing_and_release():
    pa = PageAllocator(num_pages=6, page_size=4)
    assert pa.free_pages == 5
    a = pa.alloc("a", 3)
    assert a is not None and len(a) == 3 and 0 not in a
    assert pa.alloc("b", 3) is None          # shortfall: NO state change
    assert pa.free_pages == 2
    b = pa.alloc("b", 2)
    assert b is not None and not (set(a) & set(b))
    pa.check_invariants()
    freed = pa.release("a")
    assert sorted(freed) == sorted(a) and pa.free_pages == 3
    pa.check_invariants()
    pa.release("b")
    assert pa.free_pages == 5
    pa.check_invariants()


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_scheduler_random_walk_never_leaks_pages(seed):
    rng = np.random.default_rng(seed)
    sched = Scheduler(batch=3, page_size=4, num_pages=10, max_len=24)
    for _ in range(400):
        op = int(rng.integers(0, 5))
        if op == 0 and len(sched.queue) < 6:
            plen = int(rng.integers(1, 12))
            sched.submit([1] * plen, int(rng.integers(1, 8)))
        elif op == 1:
            sched.admit()
        elif op == 2 and sched.running:
            # simulate one emitted token, then grow (preempting on famine)
            slot = int(rng.choice(sched.running))
            req = sched.slots[slot]
            req.tokens.append(3)
            if len(req.tokens) >= req.max_new:
                sched.retire(slot)
            else:
                while not sched.grow(slot):
                    if sched.preempt_youngest(exclude=slot) is None:
                        raise AssertionError("pool lost a whole sequence")
        elif op == 3 and sched.running:
            sched.preempt_youngest()
        elif op == 4 and sched.running:
            sched.retire(int(rng.choice(sched.running)))
        sched.pages.check_invariants()
    for slot in list(sched.running):
        sched.retire(slot)
    sched.pages.check_invariants()
    assert sched.pages.free_pages == 9       # everything returned


def test_admission_is_fifo_no_queue_jumping():
    sched = Scheduler(batch=2, page_size=4, num_pages=4, max_len=16)
    big = sched.submit([1] * 12, 4)          # needs 4 pages, only 3 free
    small = sched.submit([1], 1)
    placed = sched.admit()
    # the big front request can't fit -> NOTHING admits (small must wait)
    assert placed == [] and sched.queue[0].rid == big
    assert sched.pages.free_pages == 3
    del small


# ---------------------------------------------------------------------------
# engine vs per-sequence static oracle (mixed lengths, refill, preemption)
# ---------------------------------------------------------------------------

def _tiny_model():
    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, m, max_len):
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, cache = model.prefill(params, toks, max_len=max_len)
    tok = int(model.greedy_token(logits[0]))
    outs = [tok]
    for _ in range(m - 1):
        nxt, _, cache = model.greedy_step(params,
                                          jnp.asarray([[tok]], jnp.int32),
                                          cache)
        tok = int(nxt[0])
        outs.append(tok)
    return outs


def test_engine_mixed_lengths_matches_static_oracle():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 9, 3, 7)]
    max_new = [6, 4, 8, 5]
    eng = Engine(model, params, batch=2, max_len=32, page_size=4,
                 greedy=True)
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_new)]
    out = eng.drain(max_steps=300)
    # 4 requests through 2 slots: refill happened mid-flight
    for rid, p, m in zip(rids, prompts, max_new):
        assert out[rid] == _oracle(model, params, p, m, 32), rid
    eng.sched.pages.check_invariants()
    assert eng.sched.pages.free_pages == eng.sched.pages.num_pages - 1


def test_engine_preemption_still_bit_exact():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (6, 10, 4)]
    max_new = [8, 6, 9]
    # pool too small for 3 full sequences: preemption-by-eviction must fire
    eng = Engine(model, params, batch=3, max_len=24, page_size=4,
                 num_pages=9, greedy=True)
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_new)]
    out = eng.drain(max_steps=500)
    assert sum(r.preempted for r in eng._requests.values()) > 0
    for rid, p, m in zip(rids, prompts, max_new):
        assert out[rid] == _oracle(model, params, p, m, 24), rid
    eng.sched.pages.check_invariants()


def test_engine_eos_retires_and_refills():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 6, 4)]
    # pick an EOS that row 0 actually emits, from an eos-free dry run
    free = _oracle(model, params, prompts[0], 6, 32)
    eos = free[2]
    eng = Engine(model, params, batch=2, max_len=32, page_size=8,
                 eos_id=eos, greedy=True)
    rids = [eng.submit(p, 8) for p in prompts]
    out = eng.drain(max_steps=300)
    for rid, p in zip(rids, prompts):
        exp = _oracle(model, params, p, 8, 32)
        if eos in exp:
            exp = exp[:exp.index(eos) + 1]   # EOS itself is emitted
        assert out[rid] == exp, rid
    assert out[rids[0]][-1] == eos and len(out[rids[0]]) == 3


# ---------------------------------------------------------------------------
# launch.serve.generate: engine wrapper vs static path, pad/temperature fixes
# ---------------------------------------------------------------------------

def test_generate_engine_matches_static():
    from repro.launch.serve import _generate_static, generate
    cfg, model, params = _tiny_model()
    prompts = np.random.RandomState(3).randint(
        0, cfg.vocab_size, (3, 6)).astype(np.int32)
    out_e, st_e = generate(model, params, prompts, gen_tokens=5,
                           engine="paged", page_size=4)
    out_s, st_s = _generate_static(model, params, prompts, gen_tokens=5)
    assert st_e["engine"] and not st_s["engine"]
    np.testing.assert_array_equal(out_e, out_s)


def test_generate_routes_static_for_unpageable():
    from repro.launch.serve import generate
    cfg = dataclasses.replace(reduced(get_config("llama3_2_1b")), window=8)
    model = LM(cfg)
    assert not model.pageable
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 6)).astype(np.int32)
    out, stats = generate(model, params, prompts, gen_tokens=3)
    assert stats["engine"] is False and out.shape == (2, 3)


@pytest.mark.parametrize("engine", ["paged", "static"])
def test_generate_pad_token_is_explicit(engine):
    from repro.launch.serve import generate
    cfg, model, params = _tiny_model()
    prompts = np.random.RandomState(4).randint(
        0, cfg.vocab_size, (2, 5)).astype(np.int32)
    base, _ = generate(model, params, prompts, gen_tokens=6, engine=engine)
    eos = int(base[0, 2])                    # row 0 finishes at column 2
    out, _ = generate(model, params, prompts, gen_tokens=6, engine=engine,
                      eos_id=eos, pad_id=0)
    row = out[0]
    stop = int(np.argmax(row == eos))
    assert row[stop] == eos
    assert (row[stop + 1:] == 0).all()
    # the old behavior (pad with eos) is still the DEFAULT when pad_id unset
    out2, _ = generate(model, params, prompts, gen_tokens=6, engine=engine,
                       eos_id=eos)
    row2 = out2[0]
    assert (row2[int(np.argmax(row2 == eos)):] == eos).all()


def test_generate_temperature_threads_into_sampling():
    from repro.launch.serve import _generate_static
    cfg, model, params = _tiny_model()
    prompts = np.random.RandomState(5).randint(
        0, cfg.vocab_size, (2, 5)).astype(np.int32)
    greedy_out, _ = _generate_static(model, params, prompts, gen_tokens=4)
    # temperature -> 0 sharpens categorical into argmax: the fix is visible
    # (pre-fix, temperature was silently ignored)
    cold, _ = _generate_static(model, params, prompts, gen_tokens=4,
                               greedy=False, rng=jax.random.PRNGKey(0),
                               temperature=1e-4)
    np.testing.assert_array_equal(cold, greedy_out)
    with pytest.raises(ValueError, match="temperature"):
        _generate_static(model, params, prompts, gen_tokens=2, greedy=False,
                         temperature=0.0)


# ---------------------------------------------------------------------------
# model-level gates
# ---------------------------------------------------------------------------

def test_unpageable_models_raise_on_paged_cache():
    cfg = dataclasses.replace(reduced(get_config("llama3_2_1b")), window=8)
    model = LM(cfg)
    with pytest.raises(ValueError, match="paged decode"):
        model.init_paged_cache(2, 8, 4, 4)
    with pytest.raises(ValueError, match="pageable"):
        Engine(model, {}, batch=2, max_len=16, page_size=4)
