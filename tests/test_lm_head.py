"""Fused LM-head op + fused model head + this PR's CI satellites.

Covers: the multi-granularity lm_head kernels vs their oracles on all three
backends (vocab padding rows, non-dividing vocab blocks, bf16 activations,
argmax tie semantics), custom-VJP gradients vs the oracle VJP, model-level
fused-CE / fused-decode parity with the unfused paths (exact greedy-argmax
agreement), the labels>=vocab_size host-side guard, the ``Tile(reduce=...)``
validation gaps the op flushed out, and train-shape tune-winner adoption.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BACKENDS, Device, Scratch, Spec, Tile, registered_ops
from repro.kernels.lm_head import (lm_head_ce, lm_head_ce_ref, lm_head_logits,
                                   lm_head_logits_ref)
from repro.configs import get_config, reduced
from repro.models import LM

import repro.kernels  # noqa: F401 — registers the op families

from _hypothesis_compat import given, settings, strategies as st


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if jnp.dtype(dtype) == jnp.bfloat16 \
        else dict(rtol=3e-4, atol=3e-4)


def _mk(seed, R=16, d=16, V=64, vocab=None, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    vocab = V if vocab is None else vocab
    x = jnp.asarray(rng.randn(R, d), jnp.float32).astype(dtype)
    w = jnp.asarray(rng.randn(d, V), jnp.float32).astype(dtype)
    labels = jnp.asarray(rng.randint(0, vocab, (R, 1)), jnp.int32)
    return x, w, labels


# ---------------------------------------------------------------------------
# kernel vs oracle: CE path (lse/gold only — no materialized logits)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_ce_matches_ref_with_padding_and_nondividing_blocks(backend):
    # V=96 padded down to vocab=70 (the last vocab block is PARTIALLY padded
    # and, at block_v=16, one block is FULLY padded); block_v=40 does not
    # divide 96 and fit_block degrades it
    x, w, labels = _mk(0, R=24, d=32, V=96, vocab=70)
    ref = lm_head_ce_ref(x, w, labels, vocab=70)
    for bv in (16, 40, 96):
        got = lm_head_ce(x, w, labels, vocab=70, block_r=8, block_v=bv,
                         block_k=16, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   **_tol(x.dtype),
                                   err_msg=f"{backend} bv={bv}")


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 999),
       vocab=st.sampled_from([64, 63, 40, 1]),
       blocks=st.sampled_from([(8, 16, 8), (16, 64, 16), (4, 24, 4)]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_ce_property_all_backends(seed, vocab, blocks, dtype):
    br, bv, bk = blocks
    x, w, labels = _mk(seed, R=16, d=16, V=64, vocab=vocab,
                       dtype=jnp.dtype(dtype))
    ref = lm_head_ce_ref(x, w, labels, vocab=vocab)
    for backend in BACKENDS:
        got = lm_head_ce(x, w, labels, vocab=vocab, block_r=br, block_v=bv,
                         block_k=bk, backend=backend)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype), err_msg=f"{backend} vocab={vocab} blocks={blocks}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_ce_grads_match_oracle_vjp(backend):
    x, w, labels = _mk(1, R=16, d=24, V=64, vocab=50)
    r = jnp.asarray(np.random.RandomState(2).randn(16), jnp.float32)

    def loss_k(x_, w_):
        return (lm_head_ce(x_, w_, labels, vocab=50, block_r=8, block_v=16,
                           block_k=8, backend=backend) * r).sum()

    def loss_r(x_, w_):
        return (lm_head_ce_ref(x_, w_, labels, vocab=50) * r).sum()

    gk = jax.grad(loss_k, argnums=(0, 1))(x, w)
    gr = jax.grad(loss_r, argnums=(0, 1))(x, w)
    for name, a, b in zip("xw", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"d{name} mismatch on {backend}")


def test_ce_grads_under_jit_bf16():
    x, w, labels = _mk(3, R=8, d=16, V=32, dtype=jnp.bfloat16)
    g = jax.jit(jax.grad(lambda x_: lm_head_ce(
        x_, w, labels, block_r=4, block_v=16, block_k=8).sum()))(x)
    assert g.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


@pytest.mark.parametrize("backend", BACKENDS)
def test_ce_pads_odd_row_counts(backend):
    # R = B*(S-1) is odd-ish for power-of-two seq lens: the pre hook pads
    # rows to a block multiple (labels pad with 0) and the post/bwd hooks
    # slice the pad back off — values AND grads must be pad-invariant
    x, w, labels = _mk(8, R=30, d=16, V=64, vocab=50)
    ref = lm_head_ce_ref(x, w, labels, vocab=50)
    got = lm_head_ce(x, w, labels, vocab=50, block_r=8, block_v=16,
                     block_k=8, backend=backend)
    assert got.shape == (30,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)
    gk = jax.grad(lambda x_, w_: lm_head_ce(
        x_, w_, labels, vocab=50, block_r=8, block_v=16, block_k=8,
        backend=backend).sum(), argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x_, w_: lm_head_ce_ref(
        x_, w_, labels, vocab=50).sum(), argnums=(0, 1))(x, w)
    for name, a, b in zip("xw", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"d{name} pad mismatch {backend}")


def test_ce_traces_at_production_train_shapes():
    # regression: B=8, S=4096, d=2048, llama-3 vocab — rows 8*4095 = 32760
    # never divides a power-of-two block_r, and vpad = 256*501 fits block_v
    # 512 -> 501 (a mild, legitimate degradation). The old any-shrink guard
    # raised here; row padding + the blowup-ratio guard must let the fused
    # CE path trace at real train shapes.
    R, d, V = 8 * 4095, 2048, 128256         # pad_vocab(128256) == 128256
    x = jax.ShapeDtypeStruct((R, d), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((d, V), jnp.bfloat16)
    labels = jax.ShapeDtypeStruct((R, 1), jnp.int32)
    out = jax.eval_shape(
        lambda x_, w_, l_: lm_head_ce(x_, w_, l_, vocab=128256), x, w, labels)
    assert out.shape == (R,) and out.dtype == jnp.float32
    # and the grads trace too (the bwd kernel shares the padding policy)
    dx, dw = jax.eval_shape(
        jax.grad(lambda x_, w_: lm_head_ce(x_, w_, labels,
                                           vocab=128256).sum(),
                 argnums=(0, 1)), x, w)
    assert dx.shape == (R, d) and dw.shape == (d, V)


def test_degradation_guard_still_catches_pathological_shapes():
    # a PRIME vocab dim collapses block_v to 1: the grid explodes far past
    # what the requested blocks would give — the blowup-ratio guard fires
    R, d, V = 25600, 16, 997
    x = jax.ShapeDtypeStruct((R, d), jnp.float32)
    w = jax.ShapeDtypeStruct((d, V), jnp.float32)
    labels = jax.ShapeDtypeStruct((R, 1), jnp.int32)
    with pytest.raises(ValueError, match="degraded"):
        jax.eval_shape(lambda x_, w_, l_: lm_head_ce(x_, w_, l_),
                       x, w, labels)


# ---------------------------------------------------------------------------
# kernel vs oracle: decode path (logits + row max + first-occurrence argmax)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_logits_m_arg_match_ref(backend):
    x, w, _ = _mk(4, R=8, d=16, V=96, vocab=70)
    lref, mref, aref = lm_head_logits_ref(x, w, vocab=70)
    lg, m, arg = lm_head_logits.raw(x, w, vocab=70, block_r=4, block_v=16,
                                    block_k=8, backend=backend)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mref),
                               rtol=3e-4, atol=3e-4)
    assert (np.asarray(arg) == np.asarray(aref)).all()
    # public call returns JUST the masked logits (drop-in for the einsum)
    pub = lm_head_logits(x, w, vocab=70, block_r=4, block_v=16, block_k=8,
                         backend=backend)
    np.testing.assert_allclose(np.asarray(pub), np.asarray(lg))


@pytest.mark.parametrize("backend", BACKENDS)
def test_argmax_first_occurrence_across_blocks(backend):
    # duplicate-max columns in DIFFERENT vocab blocks: jnp.argmax picks the
    # first occurrence; the kernel's running argmax must too (a strictly-
    # greater block max displaces it, an equal one does not)
    x, w, _ = _mk(5, R=4, d=8, V=64)
    w = w.at[:, 41].set(w[:, 9])             # blocks 0 and 2 at block_v=16
    big = jnp.asarray(np.full((8,), 3.0), jnp.float32)
    w = w.at[:, 9].set(big).at[:, 41].set(big)
    x = jnp.abs(x)                           # make column 9/41 the max
    _, mref, aref = lm_head_logits_ref(x, w)
    assert (np.asarray(aref) == 9).all()
    _, m, arg = lm_head_logits.raw(x, w, block_r=4, block_v=16, block_k=8,
                                   backend=backend)
    assert (np.asarray(arg) == 9).all()
    np.testing.assert_allclose(np.asarray(m), np.asarray(mref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model level: fused CE / fused decode vs the unfused paths
# ---------------------------------------------------------------------------

def _small_cfg(vocab_offset=37):
    cfg = reduced(get_config("llama3_2_1b"))
    # force vpad > vocab_size so the padding rows are live in every test
    return dataclasses.replace(cfg, vocab_size=cfg.vocab_size - vocab_offset)


def _batch(cfg, seed=0, b=2, s=16):
    rng = np.random.RandomState(seed)
    return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)))}


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_loss_matches_default_loss(backend):
    cfg = _small_cfg()
    m0 = LM(cfg, fused_head=False)
    m1 = LM(cfg, fused_head=True, head_backend=backend)
    params = m0.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l0, met0 = m0.loss(params, batch)
    l1, met1 = m1.loss(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    np.testing.assert_allclose(float(met0["ce"]), float(met1["ce"]),
                               rtol=1e-5)


def test_fused_loss_grads_match_default():
    cfg = _small_cfg()
    params = LM(cfg).init(jax.random.PRNGKey(1))
    batch = _batch(cfg, seed=1)
    g0 = jax.grad(lambda p: LM(cfg, fused_head=False).loss(p, batch)[0])(params)
    g1 = jax.grad(lambda p: LM(cfg, fused_head=True).loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_greedy_step_argmax_matches_greedy_token_exactly(backend):
    cfg = _small_cfg()
    model = LM(cfg, fused_head=True, head_backend=backend)
    baseline = LM(cfg, fused_head=False)
    params = model.init(jax.random.PRNGKey(2))
    tokens = _batch(cfg, seed=2)["tokens"]
    _, cache = model.prefill(params, tokens[:, :8], max_len=16)
    _, cache_b = baseline.prefill(params, tokens[:, :8], max_len=16)
    for t in range(8, 12):
        tok, logits, cache = model.greedy_step(params, tokens[:, t:t + 1],
                                               cache)
        # the fused argmax IS greedy_token of the fused logits — exactly
        assert (np.asarray(tok) ==
                np.asarray(model.greedy_token(logits))).all(), t
        # and the fused logits agree with the unfused head within fp tolerance
        ref_logits, cache_b = baseline.decode_step(params,
                                                   tokens[:, t:t + 1], cache_b)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                                   rtol=2e-3, atol=2e-3)


def test_greedy_step_unfused_fallback():
    cfg = _small_cfg()
    model = LM(cfg, fused_head=False)
    params = model.init(jax.random.PRNGKey(3))
    tokens = _batch(cfg, seed=3)["tokens"]
    _, cache = model.prefill(params, tokens[:, :8], max_len=16)
    tok, logits, cache = model.greedy_step(params, tokens[:, 8:9], cache)
    ref_logits, _ = LM(cfg, fused_head=False).decode_step(
        params, tokens[:, 8:9], jax.tree.map(lambda a: a, cache))
    assert (np.asarray(tok) == np.asarray(model.greedy_token(logits))).all()


def test_prefill_last_logits_match_unfused():
    cfg = _small_cfg()
    params = LM(cfg).init(jax.random.PRNGKey(4))
    tokens = _batch(cfg, seed=4)["tokens"]
    l0, _ = LM(cfg, fused_head=False).prefill(params, tokens)
    l1, _ = LM(cfg, fused_head=True).prefill(params, tokens)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# bugfix: labels >= vocab_size raise host-side (both CE paths)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [False, True])
def test_out_of_range_labels_raise_host_side(fused):
    cfg = _small_cfg()
    model = LM(cfg, fused_head=fused)
    params = model.init(jax.random.PRNGKey(5))
    batch = _batch(cfg, seed=5)
    bad = {"tokens": batch["tokens"].at[0, 3].set(cfg.vocab_size)}
    with pytest.raises(ValueError, match="labels out of range"):
        model.loss(params, bad)
    neg = {"tokens": batch["tokens"].at[1, 2].set(-1)}
    with pytest.raises(ValueError, match="labels out of range"):
        model.loss(params, neg)
    # in-range labels still fine, including vocab_size - 1
    ok = {"tokens": batch["tokens"].at[0, 3].set(cfg.vocab_size - 1)}
    loss, _ = model.loss(params, ok)
    assert jnp.isfinite(loss)


def test_train_loop_host_batch_guard():
    # the jitted train step sees tracers, so LM.loss's guard cannot fire
    # there — launch.train validates each HOST batch before device_put
    from repro.launch.train import validate_host_batch

    ok = np.random.RandomState(0).randint(0, 100, (2, 8))
    validate_host_batch(ok, 100)
    with pytest.raises(ValueError, match="out of range"):
        validate_host_batch(np.array([[1, 100]]), 100)
    with pytest.raises(ValueError, match="out of range"):
        validate_host_batch(np.array([[-1, 5]]), 100)


def test_label_guard_skipped_under_trace():
    # jitted steps see tracers: the guard must not break tracing (the data
    # pipeline / an eager first step owns validation there)
    cfg = _small_cfg()
    model = LM(cfg, fused_head=True)
    params = model.init(jax.random.PRNGKey(6))
    batch = _batch(cfg, seed=6)
    loss = jax.jit(lambda p, b: model.loss(p, b)[0])(params, batch)
    assert jnp.isfinite(loss)


# ---------------------------------------------------------------------------
# Tile(reduce=...) validation gaps flushed out by the multi-granularity op
# ---------------------------------------------------------------------------

def test_input_tile_rejects_output_only_declarations():
    def bad_stream(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("bad_in_stream", grid=(2,),
                    inputs=[Tile("x", (8,), jnp.float32, block=(4,),
                                 stream=True)],
                    outputs=[Tile("y", (8,), jnp.float32, block=(4,))],
                    body=body)

    with pytest.raises(ValueError, match="output-only"):
        Device("jnp").build_kernel(bad_stream, {})

    def bad_reduce(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("bad_in_reduce", grid=(2, 2), reduce_axes=(1,),
                    inputs=[Tile("x", (8,), jnp.float32, block=(4,),
                                 index=lambda i, r: (i,), reduce=(1,))],
                    outputs=[Tile("y", (8,), jnp.float32, block=(4,),
                                  index=lambda i, r: (i,))],
                    body=body)

    with pytest.raises(ValueError, match="output-only"):
        Device("jnp").build_kernel(bad_reduce, {})


def test_duplicate_reduce_axes_rejected():
    def bad(D):
        def body(ctx, x, y):
            y[...] = x[...]

        return Spec("dup_reduce", grid=(2, 2), reduce_axes=(1,),
                    inputs=[Tile("x", (8,), jnp.float32, block=(4,),
                                 index=lambda i, r: (i,))],
                    outputs=[Tile("y", (8,), jnp.float32, block=(4,),
                                  index=lambda i, r: (i,), reduce=(1, 1))],
                    body=body)

    with pytest.raises(ValueError, match="duplicate axes"):
        Device("jnp").build_kernel(bad, {})


def test_three_granularities_in_one_grid_all_backends():
    """A miniature of the lm_head shape: one grid (n, nv, nk) with outputs at
    reduce=(2,) (per-slot accumulation), reduce=(1, 2) (full row state) and
    the bwd pairing's transposed granularity — all agreeing with numpy."""
    def builder(D):
        def body(ctx, x, blk_sum, total):
            acc, = ctx.scratch

            @ctx.when(ctx.is_first)
            def _init_total():
                acc[...] = jnp.zeros(acc.shape, jnp.float32)

            @ctx.when(ctx.reduce_first(1))
            def _init_blk():
                blk_sum[...] = jnp.zeros(blk_sum.shape, jnp.float32)

            blk_sum[...] = blk_sum[...] + x[...].sum(-1, keepdims=True)
            acc[...] += x[...].sum(-1, keepdims=True)

            @ctx.when(ctx.is_last)
            def _fin():
                total[...] = acc[...]

        n, nv, nk, b = D.n, D.nv, D.nk, D.b
        return Spec(
            "three_gran", grid=(n, nv, nk), reduce_axes=(1, 2),
            scratch=[Scratch((b, 1), jnp.float32)],
            inputs=[Tile("x", (n * b, nv * nk), jnp.float32, block=(b, 1),
                         index=lambda i, v, k: (i, v * D.nk + k))],
            outputs=[
                Tile("blk_sum", (n * b, nv), jnp.float32, block=(b, 1),
                     index=lambda i, v, k: (i, v), reduce=(2,)),
                Tile("total", (n * b, 1), jnp.float32, block=(b, 1),
                     index=lambda i, v, k: (i, 0), reduce=(1, 2)),
            ],
            body=body)

    n, nv, nk, b = 2, 3, 2, 4
    x = np.random.RandomState(7).randn(n * b, nv * nk).astype(np.float32)
    want_blk = x.reshape(n * b, nv, nk).sum(-1)
    want_total = x.sum(-1, keepdims=True)
    for be in BACKENDS:
        blk, total = Device(be).build_kernel(
            builder, dict(n=n, nv=nv, nk=nk, b=b)).run(x)
        np.testing.assert_allclose(np.asarray(blk), want_blk,
                                   rtol=1e-5, atol=1e-5, err_msg=be)
        np.testing.assert_allclose(np.asarray(total), want_total,
                                   rtol=1e-5, atol=1e-5, err_msg=be)


# ---------------------------------------------------------------------------
# tune-winner adoption for the TRAIN shapes (ROADMAP item: train warmup)
# ---------------------------------------------------------------------------

def test_train_warmup_adopts_persisted_lm_head_winner(tmp_path, monkeypatch):
    from repro.launch.train import apply_tuned_winners
    from repro.launch.tuning import train_probes

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    cfg = dataclasses.replace(reduced(get_config("llama3_2_1b")), d_model=256)
    B, S = 2, 65                             # rows = 2 * 64 = 128
    op = registered_ops()["lm_head_ce"]
    monkeypatch.setattr(op, "sweep", {"block_r": [128], "block_v": [256, 512],
                                      "block_k": [256]})
    defaults_before = dict(op.defaults)
    try:
        structs, params = train_probes(cfg, B, S)["lm_head_ce"]
        rng = np.random.RandomState(0)
        args = tuple(
            jnp.asarray(rng.randint(0, cfg.vocab_size, s.shape), jnp.int32)
            if jnp.dtype(s.dtype) == jnp.int32 else
            jnp.asarray(rng.standard_normal(s.shape), s.dtype)
            for s in structs)
        r = op.tune(args, repeats=1, **params)
        assert not r.cached and r.trials
        applied = apply_tuned_winners(cfg, B, S)
        assert "lm_head_ce" in applied
        assert op.defaults["block_v"] == applied["lm_head_ce"]["block_v"]
        # second adoption is a pure cache hit and idempotent
        assert apply_tuned_winners(cfg, B, S)["lm_head_ce"] == \
            applied["lm_head_ce"]
    finally:
        op.defaults.clear()
        op.defaults.update(defaults_before)


def test_tune_cli_list_and_arch_mode(tmp_path, monkeypatch, capsys):
    from repro import tune_cli

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    assert tune_cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "lm_head_ce" in out and "block_v" in out
    # fleet pre-tune at real (reduced) train shapes: rows = 2*64 = 128,
    # d_model = 128, vpad = 512 — trim the sweep so the test stays fast
    op = registered_ops()["lm_head_ce"]
    monkeypatch.setattr(op, "sweep", {"block_r": [128], "block_v": [256, 512],
                                      "block_k": [128]})
    assert tune_cli.main(["--arch", "llama3_2_1b", "--reduced", "--train",
                          "--batch", "2", "--seq-len", "65",
                          "--repeats", "1"]) == 0
    out = capsys.readouterr().out
    assert "lm_head_ce: winner" in out
    assert list((tmp_path / "autotune").glob("*.json"))
