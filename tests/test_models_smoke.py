"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward + one SGD train step, asserting output
shapes and finiteness. For one representative arch per family: teacher-forced
prefill+decode must match the full forward logits (the serving-correctness
invariant).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import LM

B, S = 2, 16


def make_batch(cfg, rng, s=S):
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, s)))}
    if cfg.frontend:
        batch["prefix_embeddings"] = jnp.asarray(
            rng.randn(B, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, np.random.RandomState(0))

    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    # logits shape check
    logits, _ = model.forward(params, batch["tokens"],
                              batch.get("prefix_embeddings"))
    p = cfg.num_prefix_embeddings if cfg.frontend else 0
    assert logits.shape == (B, p + S, model.vpad)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    # one SGD step must keep things finite and reduce nothing to NaN
    new_params = jax.tree.map(lambda p_, g: p_ - 0.01 * g.astype(p_.dtype),
                              params, grads)
    loss2, _ = model.loss(new_params, batch)
    assert jnp.isfinite(loss2), arch
    # gradients flow everywhere (no dead subtree)
    gnorms = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert max(gnorms) > 0


@pytest.mark.parametrize("arch,overrides", [
    ("llama3_2_1b", {}),                      # GQA + tied embeddings
    # MoE archs need drop-free capacity: forward-vs-decode equivalence only
    # holds when no token is dropped (capacity depends on the token SET).
    ("mixtral_8x22b", {"window": 8, "capacity_factor": 8.0}),  # SWA ring cache
    ("deepseek_v2_lite", {"capacity_factor": 8.0}),            # MLA absorbed
    ("falcon_mamba_7b", {}),                  # mamba1 state carry
    ("zamba2_7b", {}),                        # hybrid: ssd + shared attn caches
    ("musicgen_medium", {}),                  # MHA + sinusoidal positions
    ("paligemma_3b", {}),                     # MQA + prefix-LM + frontend stub
])
def test_prefill_decode_matches_forward(arch, overrides):
    cfg = dataclasses.replace(reduced(get_config(arch)), **overrides)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(2)
    batch = make_batch(cfg, rng)
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeddings")
    p = prefix.shape[1] if prefix is not None else 0

    full_logits, _ = model.forward(params, tokens, prefix)   # (B, P+S, V)

    t0 = S // 2
    _, cache = model.prefill(params, tokens[:, :t0], prefix_embeddings=prefix,
                             max_len=p + S)
    for t in range(t0, S):
        step_logits, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        want = full_logits[:, p + t]
        got = np.asarray(step_logits, np.float32)
        np.testing.assert_allclose(
            got[..., :cfg.vocab_size],
            np.asarray(want, np.float32)[..., :cfg.vocab_size],
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode step {t} diverged from forward")


def test_prefill_last_logits_match_forward():
    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(3))
    tokens = jnp.asarray(np.random.RandomState(4).randint(0, cfg.vocab_size, (B, S)))
    full_logits, _ = model.forward(params, tokens)
    last, _ = model.prefill(params, tokens)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full_logits[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_moe_dispatch_modes_agree():
    """einsum (GShard baseline) and gather (optimized) dispatch must match."""
    cfg = reduced(get_config("mixtral_8x22b"))
    m1 = LM(cfg, moe_dispatch="einsum")
    m2 = LM(cfg, moe_dispatch="gather")
    params = m1.init(jax.random.PRNGKey(5))
    tokens = jnp.asarray(np.random.RandomState(6).randint(0, cfg.vocab_size, (B, S)))
    l1, _ = m1.forward(params, tokens)
    l2, _ = m2.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)


def test_remat_does_not_change_loss():
    cfg = reduced(get_config("internlm2_1_8b"))
    params = LM(cfg).init(jax.random.PRNGKey(7))
    batch = make_batch(cfg, np.random.RandomState(8))
    l0, _ = LM(cfg, remat="none").loss(params, batch)
    l1, _ = LM(cfg, remat="full").loss(params, batch)
    l2, _ = LM(cfg, remat="dots").loss(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    np.testing.assert_allclose(float(l0), float(l2), rtol=1e-5)


def test_ssd_chunked_matches_sequential_ref():
    from repro.layers.mamba import ssd_chunked, ssd_ref
    rng = np.random.RandomState(9)
    b, L, h, p, n = 2, 64, 4, 8, 16
    x = jnp.asarray(rng.randn(b, L, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(b, L, h)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(h)) + 0.2, jnp.float32)
    Bm = jnp.asarray(rng.randn(b, L, n), jnp.float32)
    Cm = jnp.asarray(rng.randn(b, L, n), jnp.float32)
    y_ref = ssd_ref(x, dt, A, Bm, Cm)
    y_chk, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rope properties (hypothesis)
# ---------------------------------------------------------------------------

from _hypothesis_compat import given, settings, strategies as st


@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([16, 32, 64]), shift=st.integers(0, 50),
       seed=st.integers(0, 999))
def test_rope_is_relative_and_isometric(d, shift, seed):
    """Rotations preserve norms, and q.k depends only on relative position."""
    from repro.layers.rope import apply_rope
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(1, 1, 4, d), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 4, d), jnp.float32)
    pos = jnp.arange(4)
    qr, kr = apply_rope(q, pos, 10000.0), apply_rope(k, pos, 10000.0)
    # isometry
    np.testing.assert_allclose(np.linalg.norm(np.asarray(qr), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=1e-5, atol=1e-5)
    # relative position: shifting both q and k leaves scores unchanged
    qs, ks = apply_rope(q, pos + shift, 10000.0), apply_rope(k, pos + shift, 10000.0)
    s1 = np.einsum("bhqd,bhkd->bhqk", np.asarray(qr), np.asarray(kr))
    s2 = np.einsum("bhqd,bhkd->bhqk", np.asarray(qs), np.asarray(ks))
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), k=st.sampled_from([1, 2, 4]))
def test_moe_router_gates_normalized(seed, k):
    from repro.layers.moe import _router, moe_init
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("mixtral_8x22b")),
                              n_experts_per_tok=k)
    params = moe_init(jax.random.PRNGKey(seed % 7), cfg, jnp.float32)
    x = jnp.asarray(np.random.RandomState(seed).randn(2, 8, cfg.d_model),
                    jnp.float32)
    gate, idx, aux = _router(params, x, cfg)
    np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < cfg.n_experts and int(idx.min()) >= 0
    assert float(aux["moe_lb_loss"]) >= 0.99  # >= 1 at uniform routing limit


def test_sequence_chunked_ce_exact_parity():
    """ce_chunks: loss and gradients must match the unchunked path exactly."""
    cfg = reduced(get_config("llama3_2_1b"))
    params = LM(cfg).init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 16)))}
    l1, _ = LM(cfg, ce_chunks=1).loss(params, batch)
    l4, _ = LM(cfg, ce_chunks=4).loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    g1 = jax.grad(lambda p: LM(cfg, ce_chunks=1).loss(p, batch)[0])(params)
    g4 = jax.grad(lambda p: LM(cfg, ce_chunks=4).loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)
