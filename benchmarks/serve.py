"""Serving-engine rows: continuous batching vs static batching, measured.

``serve/engine_mixed`` drains a mixed-length request stream (varying prompt
lengths AND generation budgets) through :class:`repro.serving.Engine` —
paged KV pool, per-slot positions, EOS/max_new retirement with mid-flight
slot refill. ``serve/static_batch`` pushes the SAME traffic through the
classic static batch: every wave padded to the longest prompt and decoded in
lockstep until the longest generation budget is spent, so short requests pay
for long ones. Both rows report wall time per USEFUL generated token; the
ratio is the continuous-batching win the README table quotes.

Both paths are warmed (one full untimed pass) so the rows time steady-state
serving, not jit compilation.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from .common import Row

__all__ = ["run"]


def _traffic(rng, n_req: int, vocab: int, smoke: bool):
    """Mixed prompt/generation lengths — the shape continuous batching is
    for. Deterministic given ``rng``."""
    plens = ([5, 9, 3, 7] if smoke else [5, 21, 9, 3, 17, 7, 24, 12])[:n_req]
    mnew = ([6, 4, 8, 5] if smoke else [6, 12, 4, 16, 8, 5, 10, 7])[:n_req]
    return [(rng.randint(1, vocab, (p,)).tolist(), m)
            for p, m in zip(plens, mnew)]


def run(rows, smoke: bool = False):
    from repro.configs import get_config, reduced
    from repro.models import LM
    from repro.serving import Engine

    cfg = reduced(get_config("llama3_2_1b"))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    batch = 2 if smoke else 4
    n_req = 4 if smoke else 8
    max_len = 32 if smoke else 64
    page = 8 if smoke else 16
    traffic = _traffic(rng, n_req, cfg.vocab_size, smoke)
    useful = sum(m for _, m in traffic)

    # --- continuous batching: Engine over the paged pool ------------------
    eng = Engine(model, params, batch=batch, max_len=max_len, page_size=page)

    def drain_once():
        rids = [eng.submit(p, m) for p, m in traffic]
        t0 = time.perf_counter()
        res = eng.drain()
        dt = time.perf_counter() - t0
        return sum(len(res[r]) for r in rids), dt

    drain_once()                               # warm: compiles prefill+step
    n_eng, dt_eng = drain_once()
    rows.append(Row("serve/engine_mixed", dt_eng / max(n_eng, 1),
                    f"tok_s={n_eng / dt_eng:.0f} reqs={n_req} slots={batch} "
                    f"page={eng.page_size} preempt="
                    f"{sum(r.preempted for r in eng._requests.values())}"))

    # --- static batching: padded lockstep waves over the SAME traffic -----
    pmax = max(len(p) for p, _ in traffic)
    steps = max(m for _, m in traffic)
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    step = jax.jit(lambda p, t, c: model.greedy_step(p, t, c),
                   donate_argnums=(2,))

    def static_pass():
        t0 = time.perf_counter()
        for w in range(0, n_req, batch):
            wave = traffic[w:w + batch]
            toks = np.zeros((batch, pmax), np.int32)
            for i, (p, _) in enumerate(wave):
                toks[i, :len(p)] = p           # right-pad: lockstep cost model
            logits, cache = prefill(params, jax.numpy.asarray(toks))
            tok = model.greedy_token(logits)
            for _ in range(steps):             # no early retirement
                tok, logits, cache = step(params, tok[:, None], cache)
            jax.block_until_ready(tok)
        return time.perf_counter() - t0

    static_pass()                              # warm
    dt_sta = static_pass()
    rows.append(Row("serve/static_batch", dt_sta / max(useful, 1),
                    f"tok_s={useful / dt_sta:.0f} reqs={n_req} slots={batch} "
                    f"lockstep_steps={steps} "
                    f"engine_speedup={dt_sta / max(dt_eng, 1e-9):.2f}x"))
    return rows
