"""LM kernel micro-benchmarks (CPU): oracle paths + interpret-mode kernels.

The compiled Pallas kernels target TPU; on CPU we time the jnp oracle and
the chunked variant (the XLA realization of the flash schedule), plus the
ssm chunked-vs-associative scans. Interpret-mode timings are correctness
artifacts, not performance (reported at tiny sizes for completeness).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import decode_attention, flash_attention
from repro.kernels.flash_attention.ref import (decode_ref, mha_chunked,
                                               mha_ref, rolling_slot_pos)
from repro.kernels.ssm_scan.ref import selective_scan_assoc
from repro.layers.mamba import ssd_chunked
from .common import Row, SMOKE_TIME, time_fn


def run(rows: list, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    rng = np.random.RandomState(0)
    b, h, s, d = (1, 2, 128, 32) if smoke else (1, 8, 2048, 64)
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    flops = 4 * b * h * s * s * d

    f_ref = jax.jit(lambda q, k, v: mha_ref(q, k, v, causal=True))
    sec = time_fn(f_ref, q, k, v, **tkw)
    rows.append(Row(f"attn/ref/s{s}", sec, f"{flops / sec / 1e9:.1f} GFLOP/s"))

    f_chk = jax.jit(lambda q, k, v: mha_chunked(q, k, v, causal=True,
                                                block_q=256))
    sec = time_fn(f_chk, q, k, v, **tkw)
    rows.append(Row(f"attn/chunked/s{s}", sec,
                    f"{flops / sec / 1e9:.1f} GFLOP/s"))

    # flash BACKWARD (unified language, fused dq/dk/dv; jnp expansion is the
    # meaningful CPU row) vs grad through the oracle
    bq = 64 if smoke else 128
    bwd_flops = int(2.5 * flops)  # fwd recompute + dq/dk/dv matmuls

    def _loss(fn, **kw):
        return jax.jit(jax.grad(
            lambda q_, k_, v_: (fn(q_, k_, v_, causal=True, **kw) ** 2).sum(),
            argnums=(0, 1, 2)))

    sec = time_fn(_loss(mha_ref), q, k, v, **tkw)
    rows.append(Row(f"attn/bwd_ref/s{s}", sec,
                    f"{bwd_flops / sec / 1e9:.1f} GFLOP/s"))
    sec = time_fn(_loss(flash_attention, block_q=bq, block_kv=bq,
                        backend="jnp"), q, k, v, **tkw)
    rows.append(Row(f"attn/flash_bwd/s{s}", sec,
                    f"{bwd_flops / sec / 1e9:.1f} GFLOP/s"))

    # single-token decode against a full cache: oracle vs the flash_decode op
    q1 = q[:, :, :1]
    dec_flops = 4 * b * h * s * d
    bkv = min(64 if smoke else 512, s)
    sec = time_fn(jax.jit(lambda q_, k_, v_: decode_ref(q_, k_, v_)),
                  q1, k, v, **tkw)
    rows.append(Row(f"attn/decode_ref/s{s}", sec,
                    f"{dec_flops / sec / 1e9:.1f} GFLOP/s"))
    sec = time_fn(jax.jit(lambda q_, k_, v_: decode_attention(
        q_, k_, v_, block_kv=bkv, backend="jnp")), q1, k, v, **tkw)
    rows.append(Row(f"attn/flash_decode/s{s}", sec,
                    f"{dec_flops / sec / 1e9:.1f} GFLOP/s"))

    # rolling-window decode against a ROTATED cache (slot = pos % W), decoded
    # past the wrap: the masked grouped einsum (the old fallback path) vs the
    # unified kernel with the slot_pos input tile
    W = 64 if smoke else 1024
    t = W + W // 2                           # wrapped: every slot live
    sp = jnp.asarray(rolling_slot_pos(W, t))
    kw_, vw_ = k[:, :, :W], v[:, :, :W]
    wflops = 4 * b * h * W * d
    sec = time_fn(jax.jit(lambda q_, k_, v_: decode_ref(
        q_, k_, v_, window=W, kv_len=t, slot_pos=sp)), q1, kw_, vw_, **tkw)
    rows.append(Row(f"attn/wdecode_einsum/w{W}", sec,
                    f"{wflops / sec / 1e9:.1f} GFLOP/s"))
    wbkv = min(bkv, W)
    sec = time_fn(jax.jit(lambda q_, k_, v_: decode_attention(
        q_, k_, v_, window=W, kv_len=t, slot_pos=sp, block_kv=wbkv,
        backend="jnp")), q1, kw_, vw_, **tkw)
    rows.append(Row(f"attn/wdecode_flash/w{W}", sec,
                    f"{wflops / sec / 1e9:.1f} GFLOP/s"))

    # ssm scans
    bt, L, dm, n = (1, 128, 64, 8) if smoke else (1, 2048, 512, 16)
    x = jnp.asarray(rng.randn(bt, L, dm), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(bt, L, dm)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(dm, n)) + 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(bt, L, n), jnp.float32)
    C = jnp.asarray(rng.randn(bt, L, n), jnp.float32)
    D = jnp.asarray(rng.randn(dm), jnp.float32)
    f_assoc = jax.jit(lambda *a: selective_scan_assoc(*a)[0])
    sec = time_fn(f_assoc, x, dt, A, B, C, D, **tkw)
    el = bt * L * dm * n
    rows.append(Row(f"ssm/assoc/L{L}", sec, f"{el / sec / 1e6:.1f} Mcell/s"))

    # mamba2 SSD chunked
    hh, p = (2, 16) if smoke else (8, 64)
    chunk = min(128, L)
    xh = jnp.asarray(rng.randn(bt, L, hh, p), jnp.float32)
    dth = jnp.asarray(np.abs(rng.randn(bt, L, hh)) * 0.1, jnp.float32)
    Ah = -jnp.asarray(np.abs(rng.randn(hh)) + 0.2, jnp.float32)
    f_ssd = jax.jit(lambda *a: ssd_chunked(*a, chunk=chunk)[0])
    sec = time_fn(f_ssd, xh, dth, Ah, B, C, **tkw)
    rows.append(Row(f"ssm/ssd_chunked/L{L}", sec,
                    f"{bt * L * hh * p * n / sec / 1e6:.1f} Mcell/s"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run([]))
