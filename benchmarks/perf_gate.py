"""CI perf gate: the unified kernel language must WIN the paper's benchmarks.

Reads a ``bench_smoke.json`` artifact and, for each app workload (fd2d, sem,
dg volume, dg surface), compares the BEST unified-backend time against the
hand-written native jnp baseline at the same shape. The build fails when any
workload's best unified expansion is more than ``--max-ratio`` (default 1.5x)
slower than native — the paper's "portability without a performance tax"
claim, enforced per commit. All ratios are printed either way.

    python -m benchmarks.perf_gate artifacts/bench_smoke.json
"""

from __future__ import annotations

import argparse
import json
import re
import sys

#: workload -> (row prefix, native backend label). A row is
#: ``<prefix><backend>/<shape...>``; shapes must match exactly across
#: backends for a comparison to count.
APPS = {
    "fd2d": "fd2d/",
    "sem": "sem/",
    "dg": "dg/",
    "dg_surface": "dg/surface/",
}
UNIFIED = ("jnp", "loops", "pallas")


def _split(name: str, prefix: str) -> tuple[str, str] | None:
    """``<prefix><backend>/<shape>`` -> (backend, shape), else None."""
    if not name.startswith(prefix):
        return None
    rest = name[len(prefix):]
    backend, _, shape = rest.partition("/")
    # keep 'dg/' from swallowing 'dg/surface/...' rows
    if backend not in UNIFIED and backend != "native":
        return None
    return backend, shape


def _gate_ratio(derived: str) -> float | None:
    """Paired vs-native ratio the benchmark embedded in the row (see
    time_fn_paired): immune to the host frequency drift that moves the
    separately-timed absolute us 2x between runs."""
    m = re.search(r"gate_ratio=([0-9.]+)", derived)
    return float(m.group(1)) if m else None


def gate(rows: list[dict], max_ratio: float = 1.5) -> list[str]:
    """Returns failure messages (empty = gate passes); prints all ratios.

    The gated statistic per backend is the row-embedded paired ratio when
    present (smoke rows carry one), falling back to the quotient of the two
    rows' us otherwise (full-size runs, older artifacts)."""
    failures = []
    for app, prefix in APPS.items():
        # shape -> backend -> (us, paired ratio or None)
        times: dict[str, dict[str, tuple[float, float | None]]] = {}
        for r in rows:
            hit = _split(r["name"], prefix)
            if hit is None:
                continue
            backend, shape = hit
            times.setdefault(shape, {})[backend] = (
                float(r["us_per_call"]), _gate_ratio(r.get("derived", "")))
        compared = False
        for shape, per in sorted(times.items()):
            native = per.get("native")
            uni = {b: per[b] for b in UNIFIED if b in per}
            if native is None or not uni:
                continue
            compared = True
            ratios = {b: (pr if pr is not None else us / native[0])
                      for b, (us, pr) in uni.items()}
            best_b = min(ratios, key=ratios.get)
            ratio = ratios[best_b]
            verdict = "OK" if ratio <= max_ratio else "FAIL"
            print(f"[perf-gate] {app}/{shape}: best unified {best_b} "
                  f"{uni[best_b][0]:.1f}us vs native {native[0]:.1f}us "
                  f"-> {ratio:.2f}x [{verdict}]")
            for b in UNIFIED:
                if b in uni and b != best_b:
                    print(f"[perf-gate]   {b}: {ratios[b]:.2f}x")
            if ratio > max_ratio:
                failures.append(
                    f"{app}/{shape}: best unified backend ({best_b}) is "
                    f"{ratio:.2f}x native (limit {max_ratio}x)")
        if not compared:
            failures.append(
                f"{app}: no comparable native-vs-unified rows found "
                f"(prefix {prefix!r}) — benchmark drift?")
    return failures


#: backends the paged gate FAILS on (vs informational print-only). The
#: serving engine resolves backend="auto" to pallas — that is the path the
#: 1.3x requirement protects. jnp/loops ratios are printed for visibility:
#: whole-graph XLA may keep a fixed dynamic-gather cost at tiny smoke shapes
#: that the pipelined backends don't pay, and it is not the served path.
PAGED_GATED = ("pallas",)


def gate_paged(rows: list[dict], max_ratio: float = 1.3) -> list[str]:
    """Paged-decode gate: reading the KV cache through the block-table tile
    (the continuous-batching pool layout) must stay within ``max_ratio`` of
    the contiguous ``flash_decode`` row at the same smoke shape on the
    SERVED backend — the page-gather indirection is bookkeeping, not a tax.
    Both rows time the jitted call, paired (see benchmarks/unified.py);
    the gated statistic is the row-embedded paired ratio when present."""
    times = {r["name"]: float(r["us_per_call"]) for r in rows}
    ratios = {r["name"]: _gate_ratio(r.get("derived", "")) for r in rows}
    failures = []
    compared = False
    for b in UNIFIED:
        paged = times.get(f"unified/flash_decode_paged/{b}")
        contig = times.get(f"unified/flash_decode/{b}")
        if paged is None or contig is None:
            continue
        gated = b in PAGED_GATED
        if gated:
            compared = True
        pr = ratios.get(f"unified/flash_decode_paged/{b}")
        ratio = pr if pr is not None else paged / contig
        verdict = ("OK" if ratio <= max_ratio else "FAIL") if gated else "info"
        print(f"[perf-gate] paged-decode/{b}: {paged:.1f}us vs contiguous "
              f"{contig:.1f}us -> {ratio:.2f}x [{verdict}]")
        if gated and ratio > max_ratio:
            failures.append(
                f"paged-decode/{b}: block-table decode is {ratio:.2f}x the "
                f"contiguous cache (limit {max_ratio}x)")
    if not compared:
        failures.append(
            "paged-decode: no flash_decode_paged-vs-flash_decode rows found "
            f"for the served backend(s) {PAGED_GATED} — benchmark drift?")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("artifact", help="bench_smoke.json path")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail when best-unified/native exceeds this "
                         "(default 1.5)")
    ap.add_argument("--paged-max-ratio", type=float, default=1.3,
                    help="fail when paged decode exceeds this multiple of "
                         "contiguous decode on any backend (default 1.3)")
    args = ap.parse_args(argv)
    with open(args.artifact) as f:
        rows = json.load(f)
    failures = gate(rows, args.max_ratio)
    failures += gate_paged(rows, args.paged_max_ratio)
    if failures:
        print("[perf-gate] FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("[perf-gate] all workloads within "
          f"{args.max_ratio}x of native")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
