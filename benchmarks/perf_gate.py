"""CI perf gate: the unified kernel language must WIN the paper's benchmarks.

Reads a ``bench_smoke.json`` artifact and, for each app workload (fd2d, sem,
dg volume, dg surface), compares the BEST unified-backend time against the
hand-written native jnp baseline at the same shape. The build fails when any
workload's best unified expansion is more than ``--max-ratio`` (default 1.5x)
slower than native — the paper's "portability without a performance tax"
claim, enforced per commit. All ratios are printed either way.

    python -m benchmarks.perf_gate artifacts/bench_smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys

#: workload -> (row prefix, native backend label). A row is
#: ``<prefix><backend>/<shape...>``; shapes must match exactly across
#: backends for a comparison to count.
APPS = {
    "fd2d": "fd2d/",
    "sem": "sem/",
    "dg": "dg/",
    "dg_surface": "dg/surface/",
}
UNIFIED = ("jnp", "loops", "pallas")


def _split(name: str, prefix: str) -> tuple[str, str] | None:
    """``<prefix><backend>/<shape>`` -> (backend, shape), else None."""
    if not name.startswith(prefix):
        return None
    rest = name[len(prefix):]
    backend, _, shape = rest.partition("/")
    # keep 'dg/' from swallowing 'dg/surface/...' rows
    if backend not in UNIFIED and backend != "native":
        return None
    return backend, shape


def gate(rows: list[dict], max_ratio: float = 1.5) -> list[str]:
    """Returns failure messages (empty = gate passes); prints all ratios."""
    failures = []
    for app, prefix in APPS.items():
        # shape -> backend -> us
        times: dict[str, dict[str, float]] = {}
        for r in rows:
            hit = _split(r["name"], prefix)
            if hit is None:
                continue
            backend, shape = hit
            times.setdefault(shape, {})[backend] = float(r["us_per_call"])
        compared = False
        for shape, per in sorted(times.items()):
            native = per.get("native")
            uni = {b: per[b] for b in UNIFIED if b in per}
            if native is None or not uni:
                continue
            compared = True
            best_b = min(uni, key=uni.get)
            ratio = uni[best_b] / native
            verdict = "OK" if ratio <= max_ratio else "FAIL"
            print(f"[perf-gate] {app}/{shape}: best unified {best_b} "
                  f"{uni[best_b]:.1f}us vs native {native:.1f}us "
                  f"-> {ratio:.2f}x [{verdict}]")
            for b in UNIFIED:
                if b in uni and b != best_b:
                    print(f"[perf-gate]   {b}: {uni[b] / native:.2f}x")
            if ratio > max_ratio:
                failures.append(
                    f"{app}/{shape}: best unified backend ({best_b}) is "
                    f"{ratio:.2f}x native (limit {max_ratio}x)")
        if not compared:
            failures.append(
                f"{app}: no comparable native-vs-unified rows found "
                f"(prefix {prefix!r}) — benchmark drift?")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("artifact", help="bench_smoke.json path")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail when best-unified/native exceeds this "
                         "(default 1.5)")
    args = ap.parse_args(argv)
    with open(args.artifact) as f:
        rows = json.load(f)
    failures = gate(rows, args.max_ratio)
    if failures:
        print("[perf-gate] FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("[perf-gate] all workloads within "
          f"{args.max_ratio}x of native")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
