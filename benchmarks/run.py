"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. FD = paper Fig. 2; SEM = Figs. 3-4;
DG = Figs. 5-6; attention/ssm = LM kernel hot-spots; unified = matmul/rmsnorm
in the unified kernel language on all three backends; roofline rows summarize
the dry-run artifacts when present (full table via ``-m benchmarks.roofline``).
"""

from __future__ import annotations

from . import attention, dg, fd, sem, unified
from .common import Row, emit


def _roofline_rows(rows):
    from . import roofline
    recs = roofline.load("artifacts/dryrun")
    ok = [r for r in recs if not r.get("skipped") and "error" not in r]
    for r in ok:
        a = roofline.analyze(r)
        dom = a["dominant"]
        rows.append(Row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            a["terms"][dom],
            f"dominant={dom}; frac={a['roofline_fraction']:.2f}; "
            f"6ND/HLO={a['useful_ratio']:.2f}"))
    return rows


def main() -> None:
    rows = []
    fd.run(rows)
    sem.run(rows)
    dg.run(rows)
    attention.run(rows)
    unified.run(rows)
    try:
        _roofline_rows(rows)
    except Exception as e:  # artifacts may not exist yet
        rows.append(Row("roofline/unavailable", 0.0, str(e)[:60]))
    emit(rows)


if __name__ == "__main__":
    main()
