"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. FD = paper Fig. 2; SEM = Figs. 3-4;
DG = Figs. 5-6; attention/ssm = LM kernel hot-spots; unified = matmul/rmsnorm
in the unified kernel language on all three backends; serve = continuous-vs-
static batching throughput; roofline rows summarize
the dry-run artifacts when present (full table via ``-m benchmarks.roofline``).
"""

from __future__ import annotations

from . import attention, dg, fd, sem, serve, unified
from .common import Row, check_manifest, emit, write_json


def _cost_rows(rows):
    """One static-cost-model row per registered op (default derived config):
    us column is 0 (nothing is timed), derived carries the footprint/traffic
    summary the CI smoke manifest pins."""
    import numpy as np

    import repro.kernels  # noqa: F401 — registers the op families
    from repro.core import registered_ops
    from repro.lint_kernels import cost_op

    for name, op in sorted(registered_ops().items()):
        c = cost_op(op, np.random.RandomState(0))
        k = c["kernels"][0]
        fl = "?" if k["flops"] is None else str(k["flops"])
        comm = (f"comm={k['comm_bytes']}B; " if k.get("comm_bytes") else "")
        rows.append(Row(
            f"cost/{name}", 0.0,
            f"vmem={k['vmem_bytes']}B ({k['vmem_frac']:.0%} budget); "
            f"hbm={k['hbm_bytes']}B; flops={fl}; {comm}"
            f"pruned={len(c['sweep_pruned'])}/"
            f"{len(c['sweep_pruned']) + c['sweep_kept']}"))
    return rows


def _roofline_rows(rows):
    from . import roofline
    recs = roofline.load("artifacts/dryrun")
    ok = [r for r in recs if not r.get("skipped") and "error" not in r]
    for r in ok:
        a = roofline.analyze(r)
        dom = a["dominant"]
        rows.append(Row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            a["terms"][dom],
            f"dominant={dom}; frac={a['roofline_fraction']:.2f}; "
            f"6ND/HLO={a['useful_ratio']:.2f}"))
    return rows


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one rep per row: a fast CI canary that "
                         "every benchmark path still builds and runs "
                         "(timings are not meaningful)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write the rows as JSON (the CI "
                         "bench_smoke.json artifact)")
    ap.add_argument("--check-manifest", default=None, metavar="PATH",
                    help="fail (exit 1) unless every row-name prefix listed "
                         "in PATH matched at least one emitted row — "
                         "benchmark drift breaks CI instead of rotting")
    args = ap.parse_args(argv)

    rows = []
    fd.run(rows, smoke=args.smoke)
    sem.run(rows, smoke=args.smoke)
    dg.run(rows, smoke=args.smoke)
    attention.run(rows, smoke=args.smoke)
    unified.run(rows, smoke=args.smoke)
    serve.run(rows, smoke=args.smoke)
    try:
        _cost_rows(rows)
    except Exception as e:
        rows.append(Row("cost/unavailable", 0.0, str(e)[:60]))
    try:
        _roofline_rows(rows)
    except Exception as e:  # artifacts may not exist yet
        rows.append(Row("roofline/unavailable", 0.0, str(e)[:60]))
    emit(rows)
    if args.out:
        write_json(rows, args.out)
    if args.check_manifest:
        import sys

        missing = check_manifest(rows, args.check_manifest)
        if missing:
            print("benchmarks.run: expected rows MISSING from this run "
                  f"(manifest {args.check_manifest}):", file=sys.stderr)
            for m in missing:
                print(f"  {m}", file=sys.stderr)
            sys.exit(1)
        print(f"benchmarks.run: manifest OK ({args.check_manifest})",
              file=sys.stderr)


if __name__ == "__main__":
    main()
