"""Paper Figs. 3-4 analogue: SEM operator GFLOP/s + GB/s vs order N."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import sem
from .common import Row, SMOKE_INNER, SMOKE_TIME, time_fn, time_fn_paired

ORDERS = (1, 2, 3, 4, 5, 6, 7)


def run(rows: list, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    inner = SMOKE_INNER if smoke else 2
    for n in ((1, 2) if smoke else ORDERS):
        nq = n + 1
        E = max(512 // nq, 32)
        ex = 2 if smoke else max(2, round(E ** (1 / 3)))
        # native first: in smoke every unified backend is timed PAIRED
        # against this fn (time_fn_paired) and the perf gate reads the
        # paired-ratio from the row — absolute us at these ~15-25us shapes
        # swings 2x with host frequency between runs, the paired ratio
        # doesn't.
        nat = sem.SEMOperator(model="jnp", ex=ex, ey=ex, ez=ex, n=n,
                              deform=0.1)
        u = jnp.asarray(np.random.RandomState(0).randn(
            nat.E, nq, nq, nq), jnp.float32)
        nat_fn = jax.jit(lambda u_: sem.apply_ref(u_, nat.o_geo.data,
                                                  nat.o_dmat.data))
        sec = time_fn(nat_fn, u, inner=inner, **tkw)
        _row(rows, "native", n, nat, sec)
        for backend in ("jnp", "loops", "pallas"):
            if backend == "loops" and n > 4:
                continue  # serial expansion too slow at high order on CPU
            if backend == "pallas" and not smoke and n > 3:
                continue  # interpret-mode overhead at high order on CPU
            op = sem.SEMOperator(model=backend, ex=ex, ey=ex, ez=ex, n=n,
                                 deform=0.1)
            extra = ""
            if smoke:
                _, sec, ratio = time_fn_paired(
                    nat_fn, (u,), lambda: op.apply_local(u), (),
                    inner=inner, **tkw)
                extra = f"; gate_ratio={ratio:.3f}"
            else:
                sec = time_fn(lambda: op.apply_local(u), inner=inner, **tkw)
            _row(rows, backend, n, op, sec, extra)
    return rows


def _row(rows, backend, n, op, sec, extra=""):
    nq = n + 1
    gflops = op.E * sem.sem_flops_per_element(nq) / sec / 1e9
    gbs = op.E * sem.sem_bytes_per_element(nq, 4) / sec / 1e9
    rows.append(Row(f"sem/{backend}/N{n}/E{op.E}", sec,
                    f"{gflops:.2f} GFLOP/s; {gbs:.2f} GB/s{extra}"))


if __name__ == "__main__":
    from .common import emit
    emit(run([]))
