"""Roofline analysis from dry-run artifacts (TPU v5e terms).

  compute    = HLO_FLOPs_per_chip / peak_FLOPs      (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw          (819 GB/s)
  collective = collective_bytes_per_chip / link_bw  (~50 GB/s/link ICI)

All three use the PER-PARTITION program (the dry-run compiles the SPMD
module for one device), so terms are per-chip step times. MODEL_FLOPS uses
6*N_active*D (train), 2*N_active*D (prefill/decode forward-only).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
       [--markdown artifacts/roofline.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_ACTIVE_CACHE: dict = {}


def active_params(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts, analytic (cached)."""
    if arch in _ACTIVE_CACHE:
        return _ACTIVE_CACHE[arch]
    import jax

    from repro.configs import get_config
    from repro.models import LM
    model = LM(get_config(arch))
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(x.size for x in jax.tree.leaves(params))
    cfg = model.cfg
    active = total
    if cfg.n_experts:
        stack = params["stacks"][-1]
        expert = sum(stack["moe"][k].size for k in ("w_gate", "w_up", "w_down"))
        active = int(total - expert * (1 - cfg.n_experts_per_tok / cfg.n_experts))
    _ACTIVE_CACHE[arch] = (int(total), int(active))
    return _ACTIVE_CACHE[arch]


def model_flops(rec) -> float:
    """6*N_active*D (train) or 2*N_active*D (fwd-only), GLOBAL."""
    from repro.configs import SHAPES
    shape = SHAPES[rec["shape"]]
    _, act = active_params(rec["arch"])
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * act * tokens
    if rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * act * tokens
    return 2.0 * act * shape.global_batch  # decode: one token per sequence


def analyze(rec) -> dict:
    ex = rec["extrapolated"]
    chips = rec["chips"]
    t_c = ex["flops"] / PEAK_FLOPS
    t_m = ex["bytes_accessed"] / HBM_BW
    t_x = ex["collective_total_bytes"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec) / chips
    ratio = mf / ex["flops"] if ex["flops"] else 0.0
    # roofline fraction: useful work at peak vs the time the dominant term costs
    t_dom = terms[dominant]
    frac = (mf / PEAK_FLOPS) / t_dom if t_dom else 0.0
    note = _note(rec, dominant, ratio, terms)
    return {"terms": terms, "dominant": dominant, "model_flops_per_chip": mf,
            "useful_ratio": ratio, "roofline_fraction": frac, "note": note}


def _note(rec, dominant, ratio, terms) -> str:
    if dominant == "compute" and ratio < 0.5:
        if rec.get("moe_dispatch") == "einsum" and "mixtral" in rec["arch"] \
                or "deepseek" in rec["arch"]:
            return ("compute inflated by one-hot dispatch + remat recompute: "
                    "switch MoE dispatch to gather and relax remat")
        return ("compute inflated vs 6ND (remat recompute / masked-attn "
                "waste): relax remat policy, block-sparse causal attention")
    if dominant == "compute":
        return "near compute-bound: overlap collectives, tighten kernels"
    if dominant == "memory":
        return ("memory-bound: fuse elementwise chains, keep bf16 residuals, "
                "cut f32 temps (CPU cost model overstates fusion misses)")
    return ("collective-bound: cut all-reduce volume (reduce-scatter + "
            "all-gather), shard activations along seq, overlap with compute")


def load(dirpath: str, tag: str = "") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("tag", "") != tag or r.get("component"):
            continue
        recs.append(r)
    return recs


def load_components(dirpath: str, tag: str = "") -> dict:
    comps = {}
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if not r.get("component") or r.get("tag", "") != tag:
            continue
        comps.setdefault((r["arch"], r["shape"], r["mesh"]), []).append(r)
    return comps


def flash_adjust(rec: dict, comps) -> dict:
    """Substitute measured unfused kernel chains (attention softmax chain,
    chunked SSM scan) with the Pallas kernels' analytic traffic — the TPU
    deployment path flips ``kernel_backend`` to "pallas" (the OCCA run-time
    backend switch). The ref components cover fwd(+bwd) but not remat
    recompute, so the adjustment is conservative for train cells."""
    out = dict(rec)
    ex = dict(rec["extrapolated"])
    if isinstance(comps, dict):
        comps = [comps]
    for comp in comps:
        if comp.get("skipped"):
            continue
        L = comp["n_attention_layers"]
        ex["flops"] = max(ex["flops"] - L * comp["ref_flops"]
                          + L * comp["flash_flops_per_chip"], 1.0)
        ex["bytes_accessed"] = max(
            ex["bytes_accessed"] - L * comp["ref_bytes"]
            + L * comp["flash_bytes_per_chip"], 1.0)
        ex["collective_total_bytes"] = max(
            ex["collective_total_bytes"] - L * comp["ref_collective_bytes"],
            0.0)
    out["extrapolated"] = ex
    return out


def markdown_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | 6ND/HLO | roofline frac | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                         f"| — | — | — | — | {r['reason']} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERR "
                         f"| | | | | | {r['error'][:80]} |")
            continue
        a = analyze(r)
        t = a["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute']:.3e} | {t['memory']:.3e} | {t['collective']:.3e} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.2f} | {a['note']} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--markdown", default=None)
    ap.add_argument("--flash-adjust", action="store_true",
                    help="substitute the measured attention chain with the "
                         "Pallas flash kernel's analytic traffic")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.tag)
    if not recs:
        print(f"[roofline] no artifacts under {args.dir}")
        return 1
    if args.flash_adjust:
        comps = load_components(args.dir)
        recs = [flash_adjust(r, comps[(r["arch"], r["shape"], r["mesh"])])
                if (r["arch"], r["shape"], r["mesh"]) in comps
                and not r.get("skipped") and "error" not in r else r
                for r in recs]
    md = markdown_table(recs)
    print(md)
    if args.markdown:
        os.makedirs(os.path.dirname(args.markdown), exist_ok=True)
        with open(args.markdown, "w") as f:
            f.write(md + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
