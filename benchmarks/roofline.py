"""Roofline analysis from dry-run artifacts (TPU v5e terms).

  compute    = HLO_FLOPs_per_chip / peak_FLOPs      (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw          (819 GB/s)
  collective = collective_bytes_per_chip / link_bw  (~50 GB/s/link ICI)

All three use the PER-PARTITION program (the dry-run compiles the SPMD
module for one device), so terms are per-chip step times. MODEL_FLOPS uses
6*N_active*D (train), 2*N_active*D (prefill/decode forward-only).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
       [--markdown artifacts/roofline.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_ACTIVE_CACHE: dict = {}


def active_params(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts, analytic (cached)."""
    if arch in _ACTIVE_CACHE:
        return _ACTIVE_CACHE[arch]
    import jax

    from repro.configs import get_config
    from repro.models import LM
    model = LM(get_config(arch))
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(x.size for x in jax.tree.leaves(params))
    cfg = model.cfg
    active = total
    if cfg.n_experts:
        stack = params["stacks"][-1]
        expert = sum(stack["moe"][k].size for k in ("w_gate", "w_up", "w_down"))
        active = int(total - expert * (1 - cfg.n_experts_per_tok / cfg.n_experts))
    _ACTIVE_CACHE[arch] = (int(total), int(active))
    return _ACTIVE_CACHE[arch]


def model_flops(rec) -> float:
    """6*N_active*D (train) or 2*N_active*D (fwd-only), GLOBAL."""
    from repro.configs import SHAPES
    shape = SHAPES[rec["shape"]]
    _, act = active_params(rec["arch"])
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * act * tokens
    if rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * act * tokens
    return 2.0 * act * shape.global_batch  # decode: one token per sequence


def analyze(rec) -> dict:
    ex = rec["extrapolated"]
    chips = rec["chips"] or 1      # a zero-chip record must not divide-crash
    t_c = ex.get("flops", 0.0) / PEAK_FLOPS
    t_m = ex.get("bytes_accessed", 0.0) / HBM_BW
    t_x = ex.get("collective_total_bytes", 0.0) / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec) / chips
    ratio = mf / ex["flops"] if ex.get("flops") else 0.0
    # roofline fraction: useful work at peak vs the time the dominant term costs
    t_dom = terms[dominant]
    frac = (mf / PEAK_FLOPS) / t_dom if t_dom else 0.0
    note = _note(rec, dominant, ratio, terms)
    return {"terms": terms, "dominant": dominant, "model_flops_per_chip": mf,
            "useful_ratio": ratio, "roofline_fraction": frac, "note": note}


def _note(rec, dominant, ratio, terms) -> str:
    if dominant == "compute" and ratio < 0.5:
        if rec.get("moe_dispatch") == "einsum" and "mixtral" in rec["arch"] \
                or "deepseek" in rec["arch"]:
            return ("compute inflated by one-hot dispatch + remat recompute: "
                    "switch MoE dispatch to gather and relax remat")
        return ("compute inflated vs 6ND (remat recompute / masked-attn "
                "waste): relax remat policy, block-sparse causal attention")
    if dominant == "compute":
        return "near compute-bound: overlap collectives, tighten kernels"
    if dominant == "memory":
        return ("memory-bound: fuse elementwise chains, keep bf16 residuals, "
                "cut f32 temps (CPU cost model overstates fusion misses)")
    return ("collective-bound: cut all-reduce volume (reduce-scatter + "
            "all-gather), shard activations along seq, overlap with compute")


def _load_records(dirpath: str):
    """Every parseable dict record under ``dirpath`` — a missing dir yields
    nothing and corrupt/shapeless JSON files are skipped with a note instead
    of crashing the report (artifacts come from interrupted dry-runs too)."""
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        try:
            with open(fn) as f:
                r = json.load(f)
        except (OSError, ValueError) as e:
            print(f"[roofline] skipping unreadable {fn}: {e}")
            continue
        if not isinstance(r, dict):
            print(f"[roofline] skipping {fn}: not a JSON object")
            continue
        yield r


def load(dirpath: str, tag: str = "") -> list[dict]:
    return [r for r in _load_records(dirpath)
            if r.get("tag", "") == tag and not r.get("component")]


def load_components(dirpath: str, tag: str = "") -> dict:
    comps = {}
    for r in _load_records(dirpath):
        if not r.get("component") or r.get("tag", "") != tag:
            continue
        try:
            key = (r["arch"], r["shape"], r["mesh"])
        except KeyError:
            continue
        comps.setdefault(key, []).append(r)
    return comps


def flash_adjust(rec: dict, comps) -> dict:
    """Substitute measured unfused kernel chains (attention softmax chain,
    chunked SSM scan) with the Pallas kernels' analytic traffic — the TPU
    deployment path flips ``kernel_backend`` to "pallas" (the OCCA run-time
    backend switch). The ref components cover fwd(+bwd) but not remat
    recompute, so the adjustment is conservative for train cells."""
    out = dict(rec)
    ex = dict(rec["extrapolated"])
    if isinstance(comps, dict):
        comps = [comps]
    for comp in comps:
        if comp.get("skipped"):
            continue
        L = comp["n_attention_layers"]
        ex["flops"] = max(ex["flops"] - L * comp["ref_flops"]
                          + L * comp["flash_flops_per_chip"], 1.0)
        ex["bytes_accessed"] = max(
            ex["bytes_accessed"] - L * comp["ref_bytes"]
            + L * comp["flash_bytes_per_chip"], 1.0)
        ex["collective_total_bytes"] = max(
            ex["collective_total_bytes"] - L * comp["ref_collective_bytes"],
            0.0)
    out["extrapolated"] = ex
    return out


def static_attention_check(comp) -> str | None:
    """Cross-check the unified flash kernel's STATIC cost-model estimate
    (``repro.core.estimate_cost`` on the very spec the op would build at
    this cell's shapes) against the component dry-run's measured terms:
    ``static_flops / ref_flops`` and ``static_bytes / ref_bytes``, per chip.
    Ratios well under 1 are the headroom the kernel path should buy; None
    when the record is not a usable attention component."""
    if comp.get("component") != "attention" or comp.get("skipped") \
            or not comp.get("ref_flops") or not comp.get("ref_bytes"):
        return None
    try:
        import jax
        import jax.numpy as jnp
        from types import SimpleNamespace

        import repro.kernels  # noqa: F401 — registers the op families
        from repro.configs import SHAPES, get_config
        from repro.core import estimate_cost, registered_ops

        cfg = get_config(comp["arch"])
        shape = SHAPES[comp["shape"]]
        h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        if cfg.attn_type == "mla":
            hk, hd = h, cfg.qk_nope_dim + cfg.qk_rope_dim
        b, s = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        decode = shape.kind == "decode"
        skv = min(s, cfg.window) if cfg.window else s
        probe = jax.ShapeDtypeStruct
        if decode:
            op = registered_ops()["flash_decode"]
            args = (probe((b, h, 1, hd), dt), probe((b, hk, skv, hd), dt),
                    probe((b, hk, skv, hd), dt))
            params = dict(window=cfg.window)
        else:
            op = registered_ops()["flash_attention"]
            args = (probe((b, h, s, hd), dt), probe((b, hk, s, hd), dt),
                    probe((b, hk, s, hd), dt))
            params = dict(causal=True, window=cfg.window)
        _, _, params = op._resolve(params)
        _, defines, _ = op._prepare(args, params)
        rep = estimate_cost(op.builder(SimpleNamespace(**defines)),
                            SimpleNamespace(**defines))
        if rep.flops is None:
            return None
        # the dry-run's train chain measures fwd+bwd(+recompute); the static
        # spec is the forward — scale by the same factors dryrun uses
        f_flops, f_bytes = (3.5, 3.0) if shape.kind == "train" else (1.0, 1.0)
        chips = comp.get("chips") or 1
        fr = (rep.flops * f_flops / chips) / comp["ref_flops"]
        br = (rep.hbm_bytes * f_bytes / chips) / comp["ref_bytes"]
        return f"static/HLO fl {fr:.2f}x B {br:.2f}x"
    except Exception as e:
        return f"static check failed ({type(e).__name__})"


def markdown_table(recs, comps=None) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | 6ND/HLO | roofline frac | static check "
        "| bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        try:
            key = (r["arch"], r["shape"], r["mesh"])
        except KeyError:
            lines.append(f"| ? | ? | ? | — | — | — | — | — | — | — "
                         f"| malformed record (missing arch/shape/mesh) |")
            continue
        cell3 = f"| {r['arch']} | {r['shape']} | {r['mesh']}"
        if r.get("skipped"):
            lines.append(f"{cell3} | — | — | — | — | — | — | — "
                         f"| {r.get('reason', 'skipped')} |")
            continue
        if "error" in r:
            lines.append(f"{cell3} | ERR | | | | | | | {r['error'][:80]} |")
            continue
        static = "—"
        for comp in (comps or {}).get(key, []):
            note = static_attention_check(comp)
            if note:
                static = note
                break
        try:
            a = analyze(r)
        except Exception as e:
            lines.append(f"{cell3} | ERR | | | | | | {static} "
                         f"| malformed record ({type(e).__name__}: {e}) |")
            continue
        t = a["terms"]
        lines.append(
            f"{cell3} "
            f"| {t['compute']:.3e} | {t['memory']:.3e} | {t['collective']:.3e} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.2f} | {static} | {a['note']} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--markdown", default=None)
    ap.add_argument("--flash-adjust", action="store_true",
                    help="substitute the measured attention chain with the "
                         "Pallas flash kernel's analytic traffic")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.tag)
    if not recs:
        print(f"[roofline] no dry-run artifacts under {args.dir!r} — run "
              "`python -m benchmarks.dryrun` first (or pass --dir)")
        return 1
    comps = load_components(args.dir)
    if args.flash_adjust:
        recs = [flash_adjust(r, comps[(r["arch"], r["shape"], r["mesh"])])
                if (r["arch"], r["shape"], r["mesh"]) in comps
                and not r.get("skipped") and "error" not in r else r
                for r in recs]
    md = markdown_table(recs, comps)
    print(md)
    if args.markdown:
        d = os.path.dirname(args.markdown)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.markdown, "w") as f:
            f.write(md + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
