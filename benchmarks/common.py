"""Benchmark timing utilities."""

from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "Row", "emit", "write_json", "check_manifest",
           "SMOKE_TIME"]


# Smoke rows feed the CI perf gate (benchmarks/perf_gate.py), so the timings
# must be past jax's per-callable dispatch warm-up (the first few calls of a
# fresh jitted fn are 3-10x steady state), best-of a few reps, and — since
# the gated calls are ~15-40us — averaged over enough inner calls per
# timed window (SMOKE_INNER) that one lucky/unlucky scheduler slice can't
# flip a ratio past the gate. Still tiny shapes, still seconds per stage.
SMOKE_TIME = dict(warmup=5, repeats=5)
SMOKE_INNER = 64


def time_fn(fn, *args, warmup=2, repeats=5, inner=1):
    """Best-of-repeats wall time per call (seconds)."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    if out is not None:  # warmup=0: nothing dispatched yet
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


class Row:
    def __init__(self, name: str, seconds: float, derived: str):
        self.name = name
        self.seconds = seconds
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.seconds * 1e6:.1f},{self.derived}"


def emit(rows):
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())


def write_json(rows, path: str) -> None:
    """Persist rows as JSON (the CI ``bench_smoke.json`` artifact)."""
    import json
    import os

    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump([dict(name=r.name, us_per_call=r.seconds * 1e6,
                        derived=r.derived) for r in rows], f, indent=1)


def check_manifest(rows, manifest_path: str) -> list[str]:
    """Row-manifest check: every non-comment line of ``manifest_path`` is a
    row-name PREFIX that must match at least one emitted row. Returns the
    list of unmatched prefixes — a benchmark family silently disappearing
    (renamed, import-skipped, dropped from --smoke) breaks CI instead of
    rotting."""
    names = [r.name for r in rows]
    missing = []
    with open(manifest_path) as f:
        for line in f:
            want = line.split("#", 1)[0].strip()
            if not want:
                continue
            if not any(n == want or n.startswith(want) for n in names):
                missing.append(want)
    return missing
