"""Benchmark timing utilities."""

from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "Row", "emit", "SMOKE_TIME"]


SMOKE_TIME = dict(warmup=1, repeats=1)  # one rep: correctness-drift canary


def time_fn(fn, *args, warmup=2, repeats=5, inner=1):
    """Best-of-repeats wall time per call (seconds)."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    if out is not None:  # warmup=0: nothing dispatched yet
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


class Row:
    def __init__(self, name: str, seconds: float, derived: str):
        self.name = name
        self.seconds = seconds
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.seconds * 1e6:.1f},{self.derived}"


def emit(rows):
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
