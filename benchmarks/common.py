"""Benchmark timing utilities."""

from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "time_fn_paired", "Row", "emit", "write_json",
           "check_manifest", "SMOKE_TIME"]


# Smoke rows feed the CI perf gate (benchmarks/perf_gate.py), so the timings
# must be past jax's per-callable dispatch warm-up (the first few calls of a
# fresh jitted fn are 3-10x steady state), best-of a few reps, and — since
# the gated calls are ~15-40us — averaged over enough inner calls per
# timed window (SMOKE_INNER) that one lucky/unlucky scheduler slice can't
# flip a ratio past the gate, with enough repeats that the min-of-repeats
# survives a multi-hundred-ms noise burst (a shared CPU neighbor) spanning
# a few windows. Still tiny shapes, still seconds per stage.
SMOKE_TIME = dict(warmup=5, repeats=9)
SMOKE_INNER = 64


def time_fn(fn, *args, warmup=2, repeats=5, inner=1):
    """Best-of-repeats wall time per call (seconds)."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    if out is not None:  # warmup=0: nothing dispatched yet
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def time_fn_paired(fa, a_args, fb, b_args, warmup=2, repeats=5, inner=1):
    """Paired timing for an A/B pair whose RATIO is perf-gated. The two
    timed windows alternate each round, and the gated statistic is the
    MEDIAN over rounds of the adjacent-window b/a ratio: the two windows of
    one round run milliseconds apart, so host frequency scaling and noisy
    CPU neighbors (which move absolute wall time 2x between bench runs)
    cancel out of each round's ratio, and the median shrugs off the rounds
    a noise burst does split. min(A-windows)/min(B-windows) has no such
    pairing — the two mins can come from different machine states.
    Returns (sec_a, sec_b, ratio): best-of-rounds seconds for each side
    (the Row absolutes) plus the median paired ratio (the gate input)."""
    for f, args in ((fa, a_args), (fb, b_args)):
        out = None
        for _ in range(warmup):
            out = f(*args)
        if out is not None:
            jax.block_until_ready(out)
    best = [float("inf"), float("inf")]
    ratios = []
    for _ in range(repeats):
        win = [0.0, 0.0]
        for i, (f, args) in enumerate(((fa, a_args), (fb, b_args))):
            t0 = time.perf_counter()
            for _ in range(inner):
                out = f(*args)
            jax.block_until_ready(out)
            win[i] = (time.perf_counter() - t0) / inner
            best[i] = min(best[i], win[i])
        ratios.append(win[1] / win[0])
    ratios.sort()
    return best[0], best[1], ratios[len(ratios) // 2]


class Row:
    def __init__(self, name: str, seconds: float, derived: str):
        self.name = name
        self.seconds = seconds
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.seconds * 1e6:.1f},{self.derived}"


def emit(rows):
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())


def write_json(rows, path: str) -> None:
    """Persist rows as JSON (the CI ``bench_smoke.json`` artifact)."""
    import json
    import os

    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump([dict(name=r.name, us_per_call=r.seconds * 1e6,
                        derived=r.derived) for r in rows], f, indent=1)


def check_manifest(rows, manifest_path: str) -> list[str]:
    """Row-manifest check: every non-comment line of ``manifest_path`` is a
    row-name PREFIX that must match at least one emitted row. Returns the
    list of unmatched prefixes — a benchmark family silently disappearing
    (renamed, import-skipped, dropped from --smoke) breaks CI instead of
    rotting."""
    names = [r.name for r in rows]
    missing = []
    with open(manifest_path) as f:
        for line in f:
            want = line.split("#", 1)[0].strip()
            if not want:
                continue
            if not any(n == want or n.startswith(want) for n in names):
                missing.append(want)
    return missing
