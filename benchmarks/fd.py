"""Paper Fig. 2 analogue: FD kernel MNodes/s across backend expansions.

Backends: jnp (vectorized XLA — the portable expansion), loops (serial
fori — the explicit-loop expansion), native (hand-written jnp reference,
NOT through the kernel language — measures language overhead), and
pallas-interpret at a reduced size (correctness backend on CPU; the
compiled Pallas path is the TPU target).
"""

from __future__ import annotations

import jax

from repro.apps import fd2d
from .common import Row, SMOKE_INNER, SMOKE_TIME, time_fn, time_fn_paired

SIZES = {"jnp": (512, 512), "native": (512, 512), "loops": (128, 128),
         "pallas": (64, 64)}
# smoke: one shape for every backend so the CI perf gate (benchmarks/
# perf_gate.py) compares unified expansions against native per-shape; each
# unified backend is timed PAIRED against the native step so the gate reads
# the drift-immune paired ratio (see time_fn_paired), not a quotient of two
# separately-timed us.
SMOKE_SIZES = {"native": (32, 32), "jnp": (32, 32), "loops": (32, 32),
               "pallas": (32, 32)}


def run(rows: list, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    inner = SMOKE_INNER if smoke else 4
    nat_fn = None
    for backend, (w, h) in (SMOKE_SIZES if smoke else SIZES).items():
        model = "jnp" if backend == "native" else backend
        app = fd2d.FDWave(model=model, width=w, height=h, radius=1)
        extra = ""
        if backend == "native":
            nat = app
            nat_fn = jax.jit(lambda a, b: fd2d.reference_step(
                a, b, nat.weights, nat.dx, nat.dt))
            sec = time_fn(nat_fn, nat.o_u1.data, nat.o_u2.data,
                          inner=inner, **tkw)
        elif smoke:
            _, sec, ratio = time_fn_paired(
                nat_fn, (nat.o_u1.data, nat.o_u2.data),
                lambda: app.fd2d.run(app.o_u1.data, app.o_u2.data)[0], (),
                inner=inner, **tkw)
            extra = f"; gate_ratio={ratio:.3f}"
        else:
            sec = time_fn(lambda: app.fd2d.run(app.o_u1.data, app.o_u2.data)[0],
                          inner=inner, **tkw)
        mnodes = w * h / sec / 1e6
        rows.append(Row(f"fd2d/{backend}/{w}x{h}", sec,
                        f"{mnodes:.1f} MNodes/s{extra}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run([]))
