"""Unified-language kernel rows: matmul (reduce axis) + rmsnorm on all three
backend expansions. The pallas-vs-oracle ratio is the paper's portability
pitch made measurable: one source, per-backend performance."""

from __future__ import annotations

import numpy as np

from repro.core import BACKENDS
from repro.kernels.matmul import matmul
from repro.kernels.rmsnorm.kernel import rmsnorm_unified

from .common import Row, time_fn

__all__ = ["run"]


def run(rows):
    rng = np.random.RandomState(0)

    m = k = n = 256
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    for backend in BACKENDS:
        sec = time_fn(lambda a_, b_, be=backend: matmul(
            a_, b_, block_m=64, block_n=64, block_k=64, backend=be), a, b)
        rows.append(Row(f"unified/matmul/{backend}", sec,
                        f"M=K=N={m} bm=bn=bk=64 "
                        f"gflops={2 * m * k * n / sec / 1e9:.1f}"))

    r, d = 2048, 1024
    x = rng.randn(r, d).astype(np.float32)
    w = rng.randn(d).astype(np.float32)
    for backend in BACKENDS:
        sec = time_fn(lambda x_, w_, be=backend: rmsnorm_unified(
            x_, w_, block_rows=256, backend=be), x, w)
        rows.append(Row(f"unified/rmsnorm/{backend}", sec,
                        f"rows={r} d={d} block_rows=256 "
                        f"gbps={3 * x.nbytes / sec / 1e9:.1f}"))
    return rows
