"""Unified-language kernel rows: matmul (reduce axis), rmsnorm, the full
flash-attention family — forward, fused backward (per-output reduce
granularity) and single-token decode — and the fused LM head (matmul +
online-softmax row stats, outputs at multiple reduce granularities) on all
three backend expansions. The pallas-vs-oracle ratio is the paper's
portability pitch made measurable: one source, per-backend performance."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import BACKENDS, estimate_cost
from repro.core.lang import defines_namespace
from repro.kernels.flash_attention import (decode_attention, flash_attention,
                                           paged_decode_attention, ring_flash,
                                           ring_flash_attention,
                                           rolling_slot_pos)
from repro.kernels.lm_head import lm_head_ce, lm_head_logits
from repro.kernels.matmul import matmul
from repro.kernels.rmsnorm import rmsnorm_unified

from .common import (Row, SMOKE_INNER, SMOKE_TIME, time_fn, time_fn_paired)

__all__ = ["run"]


def run(rows, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    rng = np.random.RandomState(0)

    m = k = n = 64 if smoke else 256
    bs = 32 if smoke else 64
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    for backend in BACKENDS:
        sec = time_fn(lambda a_, b_, be=backend: matmul(
            a_, b_, block_m=bs, block_n=bs, block_k=bs, backend=be), a, b,
            **tkw)
        rows.append(Row(f"unified/matmul/{backend}", sec,
                        f"M=K=N={m} bm=bn=bk={bs} "
                        f"gflops={2 * m * k * n / sec / 1e9:.1f}"))

    r, d = (64, 128) if smoke else (2048, 1024)
    br = 32 if smoke else 256
    x = rng.randn(r, d).astype(np.float32)
    w = rng.randn(d).astype(np.float32)
    for backend in BACKENDS:
        sec = time_fn(lambda x_, w_, be=backend: rmsnorm_unified(
            x_, w_, block_rows=br, backend=be), x, w, **tkw)
        rows.append(Row(f"unified/rmsnorm/{backend}", sec,
                        f"rows={r} d={d} block_rows={br} "
                        f"gbps={3 * x.nbytes / sec / 1e9:.1f}"))

    # flash attention fwd, one source on every backend (CPU: interpret-mode
    # correctness artifact; the compiled pallas path is the TPU target)
    b2, h2, s2, d2 = (1, 2, 128, 32) if smoke else (1, 2, 512, 64)
    bq = 64 if smoke else 128
    q = rng.randn(b2, h2, s2, d2).astype(np.float32)
    kk = rng.randn(b2, h2, s2, d2).astype(np.float32)
    vv = rng.randn(b2, h2, s2, d2).astype(np.float32)
    afl = 4 * b2 * h2 * s2 * s2 * d2
    for backend in BACKENDS:
        sec = time_fn(lambda q_, k_, v_, be=backend: flash_attention(
            q_, k_, v_, causal=True, block_q=bq, block_kv=bq, backend=be),
            q, kk, vv, **tkw)
        rows.append(Row(f"unified/flash_attention/{backend}", sec,
                        f"s={s2} bq=bkv={bq} "
                        f"gflops={afl / sec / 1e9:.1f}"))

    # flash BACKWARD: one fused dq/dk/dv kernel (Tile(reduce=...) per-output
    # granularity) through the op's custom VJP, on every backend
    bfl = int(2.5 * afl)
    for backend in BACKENDS:
        f = jax.jit(jax.grad(
            lambda q_, k_, v_, be=backend: (flash_attention(
                q_, k_, v_, causal=True, block_q=bq, block_kv=bq,
                backend=be) ** 2).sum(),
            argnums=(0, 1, 2)))
        sec = time_fn(f, q, kk, vv, **tkw)
        rows.append(Row(f"unified/flash_bwd/{backend}", sec,
                        f"s={s2} bq=bkv={bq} "
                        f"gflops={bfl / sec / 1e9:.1f}"))

    # RING flash attention: the declared shard schedule in its local
    # single-process form — the SAME per-step kernel + exact merge the
    # shard_map ring runs, over ring_steps locally-split kv chunks (bit-
    # comparable to the mesh run). comm_B is the static cost model's
    # per-shard interconnect estimate for the mesh-extended spec.
    steps = 4
    s_loc = s2 // steps
    _, _, rp = ring_flash._resolve(dict(causal=True, block_q=bq, block_kv=bq,
                                        ring_steps=steps))
    _, rdef, _ = ring_flash._prepare(
        (q[:, :, :s_loc], kk[:, :, :s_loc], vv[:, :, :s_loc]), rp)
    rD = defines_namespace(rdef)
    comm = estimate_cost(ring_flash.builder(rD), rD).comm_bytes
    for backend in BACKENDS:
        sec = time_fn(lambda q_, k_, v_, be=backend: ring_flash_attention(
            q_, k_, v_, ring_steps=steps, causal=True, block_q=bq,
            block_kv=bq, backend=be), q, kk, vv, **tkw)
        rows.append(Row(f"unified/ring_flash/{backend}", sec,
                        f"s={s2} steps={steps} comm_B={comm} "
                        f"gflops={afl / sec / 1e9:.1f}"))

    # flash DECODE, contiguous AND paged: one query token vs the kv cache.
    # Decode rows time the JITTED call — serving runs this kernel inside a
    # jitted step, and the paged-vs-contiguous perf gate must compare kernel
    # cost, not eager per-call dispatch overhead. The paged variant reads the
    # SAME kv through the block-table input tile (continuous-batching cache
    # layout): the KV lives in a shuffled pool of fixed-size pages and the
    # kernel's index map reads the table at runtime. page == the contiguous
    # row's block_kv, so the perf gate can pin the gather overhead (paged
    # within 1.3x of contiguous at the same shape). The two rows are timed
    # INTERLEAVED per backend (time_fn_paired) because the gate checks their
    # ratio — separate timing blocks put machine drift on the ratio. The
    # decode cache is LONGER than the smoke attention shape: at s=128 the
    # grid is 2 kv blocks and per-call fixed overhead (one extra scalar
    # operand + prefetch setup) dominates the ratio, flapping it past any
    # sane limit; at 4+ blocks the per-page gather — the thing the gate
    # pins — is what's measured.
    q1 = q[:, :, :1]
    sD = 256 if smoke else s2
    kkD = rng.randn(b2, h2, sD, d2).astype(np.float32)
    vvD = rng.randn(b2, h2, sD, d2).astype(np.float32)
    dfl = 4 * b2 * h2 * sD * d2
    dkw = dict(tkw, inner=SMOKE_INNER) if smoke else tkw
    page = bq
    nsp = sD // page
    npg = b2 * nsp + 1                       # + the reserved null page 0
    ptab = np.zeros((b2, nsp), np.int32)
    perm = rng.permutation(b2 * nsp) + 1     # shuffled: a real gather
    pk = np.zeros((npg, h2, page, d2), np.float32)
    pv = np.zeros((npg, h2, page, d2), np.float32)
    for bi in range(b2):
        for j in range(nsp):
            pg = int(perm[bi * nsp + j])
            ptab[bi, j] = pg
            pk[pg] = kkD[bi, :, j * page:(j + 1) * page]
            pv[pg] = vvD[bi, :, j * page:(j + 1) * page]
    pkl = np.full((b2,), sD, np.int32)
    for backend in BACKENDS:
        fc = jax.jit(lambda q_, k_, v_, be=backend: decode_attention(
            q_, k_, v_, block_kv=bq, backend=be))
        fp = jax.jit(lambda q_, k_, v_, t_, l_, be=backend:
                     paged_decode_attention(q_, k_, v_, block_table=t_,
                                            kv_len=l_, backend=be))
        sec, psec, ratio = time_fn_paired(fc, (q1, kkD, vvD),
                                          fp, (q1, pk, pv, ptab, pkl), **dkw)
        rows.append(Row(f"unified/flash_decode/{backend}", sec,
                        f"s={sD} bkv={bq} "
                        f"gflops={dfl / sec / 1e9:.1f}"))
        rows.append(Row(f"unified/flash_decode_paged/{backend}", psec,
                        f"s={sD} page={page} "
                        f"gflops={dfl / psec / 1e9:.1f} "
                        f"gate_ratio={ratio:.3f}"))

    # WINDOWED flash decode: a rotated rolling cache (slot = pos % W) decoded
    # past the wrap — the slot_pos input tile carries the data-dependent mask
    # through the SAME kernel on every backend (was: einsum-only fallback)
    W = s2 // 2
    t = W + W // 2
    sp = rolling_slot_pos(W, t)
    wkk, wvv = kk[:, :, :W], vv[:, :, :W]
    wfl = 4 * b2 * h2 * W * d2
    wbkv = min(bq, W)
    for backend in BACKENDS:
        sec = time_fn(lambda q_, k_, v_, be=backend: decode_attention(
            q_, k_, v_, window=W, kv_len=t, slot_pos=sp, block_kv=wbkv,
            backend=be), q1, wkk, wvv, **tkw)
        rows.append(Row(f"unified/flash_decode_window/{backend}", sec,
                        f"W={W} bkv={wbkv} "
                        f"gflops={wfl / sec / 1e9:.1f}"))

    # fused LM head — matmul + row-max/row-sum at DIFFERENT reduce
    # granularities in one grid. lm_head_ce streams logsumexp + the gold
    # logit out of the pass (the (R, V) logits never materialize);
    # lm_head_logits adds the row max / greedy argmax to the logits pass.
    r4, d4, v4 = (32, 64, 512) if smoke else (512, 512, 4096)
    vocab4 = v4 - 64                       # exercise the Megatron pad mask
    br4, bv4, bk4 = (16, 128, 32) if smoke else (128, 512, 128)
    x4 = rng.randn(r4, d4).astype(np.float32)
    w4 = rng.randn(d4, v4).astype(np.float32)
    lab4 = rng.randint(0, vocab4, (r4, 1)).astype(np.int32)
    hfl = 2 * r4 * d4 * v4
    for backend in BACKENDS:
        sec = time_fn(lambda x_, w_, l_, be=backend: lm_head_ce(
            x_, w_, l_, vocab=vocab4, block_r=br4, block_v=bv4, block_k=bk4,
            backend=be), x4, w4, lab4, **tkw)
        rows.append(Row(f"unified/lm_head_ce/{backend}", sec,
                        f"R={r4} d={d4} V={v4} "
                        f"gflops={hfl / sec / 1e9:.1f}"))
    for backend in BACKENDS:
        sec = time_fn(lambda x_, w_, be=backend: lm_head_logits(
            x_, w_, vocab=vocab4, block_r=br4, block_v=bv4, block_k=bk4,
            backend=be), x4, w4, **tkw)
        rows.append(Row(f"unified/lm_head_logits/{backend}", sec,
                        f"R={r4} d={d4} V={v4} "
                        f"gflops={hfl / sec / 1e9:.1f}"))
    return rows
