"""Unified-language kernel rows: matmul (reduce axis), rmsnorm and the
flash-attention forward (masked grid cells + reduce axis + scratch) on all
three backend expansions. The pallas-vs-oracle ratio is the paper's
portability pitch made measurable: one source, per-backend performance."""

from __future__ import annotations

import numpy as np

from repro.core import BACKENDS
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.rmsnorm import rmsnorm_unified

from .common import Row, SMOKE_TIME, time_fn

__all__ = ["run"]


def run(rows, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    rng = np.random.RandomState(0)

    m = k = n = 64 if smoke else 256
    bs = 32 if smoke else 64
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    for backend in BACKENDS:
        sec = time_fn(lambda a_, b_, be=backend: matmul(
            a_, b_, block_m=bs, block_n=bs, block_k=bs, backend=be), a, b,
            **tkw)
        rows.append(Row(f"unified/matmul/{backend}", sec,
                        f"M=K=N={m} bm=bn=bk={bs} "
                        f"gflops={2 * m * k * n / sec / 1e9:.1f}"))

    r, d = (64, 128) if smoke else (2048, 1024)
    br = 32 if smoke else 256
    x = rng.randn(r, d).astype(np.float32)
    w = rng.randn(d).astype(np.float32)
    for backend in BACKENDS:
        sec = time_fn(lambda x_, w_, be=backend: rmsnorm_unified(
            x_, w_, block_rows=br, backend=be), x, w, **tkw)
        rows.append(Row(f"unified/rmsnorm/{backend}", sec,
                        f"rows={r} d={d} block_rows={br} "
                        f"gbps={3 * x.nbytes / sec / 1e9:.1f}"))

    # flash attention fwd, one source on every backend (CPU: interpret-mode
    # correctness artifact; the compiled pallas path is the TPU target)
    b2, h2, s2, d2 = (1, 2, 128, 32) if smoke else (1, 2, 512, 64)
    bq = 64 if smoke else 128
    q = rng.randn(b2, h2, s2, d2).astype(np.float32)
    kk = rng.randn(b2, h2, s2, d2).astype(np.float32)
    vv = rng.randn(b2, h2, s2, d2).astype(np.float32)
    afl = 4 * b2 * h2 * s2 * s2 * d2
    for backend in BACKENDS:
        sec = time_fn(lambda q_, k_, v_, be=backend: flash_attention(
            q_, k_, v_, causal=True, block_q=bq, block_kv=bq, backend=be),
            q, kk, vv, **tkw)
        rows.append(Row(f"unified/flash_attention/{backend}", sec,
                        f"s={s2} bq=bkv={bq} "
                        f"gflops={afl / sec / 1e9:.1f}"))
    return rows
