"""Paper Figs. 5-6 analogue: DG SWE volume kernel GFLOP/s + GB/s vs order N."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import dg_swe
from .common import Row, SMOKE_TIME, time_fn

ORDERS = (1, 2, 3, 4, 5, 6, 7)


def run(rows: list, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    for n in ((1, 2) if smoke else ORDERS):
        nx = 4 if smoke else 24
        for backend in ("jnp", "loops", "native"):
            model = "jnp" if backend == "native" else backend
            app = dg_swe.DGVolume(model=model, nx=nx, ny=nx, n=n, jitter=0.1)
            rng = np.random.RandomState(0)
            Q = jnp.asarray(np.stack([
                2.0 + 0.1 * rng.randn(app.E, app.np_),
                0.3 * rng.randn(app.E, app.np_),
                0.3 * rng.randn(app.E, app.np_)], -1), jnp.float32)
            if backend == "native":
                fn = jax.jit(lambda q: dg_swe.volume_ref(
                    q, app.o_geom.data, app.o_db.data, app.o_dr.data,
                    app.o_ds.data))
                sec = time_fn(fn, Q, inner=2, **tkw)
            else:
                if backend == "loops" and n > 4:
                    continue
                sec = time_fn(lambda: app.rhs_volume(Q), inner=2, **tkw)
            gflops = app.E * dg_swe.dg_flops_per_element(app.np_) / sec / 1e9
            gbs = app.E * dg_swe.dg_bytes_per_element(app.np_, 4) / sec / 1e9
            rows.append(Row(f"dg/{backend}/N{n}/E{app.E}", sec,
                            f"{gflops:.2f} GFLOP/s; {gbs:.2f} GB/s"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run([]))
