"""Paper Figs. 5-6 analogue: DG SWE volume kernel GFLOP/s + GB/s vs order N."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import dg_swe
from .common import Row, SMOKE_INNER, SMOKE_TIME, time_fn, time_fn_paired

ORDERS = (1, 2, 3, 4, 5, 6, 7)


def run(rows: list, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    inner = SMOKE_INNER if smoke else 2
    for n in ((1, 2) if smoke else ORDERS):
        nx = 4 if smoke else 24
        # native first: in smoke the unified backends are timed PAIRED
        # against it and the perf gate reads the drift-immune paired ratio
        # (see time_fn_paired) instead of dividing two separately-timed us.
        nat = dg_swe.DGVolume(model="jnp", nx=nx, ny=nx, n=n, jitter=0.1)
        rng = np.random.RandomState(0)
        Q = jnp.asarray(np.stack([
            2.0 + 0.1 * rng.randn(nat.E, nat.np_),
            0.3 * rng.randn(nat.E, nat.np_),
            0.3 * rng.randn(nat.E, nat.np_)], -1), jnp.float32)
        nat_fn = jax.jit(lambda q: dg_swe.volume_ref(
            q, nat.o_geom.data, nat.o_db.data, nat.o_dr.data,
            nat.o_ds.data))
        sec = time_fn(nat_fn, Q, inner=inner, **tkw)
        _vol_row(rows, "native", n, nat, sec)
        for backend in ("jnp", "loops", "pallas"):
            if backend == "loops" and n > 4:
                continue
            if backend == "pallas" and not smoke and n > 3:
                continue  # interpret-mode overhead at high order on CPU
            app = dg_swe.DGVolume(model=backend, nx=nx, ny=nx, n=n,
                                  jitter=0.1)
            extra = ""
            if smoke:
                _, sec, ratio = time_fn_paired(
                    nat_fn, (Q,), lambda: app.rhs_volume(Q), (),
                    inner=inner, **tkw)
                extra = f"; gate_ratio={ratio:.3f}"
            else:
                sec = time_fn(lambda: app.rhs_volume(Q), inner=inner, **tkw)
            _vol_row(rows, backend, n, app, sec, extra)
        _surface_rows(rows, n, nx, smoke, tkw, inner)
    return rows


def _vol_row(rows, backend, n, app, sec, extra=""):
    gflops = app.E * dg_swe.dg_flops_per_element(app.np_) / sec / 1e9
    gbs = app.E * dg_swe.dg_bytes_per_element(app.np_, 4) / sec / 1e9
    rows.append(Row(f"dg/{backend}/N{n}/E{app.E}", sec,
                    f"{gflops:.2f} GFLOP/s; {gbs:.2f} GB/s{extra}"))


def _surface_rows(rows, n, nx, smoke, tkw, inner):
    """The DG surface-flux kernel (Lax-Friedrichs + LIFT) on pre-gathered
    traces — the second half of the full DG RHS, through the same language."""
    rng = np.random.RandomState(1)
    nat = dg_swe.SWESolver(model="jnp", nx=nx, ny=nx, n=n, jitter=0.0)
    Q = jnp.asarray(np.stack([
        2.0 + 0.1 * rng.randn(nat.E, nat.np_),
        0.3 * rng.randn(nat.E, nat.np_),
        0.3 * rng.randn(nat.E, nat.np_)], -1), jnp.float32)
    Qf = Q.reshape(nat.E * nat.np_, 3)
    QM, QP = Qf[nat.vmapM], Qf[nat.vmapP]
    nat_fn = jax.jit(lambda a, b: dg_swe.surface_ref(
        a, b, nat.o_nrm.data, nat.o_lift.data))
    sec = time_fn(nat_fn, QM, QP, inner=inner, **tkw)
    _surf_row(rows, "native", n, nat, sec)
    for backend in ("jnp", "loops", "pallas"):
        if backend == "loops" and n > 4:
            continue
        if backend == "pallas" and not smoke and n > 3:
            continue
        app = dg_swe.SWESolver(model=backend, nx=nx, ny=nx, n=n, jitter=0.0)
        extra = ""
        if smoke:
            _, sec, ratio = time_fn_paired(
                nat_fn, (QM, QP),
                lambda: app.surf_kernel.run(QM, QP, app.o_nrm.data,
                                            app.o_lift.data)[0], (),
                inner=inner, **tkw)
            extra = f"; gate_ratio={ratio:.3f}"
        else:
            sec = time_fn(
                lambda: app.surf_kernel.run(QM, QP, app.o_nrm.data,
                                            app.o_lift.data)[0],
                inner=inner, **tkw)
        _surf_row(rows, backend, n, app, sec, extra)


def _surf_row(rows, backend, n, app, sec, extra=""):
    # per element: flux algebra on 3nfp face nodes + the (np x 3nfp x 3)
    # LIFT contraction
    flops = app.E * (40 * app.nfp3 + 2 * app.np_ * app.nfp3 * 3)
    rows.append(Row(f"dg/surface/{backend}/N{n}/E{app.E}", sec,
                    f"{flops / sec / 1e9:.2f} GFLOP/s{extra}"))


if __name__ == "__main__":
    from .common import emit
    emit(run([]))
