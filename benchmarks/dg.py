"""Paper Figs. 5-6 analogue: DG SWE volume kernel GFLOP/s + GB/s vs order N."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import dg_swe
from .common import Row, SMOKE_INNER, SMOKE_TIME, time_fn

ORDERS = (1, 2, 3, 4, 5, 6, 7)


def run(rows: list, smoke: bool = False):
    tkw = SMOKE_TIME if smoke else {}
    inner = SMOKE_INNER if smoke else 2
    for n in ((1, 2) if smoke else ORDERS):
        nx = 4 if smoke else 24
        for backend in ("jnp", "loops", "pallas", "native"):
            model = "jnp" if backend == "native" else backend
            app = dg_swe.DGVolume(model=model, nx=nx, ny=nx, n=n, jitter=0.1)
            rng = np.random.RandomState(0)
            Q = jnp.asarray(np.stack([
                2.0 + 0.1 * rng.randn(app.E, app.np_),
                0.3 * rng.randn(app.E, app.np_),
                0.3 * rng.randn(app.E, app.np_)], -1), jnp.float32)
            if backend == "native":
                fn = jax.jit(lambda q: dg_swe.volume_ref(
                    q, app.o_geom.data, app.o_db.data, app.o_dr.data,
                    app.o_ds.data))
                sec = time_fn(fn, Q, inner=inner, **tkw)
            else:
                if backend == "loops" and n > 4:
                    continue
                if backend == "pallas" and not smoke and n > 3:
                    continue  # interpret-mode overhead at high order on CPU
                sec = time_fn(lambda: app.rhs_volume(Q), inner=inner, **tkw)
            gflops = app.E * dg_swe.dg_flops_per_element(app.np_) / sec / 1e9
            gbs = app.E * dg_swe.dg_bytes_per_element(app.np_, 4) / sec / 1e9
            rows.append(Row(f"dg/{backend}/N{n}/E{app.E}", sec,
                            f"{gflops:.2f} GFLOP/s; {gbs:.2f} GB/s"))
        _surface_rows(rows, n, nx, smoke, tkw, inner)
    return rows


def _surface_rows(rows, n, nx, smoke, tkw, inner):
    """The DG surface-flux kernel (Lax-Friedrichs + LIFT) on pre-gathered
    traces — the second half of the full DG RHS, through the same language."""
    rng = np.random.RandomState(1)
    for backend in ("jnp", "loops", "pallas", "native"):
        if backend == "loops" and n > 4:
            continue
        if backend == "pallas" and not smoke and n > 3:
            continue
        model = "jnp" if backend == "native" else backend
        app = dg_swe.SWESolver(model=model, nx=nx, ny=nx, n=n, jitter=0.0)
        Q = jnp.asarray(np.stack([
            2.0 + 0.1 * rng.randn(app.E, app.np_),
            0.3 * rng.randn(app.E, app.np_),
            0.3 * rng.randn(app.E, app.np_)], -1), jnp.float32)
        Qf = Q.reshape(app.E * app.np_, 3)
        QM, QP = Qf[app.vmapM], Qf[app.vmapP]
        if backend == "native":
            fn = jax.jit(lambda a, b: dg_swe.surface_ref(
                a, b, app.o_nrm.data, app.o_lift.data))
            sec = time_fn(fn, QM, QP, inner=inner, **tkw)
        else:
            sec = time_fn(
                lambda: app.surf_kernel.run(QM, QP, app.o_nrm.data,
                                            app.o_lift.data)[0],
                inner=inner, **tkw)
        # per element: flux algebra on 3nfp face nodes + the (np x 3nfp x 3)
        # LIFT contraction
        flops = app.E * (40 * app.nfp3 + 2 * app.np_ * app.nfp3 * 3)
        rows.append(Row(f"dg/surface/{backend}/N{n}/E{app.E}", sec,
                        f"{flops / sec / 1e9:.2f} GFLOP/s"))


if __name__ == "__main__":
    from .common import emit
    emit(run([]))
