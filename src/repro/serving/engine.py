"""Continuous-batching decode engine over paged KV caches.

One jitted one-token decode step (``LM.paged_greedy_step`` /
``paged_decode_step``) runs over ``batch`` SLOTS every step, whatever mix of
sequences currently occupies them; the :class:`~repro.serving.scheduler.
Scheduler` retires finished sequences, refills slots from the FIFO queue
mid-flight, and preempts-by-eviction when the page pool runs dry. Admission
prefills the new sequence per-slot (B=1 ``LM.prefill``) and scatters its
contiguous KV into the sequence's pages host-side, so the hot loop is
always the SAME compiled step — no recompilation across traffic mixes.

Token semantics match ``launch.serve.generate`` exactly: the first emitted
token comes from the prefill logits, every decode step emits the next, the
EOS token itself is emitted before the sequence retires, and a sequence
emits at most ``max_new`` tokens. Attention reads KV exclusively through
the block-table tile (``flash_decode_paged``), which is bit-identical to
contiguous ``flash_decode`` when the page size equals its block size — so
a greedy Engine run reproduces the static per-sequence baseline token for
token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import fit_block

from .scheduler import Scheduler

__all__ = ["Engine"]


class Engine:
    def __init__(self, model, params, *, batch: int, max_len: int,
                 num_pages: int | None = None, page_size: int | None = None,
                 eos_id: int | None = None, greedy: bool = True,
                 temperature: float = 1.0, rng=None, mesh=None,
                 cache_dtype=None):
        if not model.pageable:
            raise ValueError("Engine needs a pageable model (see LM.pageable)")
        if temperature <= 0:
            raise ValueError(f"temperature must be > 0, got {temperature}")
        self.model = model
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.temperature = float(temperature)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        if page_size is None:
            # the page size IS flash_decode's tuned block size: paged blocks
            # then stream identically to contiguous ones (and bit-identically
            # -- the parity the tests pin). Adopt persisted winners first so
            # a pre-tuned fleet serves at its tuned block.
            from repro.kernels.flash_attention import flash_decode
            from repro.launch import tuning
            try:
                tuning.adopt(model.cfg, dict(batch=batch, prompt_len=max_len,
                                             max_len=max_len), kind="serve")
            except Exception:
                pass
            page_size = fit_block(
                int(flash_decode.defaults.get("block_kv") or 512), max_len)
        self.page_size = int(page_size)
        nsp = -(-max_len // self.page_size)
        if num_pages is None:
            # default pool: every slot can grow to max_len, so preemption
            # never fires unless the caller shrinks the pool deliberately
            num_pages = batch * nsp + 1
        if num_pages - 1 < nsp:
            raise ValueError(
                f"num_pages={num_pages} cannot hold one max_len={max_len} "
                f"sequence ({nsp} pages of {self.page_size})")
        self.sched = Scheduler(batch=batch, page_size=self.page_size,
                               num_pages=num_pages, max_len=max_len)
        self.cache = model.init_paged_cache(batch, num_pages, self.page_size,
                                            nsp, dtype=cache_dtype)
        self._requests = {}
        self._pending = np.zeros((batch,), np.int32)
        self._slot_pages = [[] for _ in range(batch)]
        if mesh is not None:
            from repro.parallel.steps import build_paged_serve_step
            self._step_fn, specs = build_paged_serve_step(
                model, mesh, batch=batch, greedy=greedy)
            self.params = jax.device_put(params, specs["params"])
            self.cache = jax.device_put(self.cache, specs["cache"])
        else:
            self.params = params
            fn = model.paged_greedy_step if greedy else model.paged_decode_step
            self._step_fn = jax.jit(lambda p, c, t: fn(p, t, c),
                                    donate_argnums=(1,))
        self._prefill_fn = jax.jit(lambda p, t: model.prefill(p, t))

        # admission scatter, fused: ALL stacks' pages + pos rows land in one
        # jitted call (the eager .at[].set chain was ~10 dispatches per
        # admission and dominated engine wall time on small models). Keyed
        # on the prefill length, like the prefill itself. ``pages`` is the
        # slot's table row, padded with the null page 0 — padded entries
        # write zero KV and all-(-1) pos rows to page 0, which the decode
        # step re-pins to -1 anyway.
        pg = self.page_size

        def _scatter_impl(stacks, pos_pages, table, lens, pstacks, pages,
                          slot):
            nsp_ = pages.shape[0]
            out = []
            for sc, pc in zip(stacks, pstacks):
                kc, vc = pc["k"], pc["v"]          # (n, 1, hk, plen, hd)
                n, _, hk, plen, hd = kc.shape
                L = nsp_ * pg

                def paged(c, pool):                # -> (n, nsp, hk, pg, hd)
                    full = jnp.zeros((n, hk, L, hd), pool.dtype)
                    full = full.at[:, :, :plen].set(c[:, 0].astype(pool.dtype))
                    return full.reshape(n, hk, nsp_, pg, hd).transpose(
                        0, 2, 1, 3, 4)

                out.append({"kp": sc["kp"].at[:, pages].set(
                                paged(kc, sc["kp"])),
                            "vp": sc["vp"].at[:, pages].set(
                                paged(vc, sc["vp"]))})
            ar = jnp.arange(pg, dtype=jnp.int32)
            pos = jnp.arange(nsp_, dtype=jnp.int32)[:, None] * pg + ar[None]
            rows = jnp.where(pos < plen, pos, -1)
            return (out, pos_pages.at[pages].set(rows),
                    table.at[slot].set(pages), lens.at[slot].set(plen))

        self._scatter_fn = jax.jit(_scatter_impl,
                                   donate_argnums=(0, 1, 2, 3))

        # retirement + growth are tiny table/pos edits — still worth one
        # jitted call each instead of an eager dispatch chain
        nsp_t = self.sched.nseq_pages

        def _clear_impl(table, lens, slot):
            return (table.at[slot].set(jnp.zeros((nsp_t,), jnp.int32)),
                    lens.at[slot].set(0))

        self._clear_fn = jax.jit(_clear_impl, donate_argnums=(0, 1))

        def _grow_impl(pos_pages, table, pages, new, slot):
            cur = pos_pages[pages]                 # (nsp, pg); dup page-0
            rows = jnp.where(new[:, None], -1, cur)  # reads write back as-is
            return pos_pages.at[pages].set(rows), table.at[slot].set(pages)

        self._grow_fn = jax.jit(_grow_impl, donate_argnums=(0, 1))
        self._greedy_fn = jax.jit(model.greedy_token)

    # -------------------------------------------------------------- requests
    def submit(self, prompt, max_new: int) -> int:
        """Queue a prompt for generation. Returns the request id."""
        rid = self.sched.submit(prompt, max_new)
        self._requests[rid] = self.sched.queue[-1]
        return rid

    def result(self, rid: int) -> list[int]:
        return list(self._requests[rid].tokens)

    @property
    def idle(self) -> bool:
        return self.sched.idle

    # ------------------------------------------------------- device mirrors
    def _table_row(self, pages: list[int]) -> np.ndarray:
        row = np.zeros((self.sched.nseq_pages,), np.int32)
        row[:len(pages)] = pages               # padded entries hit null page 0
        return row

    def _clear_slot(self, slot: int):
        self.cache["table"], self.cache["len"] = self._clear_fn(
            self.cache["table"], self.cache["len"], slot)
        self._slot_pages[slot] = []
        self._pending[slot] = 0

    def _scatter_prefill(self, pcache, pages: list[int], slot: int):
        """Copy a B=1 contiguous prefill cache into the sequence's pages
        (logical page j -> pool page pages[j]), stamp their pos rows and the
        slot's table/len — one jitted call (see ``_scatter_impl``)."""
        c = self.cache
        (c["stacks"], c["pos_pages"], c["table"], c["len"]) = \
            self._scatter_fn(c["stacks"], c["pos_pages"], c["table"],
                             c["len"], pcache["stacks"],
                             jnp.asarray(self._table_row(pages)), slot)
        self._slot_pages[slot] = list(pages)

    def _sync_grown(self, slot: int):
        """Push newly granted pages into the device table; their pos rows
        reset to -1 (the decode step stamps positions as it writes)."""
        req = self.sched.slots[slot]
        pages = self.sched.pages.owned(req.rid)
        if pages == self._slot_pages[slot]:
            return
        known = set(self._slot_pages[slot])
        row = self._table_row(pages)
        new = np.array([p not in known and p != 0 for p in row], bool)
        self.cache["pos_pages"], self.cache["table"] = self._grow_fn(
            self.cache["pos_pages"], self.cache["table"],
            jnp.asarray(row), jnp.asarray(new), slot)
        self._slot_pages[slot] = list(pages)

    # ----------------------------------------------------------------- step
    def _sample(self, logits):
        self._rng, sub = jax.random.split(self._rng)
        scaled = (logits[..., :self.model.cfg.vocab_size]
                  / self.temperature)
        return np.asarray(jax.random.categorical(sub, scaled))

    def _emit(self, slot: int, tok: int, emitted: dict):
        req = self.sched.slots[slot]
        req.tokens.append(tok)
        emitted.setdefault(req.rid, []).append(tok)
        if ((self.eos_id is not None and tok == self.eos_id)
                or len(req.tokens) >= req.max_new):
            self.sched.retire(slot)
            self._clear_slot(slot)

    def _admit(self, slot: int, req, emitted: dict):
        resume = req.resume_prompt             # prompt + generated-so-far
        toks = jnp.asarray(np.asarray(resume, np.int32)[None])
        logits, pcache = self._prefill_fn(self.params, toks)
        pages = self.sched.pages.owned(req.rid)
        self._scatter_prefill(pcache, pages, slot)
        if self.greedy:
            tok = int(np.asarray(self._greedy_fn(logits[0])))
        else:
            tok = int(self._sample(np.asarray(logits))[0])
        self._pending[slot] = tok
        self._emit(slot, tok, emitted)

    def step(self) -> dict:
        """One engine step: retirement happened at the previous emission;
        admit queued requests into free slots, grow (preempting on famine),
        run ONE batched decode step, emit. Returns ``{rid: [tokens]}``
        emitted this step (admissions emit their prefill token here too)."""
        emitted: dict = {}
        for slot, req in self.sched.admit():
            self._admit(slot, req, emitted)
        for slot in list(self.sched.running):
            if self.sched.slots[slot] is None:
                continue                        # evicted by a younger grow
            while not self.sched.grow(slot):
                freed = self.sched.preempt_youngest(exclude=slot)
                if freed is None:
                    raise RuntimeError(
                        "page pool cannot hold a single sequence")
                self._clear_slot(freed)
            self._sync_grown(slot)
        running = self.sched.running
        if not running:
            if self.sched.queue:
                raise RuntimeError(
                    "no slot admitted but requests remain queued — page "
                    "pool too small for the front request")
            return emitted
        toks = jnp.asarray(self._pending.reshape(-1, 1))
        if self.greedy:
            nxt, _logits, self.cache = self._step_fn(self.params, self.cache,
                                                     toks)
            nxt = np.asarray(nxt)
        else:
            logits, self.cache = self._step_fn(self.params, self.cache, toks)
            nxt = self._sample(np.asarray(logits))
        for slot in running:
            tok = int(nxt[slot])
            self._pending[slot] = tok
            self._emit(slot, tok, emitted)
        return emitted

    def drain(self, max_steps: int | None = None) -> dict:
        """Step until every submitted request completed. Returns
        ``{rid: generated tokens}`` for all requests ever submitted."""
        steps = 0
        while not self.sched.idle:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(f"drain: exceeded {max_steps} steps")
        return {rid: list(r.tokens) for rid, r in self._requests.items()}
