"""Fixed-size KV page allocator (the vLLM PagedAttention idiom).

The device holds one pool of ``num_pages`` pages per layer; this allocator
is the host-side owner of that pool. Page 0 is RESERVED as the null page —
idle batch slots' block tables point at it and their per-step writes land
there — so allocatable pages are ``1 .. num_pages - 1``. Allocation is
all-or-nothing per request: a sequence either gets every page it asked for
or none (partial grants would deadlock admission under fragmentation-free
fixed pages).
"""

from __future__ import annotations

__all__ = ["PageAllocator"]


class PageAllocator:
    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("PageAllocator needs >= 2 pages (page 0 is the "
                             f"reserved null page), got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: list[int] = list(range(1, num_pages))
        self._owned: dict[object, list[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV entries."""
        return -(-max(n_tokens, 1) // self.page_size)

    def alloc(self, seq_id, n: int) -> list[int] | None:
        """Grant ``n`` pages to ``seq_id`` (appended to its existing run in
        logical order), or None — with no state change — on shortfall."""
        if n < 0:
            raise ValueError(f"alloc: n must be >= 0, got {n}")
        if n > len(self._free):
            return None
        grant = self._free[:n]
        del self._free[:n]
        self._owned.setdefault(seq_id, []).extend(grant)
        return list(grant)

    def owned(self, seq_id) -> list[int]:
        return list(self._owned.get(seq_id, ()))

    def release(self, seq_id) -> list[int]:
        """Return every page of ``seq_id`` to the free list."""
        pages = self._owned.pop(seq_id, [])
        self._free.extend(pages)
        return list(pages)

    def check_invariants(self):
        """free ∪ owned must partition {1 .. num_pages-1}: no page leaked,
        none double-owned, none handed out twice. Raises AssertionError."""
        owned_all: list[int] = []
        for pages in self._owned.values():
            owned_all.extend(pages)
        assert len(set(owned_all)) == len(owned_all), \
            f"page double-owned: {sorted(owned_all)}"
        assert len(set(self._free)) == len(self._free), \
            f"free-list duplicate: {sorted(self._free)}"
        universe = set(range(1, self.num_pages))
        seen = set(self._free) | set(owned_all)
        assert not (set(self._free) & set(owned_all)), \
            "page both free and owned"
        assert seen == universe, \
            f"pages leaked: {sorted(universe - seen)}"
