"""Continuous-batching serving: paged KV pools, page allocator, scheduler
and the :class:`Engine` that keeps one jitted decode step running over
mixed prompt/generation-length traffic."""

from .engine import Engine
from .pages import PageAllocator
from .scheduler import Request, Scheduler

__all__ = ["Engine", "PageAllocator", "Request", "Scheduler"]
