"""Continuous-batching scheduler: admission, growth, retirement, preemption.

Pure host-side bookkeeping over ``batch`` decode SLOTS and a
:class:`~repro.serving.pages.PageAllocator` — no device state. The engine
owns the device mirror (block tables, lengths, KV pools) and calls back in
this order each step: ``retire`` finished slots, ``admit`` queued requests
into free slots (FIFO), ``grow`` every running slot whose next token starts
a new page — preempting the YOUNGEST running sequences when the pool runs
dry (they requeue at the FRONT with their generated prefix and re-prefill
on re-admission, so no work is lost and older sequences never starve).
"""

from __future__ import annotations

import dataclasses
from collections import deque

from .pages import PageAllocator

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]               # original prompt tokens
    max_new: int
    tokens: list[int] = dataclasses.field(default_factory=list)  # generated
    state: str = "queued"           # queued | running | done
    preempted: int = 0              # times evicted mid-flight

    @property
    def resume_prompt(self) -> list[int]:
        """What a (re-)admission must prefill: prompt + generated so far."""
        return list(self.prompt) + list(self.tokens)


class Scheduler:
    def __init__(self, *, batch: int, page_size: int, num_pages: int,
                 max_len: int):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.batch = batch
        self.max_len = max_len
        self.pages = PageAllocator(num_pages, page_size)
        self.nseq_pages = self.pages.pages_for(max_len)
        self.slots: list[Request | None] = [None] * batch
        self.queue: deque[Request] = deque()
        self._next_rid = 0
        self._admit_order = 0
        self._slot_age: list[int] = [0] * batch   # admission order per slot

    # ------------------------------------------------------------- requests
    def submit(self, prompt, max_new: int) -> int:
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"kv cache overflow: request needs "
                f"{len(prompt) + max_new} positions but "
                f"max_len={self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, prompt=prompt, max_new=max_new))
        return rid

    @property
    def running(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)

    # ------------------------------------------------------------ lifecycle
    def retire(self, slot: int) -> Request:
        """Slot finished (EOS / max_new): free its pages, open the slot."""
        req = self.slots[slot]
        assert req is not None, f"retire of empty slot {slot}"
        self.pages.release(req.rid)
        req.state = "done"
        self.slots[slot] = None
        return req

    def admit(self) -> list[tuple[int, Request]]:
        """FIFO-admit queued requests into free slots while pages last.
        Stops at the FIRST page shortfall (no queue jumping: a small later
        request must not starve a large earlier one). Returns the newly
        filled ``(slot, request)`` pairs; the engine prefills each."""
        placed = []
        for slot in range(self.batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            need = self.pages.pages_for(len(req.resume_prompt) + 1)
            if self.pages.alloc(req.rid, need) is None:
                break
            self.queue.popleft()
            req.state = "running"
            self.slots[slot] = req
            self._admit_order += 1
            self._slot_age[slot] = self._admit_order
            placed.append((slot, req))
        return placed

    def grow(self, slot: int) -> bool:
        """Ensure slot's next decode position has a page. Returns False on
        pool famine (caller should preempt and retry)."""
        req = self.slots[slot]
        assert req is not None
        # the last generated token is always PENDING (its KV not yet
        # written): the next decode writes at position len(resume) - 1
        pos = len(req.resume_prompt) - 1
        have = len(self.pages.owned(req.rid))
        need = self.pages.pages_for(pos + 1)
        if need <= have:
            return True
        return self.pages.alloc(req.rid, need - have) is not None

    def preempt_youngest(self, *, exclude: int | None = None) -> int | None:
        """Evict the most recently admitted running sequence: release its
        pages and requeue it at the FRONT (it keeps queue priority and its
        generated tokens; re-admission re-prefills them). Returns the freed
        slot, or None if nothing can be evicted."""
        candidates = [i for i in self.running if i != exclude]
        if not candidates:
            return None
        slot = max(candidates, key=lambda i: self._slot_age[i])
        req = self.slots[slot]
        self.pages.release(req.rid)
        req.state = "queued"
        req.preempted += 1
        self.slots[slot] = None
        self.queue.appendleft(req)
        return slot
