"""Shared tune-probe shapes + winner adoption for the launchers and CLI.

One place derives the kernel-op shapes a workload will hit — serving
(prefill flash + decode flash + fused LM head at batch rows) and training
(causal flash at the train sequence + fused-CE LM head at ``B*(S-1)`` rows)
— as ``{op_name: (ShapeDtypeStruct args, params)}`` probe dicts, and one
place (:func:`adopt`) turns persisted ``op.tune`` winners for those probes
into updated op defaults, keyed by workload kind. Consumers:

  * ``tuning.adopt(cfg, shapes, kind=...)``  THE warmup surface — serve /
                                             train / mesh launchers (their
                                             old ``apply_tuned_winners``
                                             names are deprecated shims)
  * ``repro.serving.Engine``                 adopts flash_decode's winner
                                             as its page size
  * ``repro.tune_cli``                       materializes the probes as real
                                             arrays and runs the sweeps — the
                                             fleet-wide pre-tuning entry point

Probes are SHAPES ONLY (``jax.ShapeDtypeStruct``): ``Op.cached_winner`` is a
pure cache lookup, so adoption performs zero builds and zero timed sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import fit_block

__all__ = ["adopt", "serving_probes", "train_probes", "mesh_probes",
           "adopt_winners"]


def _head_dims(cfg):
    h = getattr(cfg, "n_heads", 0)
    hk = getattr(cfg, "n_kv_heads", 0) or h
    hd = getattr(cfg, "resolved_head_dim", 0)
    return h, hk, hd


def _lm_head_shapes(cfg, rows: int):
    from repro.models import pad_vocab

    d = cfg.d_model
    vpad = pad_vocab(cfg.vocab_size)
    dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))
    probe = jax.ShapeDtypeStruct
    return (probe((rows, d), dtype), probe((d, vpad), dtype)), vpad


def serving_probes(cfg, batch: int, prompt_len: int, max_len: int) -> dict:
    """Probe shapes for one serving config: prefill attention, single-token
    decode attention, and the fused last-token LM head (``batch`` rows)."""
    probe = jax.ShapeDtypeStruct
    probes = {}
    h, hk, hd = _head_dims(cfg)
    dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))
    window = getattr(cfg, "window", None)
    if h and hd:  # latent-attention archs (MLA) have no flash probes here
        probes["flash_attention"] = (
            (probe((batch, h, prompt_len, hd), dtype),
             probe((batch, hk, prompt_len, hd), dtype),
             probe((batch, hk, prompt_len, hd), dtype)),
            dict(causal=True, window=window))
        # windowed archs probe too: rolling-window decode runs the unified
        # kernel (slot_pos input tile) — the cache holds min(max_len, window)
        m = min(max_len, window) if window else max_len
        probes["flash_decode"] = (
            (probe((batch, h, 1, hd), dtype),
             probe((batch, hk, m, hd), dtype),
             probe((batch, hk, m, hd), dtype)),
            dict(window=window))
        if not window:
            # paged decode (the continuous-batching engine path). The op has
            # no kernel-side sweep — the page size IS the pool layout, and
            # the engine adopts flash_decode's tuned block_kv as its page
            # size — but the probe keeps the engine shapes visible to the
            # CLI / analyze sweeps. Block-table params are REAL arrays (the
            # op's pre hook reads them), sized for ``batch`` full sequences.
            page = fit_block(512, max_len)
            nsp = max_len // page
            npages = batch * nsp + 1          # + the reserved null page 0
            tab = (np.arange(batch * nsp, dtype=np.int32)
                   .reshape(batch, nsp) + 1)
            probes["flash_decode_paged"] = (
                (probe((batch, h, 1, hd), dtype),
                 probe((npages, hk, page, hd), dtype),
                 probe((npages, hk, page, hd), dtype)),
                dict(block_table=tab,
                     kv_len=np.full((batch,), max_len, np.int32)))
    (x, w), _ = _lm_head_shapes(cfg, batch)
    probes["lm_head_logits"] = ((x, w), dict(vocab=cfg.vocab_size))
    return probes


def train_probes(cfg, global_batch: int, seq_len: int) -> dict:
    """Probe shapes for one train-step config: causal attention at the full
    sequence and the fused-CE LM head at ``B * (S - 1)`` rows."""
    probe = jax.ShapeDtypeStruct
    probes = {}
    h, hk, hd = _head_dims(cfg)
    dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))
    if h and hd:
        probes["flash_attention"] = (
            (probe((global_batch, h, seq_len, hd), dtype),
             probe((global_batch, hk, seq_len, hd), dtype),
             probe((global_batch, hk, seq_len, hd), dtype)),
            dict(causal=True, window=getattr(cfg, "window", None)))
    rows = global_batch * max(seq_len - 1, 1)
    (x, w), _ = _lm_head_shapes(cfg, rows)
    labels = jax.ShapeDtypeStruct((rows, 1), jnp.int32)
    probes["lm_head_ce"] = ((x, w, labels), dict(vocab=cfg.vocab_size))
    return probes


def mesh_probes(cfg, batch: int, prompt_len: int, *, shards: int,
                mesh_axis: str = "model") -> dict:
    """Probe shapes for ring-attention prefill over ``shards`` devices.

    Under ``shard_map`` every shard runs the PER-SHARD kernel — sequence
    length ``prompt_len // shards`` — so that is the shape to tune;
    ``ring_steps`` rides in the params, keeping the persisted cache key (and
    the spec's declared shard extent) distinct per mesh size."""
    probe = jax.ShapeDtypeStruct
    probes = {}
    h, hk, hd = _head_dims(cfg)
    dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))
    if shards < 1 or prompt_len % shards:
        raise ValueError(
            f"mesh_probes: shards={shards} does not divide prompt_len="
            f"{prompt_len}")
    loc = prompt_len // shards
    if h and hd:
        probes["ring_flash"] = (
            (probe((batch, h, loc, hd), dtype),
             probe((batch, hk, loc, hd), dtype),
             probe((batch, hk, loc, hd), dtype)),
            dict(causal=True, window=getattr(cfg, "window", None),
                 ring_steps=shards, mesh_axis=mesh_axis))
    return probes


def _winner_overflows(op, args, params, winner) -> bool:
    """True when a persisted winner's static VMEM footprint exceeds the
    current ``$REPRO_VMEM_BUDGET`` at these probe shapes — a stale entry
    tuned under other constraints must not be adopted (the very first build
    would raise VMEM_OVERFLOW). Best-effort: unmodelable winners adopt."""
    from types import SimpleNamespace

    from repro.core.analyze import vmem_budget, vmem_footprint

    try:
        # sweep keys may be op params (flash_decode's block_kv) or bare
        # defines (matmul's bm/bn/bk): route each winner key accordingly
        pwin = {k: v for k, v in winner.items() if k in op.defaults}
        _, _, params = op._resolve(dict(params, **pwin))
        _, defines, _ = op._prepare(tuple(args), params)
        spec = op.builder(SimpleNamespace(**dict(defines, **winner)))
        return vmem_footprint(spec)[0] > vmem_budget()
    except Exception:
        return False


def adopt(cfg, shapes: dict, *, kind: str) -> dict:
    """THE adoption surface: build ``kind``'s probe shapes and adopt their
    persisted tune winners into the op defaults. ``shapes`` carries the
    workload dims by name:

      kind="serve"  ->  batch, prompt_len, max_len
      kind="train"  ->  global_batch, seq_len
      kind="mesh"   ->  batch, prompt_len, shards [, mesh_axis]

    Replaces the three per-launcher ``apply_tuned_winners`` wrappers (which
    now delegate here, with deprecation notes). Returns the adopted
    ``{op_name: winner_defines}``."""
    if kind == "serve":
        probes = serving_probes(cfg, shapes["batch"], shapes["prompt_len"],
                                shapes["max_len"])
    elif kind == "train":
        probes = train_probes(cfg, shapes["global_batch"], shapes["seq_len"])
    elif kind == "mesh":
        probes = mesh_probes(cfg, shapes["batch"], shapes["prompt_len"],
                             shards=shapes["shards"],
                             mesh_axis=shapes.get("mesh_axis", "model"))
    else:
        raise ValueError(f"adopt: kind must be serve|train|mesh, got {kind!r}")
    return adopt_winners(probes)


def adopt_winners(probes: dict) -> dict:
    """Update op defaults from persisted ``op.tune`` winners for ``probes``
    (``$REPRO_CACHE_DIR``) — a pure cache lookup via the op registry, no
    builds, no timed sweeps (winners only pay a cheap static VMEM-footprint
    check, so a stale oversized winner can't poison the defaults). Returns
    ``{op_name: winner_defines}``."""
    import repro.kernels  # noqa: F401 — registers the op families
    from repro.core import registered_ops

    applied = {}
    for name, (args, params) in probes.items():
        op = registered_ops().get(name)
        if op is None:
            continue
        try:
            winner = op.cached_winner(args, **params)
        except Exception:
            continue  # probe shape invalid for this arch: no winner to adopt
        if winner and _winner_overflows(op, args, params, winner):
            continue
        if winner:
            op.defaults.update(winner)
            applied[name] = winner
    return applied
