import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract roofline inputs from the compiled artifact.

The two lines above MUST run before any jax import (jax locks the device
count at first init); do not import this module from code that already
initialized jax with a different topology.

Per cell this records:
  - compiled.memory_analysis() / cost_analysis() (per-partition program)
  - collective bytes parsed from the post-SPMD HLO (operand sizes of
    all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute)
  - analytic param/optimizer/cache bytes-per-device from the shardings
Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json (resumable).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral_8x22b --shape train_4k
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, input_specs, shape_applicable
from repro.models import LM
from repro.optim import AdamW, WarmupCosine
from repro.parallel import rules as R
from repro.parallel.steps import (build_prefill_step, build_serve_step,
                                  build_train_step, make_shardings)
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# FSDP threshold: TP-only param bytes/device above this switch on data-axis
# weight sharding (ZeRO-3 via GSPMD). v5e has 16 GB HBM.
FSDP_THRESHOLD_BYTES = int(2.5 * 2 ** 30)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)",
    re.M)
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) +
    r")(-start)?\(([^)]*)\)")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in post-SPMD HLO.

    Operands appear as bare %names in the optimized module, so a symbol
    table of value sizes is built from every definition first. ``-done``
    halves of async collectives are not counted (their operand is the
    ``-start`` tuple — counting both would double-count)."""
    sizes = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, ty = m.group(1), m.group(2)
        b = sum(_shape_bytes(sm.group(1), sm.group(2))
                for sm in _SHAPE_RE.finditer(ty))
        sizes[name] = b
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        kind, operands = m.group(1), m.group(3)
        counts[kind] += 1
        for nm in _NAME_RE.finditer(operands):
            out[kind] += sizes.get(nm.group(1), 0)
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = ("temp_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or k in ("utilization",))}
    except Exception as e:
        return {"error": str(e)}


def _cfg_with_counts(cfg, counts):
    """Rebuild cfg so build_program() yields the given per-stack counts."""
    import dataclasses

    from repro.models import build_program
    prog = build_program(cfg)
    kinds = [s.kind for s in prog]
    if kinds[0] == "zamba_group":
        g = counts[0]
        t = counts[1] if len(counts) > 1 else 0
        return dataclasses.replace(cfg, n_layers=cfg.shared_attn_every * g + t)
    if kinds == ["dense", "moe"]:
        return dataclasses.replace(cfg, first_dense_layers=counts[0],
                                   n_layers=counts[0] + counts[1])
    return dataclasses.replace(cfg, n_layers=counts[0])


def _compile_once(cfg, shape, mesh, *, scan_layers, moe_dispatch, remat,
                  zero1, want_memory=False, ce_chunks=1):
    model = LM(cfg, remat=(remat if shape.kind == "train" else "none"),
               moe_dispatch=moe_dispatch, scan_layers=scan_layers,
               ce_chunks=(ce_chunks if shape.kind == "train" else 1))
    specs = input_specs(cfg, shape)
    _, pspecs_tp, _, params_sds = make_shardings(model, mesh, fsdp=False)
    tp_bytes = R.spec_bytes_per_device(params_sds, pspecs_tp, mesh)
    fsdp = tp_bytes > FSDP_THRESHOLD_BYTES

    t0 = time.time()
    if shape.kind == "train":
        optimizer = AdamW(schedule=WarmupCosine())
        step_fn, sh = build_train_step(model, optimizer, mesh, zero1=zero1,
                                       fsdp=fsdp, batch_shapes=specs)
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        lowered = step_fn.lower(params_sds, opt_sds, specs)
    elif shape.kind == "prefill":
        p = cfg.num_prefix_embeddings if cfg.frontend else 0
        step_fn, sh = build_prefill_step(model, mesh, batch=shape.global_batch,
                                         max_len=shape.seq_len + p,
                                         batch_shapes=specs, fsdp=fsdp)
        lowered = step_fn.lower(params_sds, specs)
    else:  # decode
        step_fn, sh = build_serve_step(model, mesh, batch=shape.global_batch,
                                       max_len=shape.seq_len)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        lowered = step_fn.lower(params_sds, cache_sds, specs["tokens"])
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    hlo = compiled.as_text()
    ca = _cost_analysis(compiled)
    rec = {
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "collectives": collective_bytes(hlo),
        "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        "fsdp": fsdp,
        "param_bytes_per_device": int(R.spec_bytes_per_device(
            params_sds, sh["pspecs"], mesh)),
        "n_params": int(sum(x.size for x in jax.tree.leaves(params_sds))),
        "hlo_bytes": len(hlo),
    }
    if want_memory:
        rec["memory_analysis"] = _mem_analysis(compiled)
        rec["cost_analysis_raw"] = ca
    return rec


def _lin(base, var, real, base_c):
    return var - base if real > base_c else 0.0


def _extrapolate(base, variants, real_counts, base_counts):
    """total = base + sum_i (real_i - base_i) * (variant_i - base)."""
    def combine(get):
        total = get(base)
        for var, real, bc in zip(variants, real_counts, base_counts):
            if var is not None and real > bc:
                total += (real - bc) * (get(var) - get(base))
        return total

    out = {
        "flops": combine(lambda r: r["flops"]),
        "bytes_accessed": combine(lambda r: r["bytes_accessed"]),
        "collective_total_bytes": combine(
            lambda r: r["collectives"]["total_bytes"]),
        "collective_bytes": {},
        "collective_counts": {},
    }
    for k in _COLLECTIVES:
        out["collective_bytes"][k] = combine(
            lambda r, k=k: r["collectives"]["bytes"][k])
        out["collective_counts"][k] = combine(
            lambda r, k=k: r["collectives"]["counts"][k])
    return out


def lower_cell(arch: str, shape_name: str, mesh_kind: str, *,
               moe_dispatch: str = "einsum", remat: str = "full",
               zero1: bool = True, extra_tag: str = "", ce_chunks: int = 1):
    from repro.models import build_program

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    kw = dict(moe_dispatch=moe_dispatch, remat=remat, zero1=zero1,
              ce_chunks=ce_chunks)

    # 1) the REQUIRED full compile (scan-over-layers, real depth) — proves
    #    lower+compile succeeds and provides the memory analysis.
    full = _compile_once(cfg, shape, mesh, scan_layers=True,
                         want_memory=True, **kw)

    # 2) per-stack cost extrapolation on small UNROLLED variants (HLO cost
    #    analysis counts while bodies once; see module doc).
    real_counts = [s.n for s in build_program(cfg)]
    base_counts = [1] * len(real_counts)
    base = _compile_once(_cfg_with_counts(cfg, base_counts), shape, mesh,
                         scan_layers=False, **kw)
    variants = []
    for i, rc in enumerate(real_counts):
        if rc > base_counts[i]:
            vc = list(base_counts)
            vc[i] += 1
            variants.append(_compile_once(_cfg_with_counts(cfg, vc), shape,
                                          mesh, scan_layers=False, **kw))
        else:
            variants.append(None)
    extrap = _extrapolate(base, variants, real_counts, base_counts)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "chips": mesh.size, "skipped": False,
        "fsdp": full["fsdp"], "zero1": zero1 and shape.kind == "train",
        "moe_dispatch": moe_dispatch, "remat": remat, "tag": extra_tag,
        "lower_s": full["lower_s"], "compile_s": full["compile_s"],
        "cost_analysis": full["cost_analysis_raw"],
        "memory_analysis": full["memory_analysis"],
        "collectives": full["collectives"],
        "extrapolated": extrap,
        "param_bytes_per_device": full["param_bytes_per_device"],
        "hlo_bytes": full["hlo_bytes"],
        "n_params": full["n_params"],
    }
    return rec


def attention_component(arch: str, shape_name: str, mesh_kind: str):
    """Measure the standalone attention chain (the part the Pallas flash
    kernel replaces) at the cell's per-layer shapes on the production mesh,
    plus the analytic flash-kernel substitute (§Perf adjustment):

      flash_bytes: passes over q,k,v,o only (VMEM-resident chain);
      flash_flops: mask-fraction * 4*B*H*Sq*Skv*d (skipped blocks not issued),
                   x(1 fwd) inference, x(3.5: fwd+bwd+recompute) train.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.flash_attention.ref import mha_chunked, mha_ref
    from repro.parallel.context import Rules, use_rules
    from repro.parallel.steps import axis_names

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.has_attention:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "component": "attention", "skipped": True,
                "reason": "attention-free arch"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    batch_axes, m = axis_names(mesh)
    b = shape.global_batch
    s = shape.seq_len
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.attn_type == "mla":
        hk, hd = h, cfg.qk_nope_dim + cfg.qk_rope_dim
    dt = jnp.dtype(cfg.dtype)
    msize = mesh.shape[m]
    import math as _math
    bsize = _math.prod(mesh.shape[a] for a in batch_axes)
    b_ax = batch_axes if b % bsize == 0 else None
    head_ax = m if h % msize == 0 else None
    q_sh = NamedSharding(mesh, P(b_ax, head_ax, None, None))
    # kv layout mirrors the cache rules: heads if divisible, else SEQ over
    # the model axis (decode caches live in that layout — §Perf it2)
    if hk % msize == 0:
        kv_sh = NamedSharding(mesh, P(b_ax, m, None, None))
    elif shape.kind == "decode" and s % msize == 0:
        kv_sh = NamedSharding(mesh, P(b_ax, None, m, None))
    else:
        kv_sh = NamedSharding(mesh, P(b_ax, None, None, None))
    qs = jax.ShapeDtypeStruct((b, h, s, hd), dt, sharding=q_sh)
    ks = jax.ShapeDtypeStruct((b, hk, s, hd), dt, sharding=kv_sh)
    rules = Rules(batch_axes=batch_axes, model_axis=m, mesh=mesh)

    attn = mha_chunked if s > 8192 else mha_ref
    sq = 1 if shape.kind == "decode" else s
    qs = jax.ShapeDtypeStruct((b, h, sq, hd), dt, sharding=q_sh)

    if shape.kind == "train":
        def fn(q, k, v):
            with use_rules(rules):
                out, vjp = jax.vjp(
                    lambda q_, k_, v_: attn(q_, k_, v_, causal=True,
                                            window=cfg.window), q, k, v)
                return vjp(out)
        flash_factor_flops, flash_factor_bytes = 3.5, 3.0
    else:
        def fn(q, k, v):
            with use_rules(rules):
                return attn(q, k, v, causal=True, window=cfg.window)
        flash_factor_flops, flash_factor_bytes = 1.0, 1.0

    t0 = time.time()
    compiled = jax.jit(fn).lower(qs, ks, ks).compile()
    ca = _cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())

    # analytic flash substitute (global, then per-chip by mesh.size)
    skv_eff = min(s, cfg.window) if cfg.window else s
    if shape.kind == "decode":
        mask_frac = 1.0  # full-cache decode row
    else:
        mask_frac = min(1.0, cfg.window / s) if cfg.window else 0.5
    flash_flops_global = mask_frac * 4.0 * b * h * sq * skv_eff * hd \
        * flash_factor_flops
    qkvo_bytes = (2 * b * h * sq * hd + 2 * b * hk * skv_eff * hd) * dt.itemsize
    flash_bytes_global = qkvo_bytes * flash_factor_bytes

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "component": "attention", "skipped": False,
        "kind": shape.kind, "chips": mesh.size,
        "compile_s": round(time.time() - t0, 1),
        "ref_flops": ca.get("flops", 0.0),
        "ref_bytes": ca.get("bytes accessed", 0.0),
        "ref_collective_bytes": coll["total_bytes"],
        "flash_flops_per_chip": flash_flops_global / mesh.size,
        "flash_bytes_per_chip": flash_bytes_global / mesh.size,
        "n_attention_layers": _n_attn_layers(cfg),
    }


def _n_attn_layers(cfg) -> int:
    if cfg.shared_attn_every:
        return cfg.n_layers // cfg.shared_attn_every
    if not cfg.has_attention:
        return 0
    return cfg.n_layers


def ssm_component(arch: str, shape_name: str, mesh_kind: str):
    """Measure the standalone chunked SSM scan (what the fused Pallas
    ssm_scan kernel replaces) at the cell's per-layer shapes. The fused
    kernel's HBM traffic is x/dt/B/C in + y out (+ state): nothing
    (B, L, D, N)-shaped ever leaves VMEM."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.layers.mamba import _chunked_scan_jnp, ssd_chunked
    from repro.parallel.context import Rules, use_rules
    from repro.parallel.steps import axis_names

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.ssm_type or shape.kind == "decode":
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "component": "ssm", "skipped": True,
                "reason": "no ssm scan / decode is single-step"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    batch_axes, m = axis_names(mesh)
    import math as _math
    bsize = _math.prod(mesh.shape[a] for a in batch_axes)
    b = shape.global_batch
    s = shape.seq_len
    b_ax = batch_axes if b % bsize == 0 else None
    di = cfg.resolved_d_inner
    n = cfg.ssm_state
    msize = mesh.shape[m]
    di_ax = m if di % msize == 0 else None
    dt32 = jnp.float32

    rules = Rules(batch_axes=batch_axes, model_axis=m, mesh=mesh)
    sh = lambda spec: NamedSharding(mesh, spec)
    if cfg.ssm_type == "mamba1":
        args = (
            jax.ShapeDtypeStruct((b, s, di), jnp.dtype(cfg.dtype),
                                 sharding=sh(P(b_ax, None, di_ax))),   # x
            jax.ShapeDtypeStruct((b, s, di), jnp.dtype(cfg.dtype),
                                 sharding=sh(P(b_ax, None, di_ax))),   # dt
            jax.ShapeDtypeStruct((di, n), dt32, sharding=sh(P(di_ax, None))),
            jax.ShapeDtypeStruct((b, s, n), jnp.dtype(cfg.dtype),
                                 sharding=sh(P(b_ax, None, None))),    # B
            jax.ShapeDtypeStruct((b, s, n), jnp.dtype(cfg.dtype),
                                 sharding=sh(P(b_ax, None, None))),    # C
            jax.ShapeDtypeStruct((di,), dt32, sharding=sh(P(di_ax))),  # D
        )
        core = lambda *a: _chunked_scan_jnp(*a)[0]
        io_elems = 3 * b * s * di + 2 * b * s * n + b * di * n
    else:  # mamba2 / SSD
        p = cfg.ssm_head_dim
        h = di // p
        h_ax = m if h % msize == 0 else None
        args = (
            jax.ShapeDtypeStruct((b, s, h, p), jnp.dtype(cfg.dtype),
                                 sharding=sh(P(b_ax, None, h_ax, None))),  # x
            jax.ShapeDtypeStruct((b, s, h), dt32,
                                 sharding=sh(P(b_ax, None, h_ax))),        # dt
            jax.ShapeDtypeStruct((h,), dt32, sharding=sh(P(h_ax))),        # A
            jax.ShapeDtypeStruct((b, s, n), jnp.dtype(cfg.dtype),
                                 sharding=sh(P(b_ax, None, None))),        # B
            jax.ShapeDtypeStruct((b, s, n), jnp.dtype(cfg.dtype),
                                 sharding=sh(P(b_ax, None, None))),        # C
        )
        core = lambda *a: ssd_chunked(*a)[0]
        io_elems = 2 * b * s * di + b * s * h + 2 * b * s * n + b * di * n

    if shape.kind == "train":
        def fn(*a):
            with use_rules(rules):
                out, vjp = jax.vjp(core, *a)
                return vjp(out)
        kernel_factor = 3.0
    else:
        def fn(*a):
            with use_rules(rules):
                return core(*a)
        kernel_factor = 1.0

    t0 = time.time()
    compiled = jax.jit(fn).lower(*args).compile()
    ca = _cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    itemsize = jnp.dtype(cfg.dtype).itemsize
    kernel_bytes_global = io_elems * itemsize * kernel_factor

    n_layers = cfg.n_layers if not cfg.shared_attn_every else cfg.n_layers
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "component": "ssm", "skipped": False,
        "kind": shape.kind, "chips": mesh.size,
        "compile_s": round(time.time() - t0, 1),
        "ref_flops": ca.get("flops", 0.0),
        "ref_bytes": ca.get("bytes accessed", 0.0),
        "ref_collective_bytes": coll["total_bytes"],
        # the fused kernel issues the same FLOPs (same math) — only bytes move
        "flash_flops_per_chip": ca.get("flops", 0.0),
        "flash_bytes_per_chip": kernel_bytes_global / mesh.size,
        "n_attention_layers": n_layers,  # layers carrying the scan
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--component", default=None,
                    choices=[None, "attention", "ssm"])
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--moe-dispatch", default="einsum")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--ce-chunks", type=int, default=1)
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, (
        "dryrun must own the 512 fake devices; do not pre-initialize jax")
    os.makedirs(args.out, exist_ok=True)

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                comp = f"__{args.component}" if args.component else ""
                fn = os.path.join(args.out,
                                  f"{arch}__{shape}__{mesh_kind}{comp}{tag}.json")
                if os.path.exists(fn) and not args.force:
                    print(f"[dryrun] skip existing {fn}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_kind}{comp} ...",
                      flush=True)
                try:
                    if args.component == "attention":
                        rec = attention_component(arch, shape, mesh_kind)
                        rec["tag"] = args.tag
                    elif args.component == "ssm":
                        rec = ssm_component(arch, shape, mesh_kind)
                        rec["tag"] = args.tag
                    else:
                        rec = lower_cell(arch, shape, mesh_kind,
                                         moe_dispatch=args.moe_dispatch,
                                         remat=args.remat, extra_tag=args.tag,
                                         ce_chunks=args.ce_chunks)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[dryrun]   FAILED: {type(e).__name__}: {e}",
                          flush=True)
                with open(fn + ".tmp", "w") as f:
                    json.dump(rec, f, indent=1)
                os.replace(fn + ".tmp", fn)
                if rec.get("skipped"):
                    print(f"[dryrun]   skipped: {rec['reason']}", flush=True)
                elif "error" in rec:
                    pass
                elif rec.get("component"):
                    print(f"[dryrun]   ok: ref_bytes {rec['ref_bytes']:.3e} "
                          f"flash_bytes {rec['flash_bytes_per_chip']:.3e}",
                          flush=True)
                else:
                    ex = rec["extrapolated"]
                    print(f"[dryrun]   ok: compile {rec['compile_s']}s "
                          f"flops/dev {ex['flops']:.3e} "
                          f"coll/dev {ex['collective_total_bytes']:.3e}B",
                          flush=True)
                results.append(rec)
    bad = [r for r in results if "error" in r]
    print(f"[dryrun] done: {len(results)} cells, {len(bad)} failures")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
