"""Production mesh builders (functions, never module-level constants — the
import must not touch jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh adds a pure-DP 'pod'
    axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / single host)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
