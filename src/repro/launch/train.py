"""Fault-tolerant training launcher.

Integrates: sharded train step, deterministic host-sharded data with
prefetch, async atomic checkpointing + resume, straggler watchdog, failure
injection with automatic restore-retry, and elastic restart hooks.

CLI (CPU-sized by default):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --reduced \
      --steps 50 --global-batch 8 --seq-len 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced as reduce_cfg
from repro.data import Prefetcher, SyntheticLMData
from repro.models import LM
from repro.optim import AdamW, WarmupCosine
from repro.parallel.steps import build_train_step
from repro.runtime import ChaosError, FailureInjector, StepWatchdog
from repro.launch.mesh import make_local_mesh

__all__ = ["TrainLoop", "apply_tuned_winners", "main", "validate_host_batch"]


def validate_host_batch(tokens, vocab_size: int):
    """Reject out-of-range token ids while the batch is still HOST data.

    The jitted train step sees tracers, so ``LM.loss``'s label guard cannot
    fire there — this is the host-side complement: a label >= vocab_size (or
    negative) would otherwise silently train against padded-vocab logits."""
    t = np.asarray(tokens)
    if t.size == 0:
        return
    lo, hi = int(t.min()), int(t.max())
    if lo < 0 or hi >= vocab_size:
        raise ValueError(
            f"batch tokens out of range [{lo}, {hi}] for vocab_size="
            f"{vocab_size}: the jitted CE would silently train on padded-"
            "vocab logits; fix the data pipeline")


def apply_tuned_winners(cfg, global_batch: int, seq_len: int):
    """DEPRECATED shim: use ``repro.launch.tuning.adopt(cfg, shapes,
    kind="train")`` — one adoption surface now covers the serve/train/mesh
    probe families. Kept for callers of the old per-launcher name."""
    from repro.launch.tuning import adopt

    return adopt(cfg, dict(global_batch=global_batch, seq_len=seq_len),
                 kind="train")


@dataclasses.dataclass
class TrainLoop:
    """Restartable training loop with recovery; returns loss history."""

    model: LM
    mesh: object
    global_batch: int
    seq_len: int
    steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    peak_lr: float = 3e-3
    seed: int = 0
    injector: FailureInjector | None = None
    max_retries: int = 3
    log_every: int = 10
    verbose: bool = True

    def run(self):
        model, cfg = self.model, self.model.cfg
        optimizer = AdamW(schedule=WarmupCosine(
            peak_lr=self.peak_lr, warmup_steps=max(self.steps // 20, 5),
            total_steps=self.steps))
        batch_shapes = {"tokens": jax.ShapeDtypeStruct(
            (self.global_batch, self.seq_len), jnp.int32)}
        if cfg.frontend:
            batch_shapes["prefix_embeddings"] = jax.ShapeDtypeStruct(
                (self.global_batch, cfg.num_prefix_embeddings, cfg.d_model),
                jnp.dtype(cfg.dtype))

        # adopt persisted autotune winners BEFORE the step traces
        tuned = apply_tuned_winners(cfg, self.global_batch, self.seq_len)
        if tuned and self.verbose:
            print(f"[train] adopted persisted tune winners: {tuned}")

        step_fn, shardings = build_train_step(model, optimizer, self.mesh,
                                              batch_shapes=batch_shapes)
        data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=self.seq_len,
                               global_batch=self.global_batch, seed=self.seed)
        mgr = CheckpointManager(self.ckpt_dir) if self.ckpt_dir else None
        watchdog = StepWatchdog(absolute_deadline_s=None)

        def fresh_state():
            params = model.init(jax.random.PRNGKey(self.seed))
            params = jax.device_put(params, shardings["params"])
            opt = optimizer.init(params)
            opt = jax.device_put(opt, shardings["opt"])
            return params, opt, 0

        def restore_state():
            template = jax.eval_shape(
                lambda: (model.init(jax.random.PRNGKey(self.seed)),))[0]
            opt_t = jax.eval_shape(lambda: optimizer.init(template))
            step, (params, opt), _ = mgr.restore(
                (template, opt_t),
                shardings=(shardings["params"], shardings["opt"]))
            return params, opt, step

        if mgr and mgr.latest_step() is not None:
            params, opt_state, start = restore_state()
            if self.verbose:
                print(f"[train] resumed from step {start}")
        else:
            params, opt_state, start = fresh_state()

        history = []
        step = start
        retries = 0
        prefetch = Prefetcher(data, start_step=step)
        try:
            while step < self.steps:
                try:
                    if self.injector:
                        self.injector.maybe_fail(step)
                    dstep, host_batch = prefetch.next()
                    validate_host_batch(host_batch, cfg.vocab_size)
                    batch = {"tokens": jnp.asarray(host_batch)}
                    if cfg.frontend:
                        rs = np.random.Generator(np.random.Philox(
                            key=[self.seed * 2654435761 + 7, dstep]))
                        batch["prefix_embeddings"] = jnp.asarray(
                            rs.standard_normal((self.global_batch,
                                                cfg.num_prefix_embeddings,
                                                cfg.d_model), np.float32),
                            jnp.dtype(cfg.dtype))
                    batch = jax.device_put(batch, shardings["batch"])
                    watchdog.start()
                    params, opt_state, loss, metrics = step_fn(
                        params, opt_state, batch)
                    loss = float(loss)
                    watchdog.stop()
                    history.append(loss)
                    if self.verbose and step % self.log_every == 0:
                        print(f"[train] step {step:5d} loss {loss:8.4f} "
                              f"lr {float(metrics['lr']):.2e} "
                              f"gnorm {float(metrics['grad_norm']):.2f}")
                    step += 1
                    if mgr and step % self.ckpt_every == 0:
                        mgr.save(step, (params, opt_state),
                                 meta={"loss": loss})
                except ChaosError as e:
                    retries += 1
                    if self.verbose:
                        print(f"[train] {e} -> recovering "
                              f"(retry {retries}/{self.max_retries})")
                    if retries > self.max_retries:
                        raise
                    prefetch.close()
                    if mgr and mgr.latest_step() is not None:
                        params, opt_state, step = restore_state()
                    else:
                        params, opt_state, step = fresh_state()
                    prefetch = Prefetcher(data, start_step=step)
            if mgr:
                mgr.save(self.steps, (params, opt_state), async_=False,
                         meta={"loss": history[-1] if history else None})
                mgr.wait()
        finally:
            prefetch.close()
        return {"history": history, "params": params, "opt": opt_state,
                "straggler_flags": watchdog.flagged, "final_step": step,
                "tuned": tuned}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--data-axis", type=int, default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    from repro.core import ANALYZE_MODES, set_analysis_mode
    ap.add_argument("--analyze", default=None, choices=ANALYZE_MODES,
                    help="kernel static-analyzer strictness for every build "
                         "this run performs (default: $REPRO_ANALYZE or error)")
    args = ap.parse_args(argv)

    if args.analyze is not None:
        set_analysis_mode(args.analyze)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = LM(cfg, remat=args.remat)
    mesh = make_local_mesh(data=args.data_axis, model=args.model_axis)
    injector = FailureInjector(args.fail_at) if args.fail_at else None
    loop = TrainLoop(model=model, mesh=mesh, global_batch=args.global_batch,
                     seq_len=args.seq_len, steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     peak_lr=args.peak_lr, injector=injector)
    t0 = time.time()
    out = loop.run()
    h = out["history"]
    print(f"[train] done: {len(h)} steps in {time.time() - t0:.1f}s; "
          f"loss {h[0]:.3f} -> {h[-1]:.3f}")
    return out


if __name__ == "__main__":
    main()
