"""Batched serving: one prefill + jitted single-token decode steps.

Static batching with greedy sampling and EOS masking (per-slot continuous
batching requires per-sequence cache positions; the cache layout supports it
— slot refill is left to the cluster frontend). Reports tokens/s.

Warmup consults the persistent autotune cache (``$REPRO_CACHE_DIR``) through
the op registry: any attention op with a persisted ``op.tune`` winner for the
serving shapes gets its defaults updated, so the prefill/decode paths pick
the TUNED block sizes instead of the ops' hardcoded defaults. Run
``op.tune(...)`` once on the target hardware; every later serve adopts the
winners for free.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import LM
from repro.parallel.steps import build_prefill_step, build_serve_step
from repro.launch.mesh import make_local_mesh

__all__ = ["apply_tuned_winners", "generate", "main"]


def apply_tuned_winners(cfg, batch: int, prompt_len: int, max_len: int):
    """Serving warmup: adopt persisted ``op.tune`` winners for the attention
    AND fused LM-head ops at THESE serving shapes — a pure cache lookup via
    the op registry (``Op.cached_winner``), no builds or timed sweeps. Ops
    with a winner get their defaults updated in-process so every subsequent
    layer call uses the tuned block sizes. Probe shapes and the adoption
    loop live in :mod:`repro.launch.tuning` (shared with the train launcher
    and ``python -m repro.tune_cli``). Returns ``{op_name: winner}``."""
    from repro.launch.tuning import adopt_winners, serving_probes

    return adopt_winners(serving_probes(cfg, batch, prompt_len, max_len))


def generate(model: LM, params, prompts: np.ndarray, *, gen_tokens: int,
             mesh=None, eos_id: int | None = None, greedy: bool = True,
             rng=None, max_len: int | None = None):
    """prompts: (B, P) int32 -> (B, gen_tokens) int32 + stats.

    ``max_len`` sizes the kv caches (default: exactly prompt + generation).
    Overflowing a positional cache is an explicit host-side error here —
    the decode steps run jitted, where the layer-level write would silently
    clobber the last slot and attend corrupted history."""
    cfg = model.cfg
    b, plen = prompts.shape
    max_len = max_len or (plen + gen_tokens)
    if model.has_positional_cache and plen + gen_tokens > max_len:
        raise ValueError(
            f"kv cache overflow: prompt_len {plen} + gen_tokens {gen_tokens} "
            f"= {plen + gen_tokens} tokens but max_len={max_len}; raise "
            "max_len (rolling-window archs are exempt — their caches rotate)")
    mesh = mesh or make_local_mesh(model=1)

    # adopt persisted autotune winners BEFORE the steps trace: the traced
    # kernels bake in whatever block sizes the ops resolve to
    tuned = apply_tuned_winners(cfg, b, plen, max_len)

    prefill_fn, _ = build_prefill_step(model, mesh, batch=b, max_len=max_len)
    serve_fn, sh = build_serve_step(model, mesh, batch=b, max_len=max_len)

    t0 = time.time()
    logits, cache = prefill_fn(params, {"tokens": jnp.asarray(prompts)})
    cache = jax.device_put(cache, sh["cache"])
    prefill_s = time.time() - t0

    out = np.zeros((b, gen_tokens), np.int32)
    done = np.zeros((b,), bool)
    tok = np.asarray(model.greedy_token(logits))
    t0 = time.time()
    for t in range(gen_tokens):
        out[:, t] = np.where(done, eos_id if eos_id is not None else 0, tok)
        if eos_id is not None:
            done |= tok == eos_id
            if done.all():
                out = out[:, :t + 1]
                break
        logits, cache = serve_fn(params, cache, jnp.asarray(tok[:, None]))
        if greedy:
            tok = np.asarray(model.greedy_token(logits))
        else:
            rng, sub = jax.random.split(rng)
            tok = np.asarray(jax.random.categorical(
                sub, logits[..., :cfg.vocab_size]))
    decode_s = time.time() - t0
    n_gen = out.shape[1] * b
    return out, {"prefill_s": prefill_s, "decode_s": decode_s,
                 "tokens_per_s": n_gen / max(decode_s, 1e-9),
                 "tuned": tuned}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    from repro.core import ANALYZE_MODES, set_analysis_mode
    ap.add_argument("--analyze", default=None, choices=ANALYZE_MODES,
                    help="kernel static-analyzer strictness for every build "
                         "this run performs (default: $REPRO_ANALYZE or error)")
    args = ap.parse_args(argv)

    if args.analyze is not None:
        set_analysis_mode(args.analyze)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompts = np.random.RandomState(args.seed).randint(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    out, stats = generate(model, params, prompts, gen_tokens=args.gen)
    if stats["tuned"]:
        print(f"[serve] adopted persisted tune winners: {stats['tuned']}")
    print(f"[serve] batch={args.batch} prompt={args.prompt_len} "
          f"gen={out.shape[1]}: prefill {stats['prefill_s']:.2f}s, "
          f"{stats['tokens_per_s']:.1f} tok/s decode")
    print("[serve] first row:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
