"""Batched serving: continuous batching over paged KV caches.

``generate`` is a thin wrapper over :class:`repro.serving.Engine` — one
jitted one-token decode step runs over ``batch`` slots with per-slot
sequence positions, EOS retirement + mid-flight slot refill, and
preemption-by-eviction when the page pool runs dry. Models the paged path
cannot serve (MLA, rolling windows, SSM hybrids) fall back to
``_generate_static``, the classic static-batch loop — which doubles as the
per-sequence oracle the engine's bit-parity tests compare against.

Warmup consults the persistent autotune cache (``$REPRO_CACHE_DIR``)
through :func:`repro.launch.tuning.adopt`: any op with a persisted
``op.tune`` winner for the serving shapes gets its defaults updated, so
the prefill/decode paths pick the TUNED block sizes — and the engine
adopts ``flash_decode``'s tuned block as its page size.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import LM
from repro.parallel.steps import build_prefill_step, build_serve_step
from repro.launch.mesh import make_local_mesh

__all__ = ["apply_tuned_winners", "generate", "main"]


def apply_tuned_winners(cfg, batch: int, prompt_len: int, max_len: int):
    """DEPRECATED shim: use ``repro.launch.tuning.adopt(cfg, shapes,
    kind="serve")`` — one adoption surface now covers the serve/train/mesh
    probe families. Kept for callers of the old per-launcher name."""
    from repro.launch.tuning import adopt

    return adopt(cfg, dict(batch=batch, prompt_len=prompt_len,
                           max_len=max_len), kind="serve")


def _pad_token(eos_id, pad_id):
    """The token written after a sequence finishes. Explicit ``pad_id``
    wins; otherwise the EOS token when one is configured, else 0 (the old
    implicit behavior, now a documented contract)."""
    if pad_id is not None:
        return pad_id
    return eos_id if eos_id is not None else 0


def generate(model: LM, params, prompts: np.ndarray, *, gen_tokens: int,
             mesh=None, eos_id: int | None = None, greedy: bool = True,
             rng=None, max_len: int | None = None, temperature: float = 1.0,
             pad_id: int | None = None, engine: str = "auto",
             page_size: int | None = None, num_pages: int | None = None):
    """prompts: (B, P) int32 -> (B, <=gen_tokens) int32 + stats.

    Rows that finish early are padded with ``pad_id`` (default: ``eos_id``
    when set, else 0). Non-greedy sampling draws from
    ``softmax(logits / temperature)``.

    ``engine="auto"`` serves through the continuous-batching
    :class:`repro.serving.Engine` whenever the model is pageable;
    ``"static"`` forces the static-batch loop (``"paged"`` forces the
    engine and raises if the model can't page). ``page_size`` /
    ``num_pages`` pass through to the engine; ``max_len`` sizes the caches
    on both paths (default: exactly prompt + generation)."""
    b, plen = prompts.shape
    max_len = max_len or (plen + gen_tokens)
    if engine not in ("auto", "paged", "static"):
        raise ValueError(f"engine must be auto|paged|static, got {engine!r}")
    use_engine = (model.pageable if engine == "auto" else engine == "paged")
    if not use_engine:
        return _generate_static(model, params, prompts,
                                gen_tokens=gen_tokens, mesh=mesh,
                                eos_id=eos_id, greedy=greedy, rng=rng,
                                max_len=max_len, temperature=temperature,
                                pad_id=pad_id)
    from repro.serving import Engine

    eng = Engine(model, params, batch=b, max_len=max_len,
                 page_size=page_size, num_pages=num_pages, eos_id=eos_id,
                 greedy=greedy, temperature=temperature, rng=rng, mesh=mesh)
    t0 = time.time()
    rids = [eng.submit(prompts[i].tolist(), gen_tokens) for i in range(b)]
    results = eng.drain(max_steps=8 * (b * gen_tokens + b))
    decode_s = time.time() - t0
    pad = _pad_token(eos_id, pad_id)
    rows = [results[r] for r in rids]
    width = (max(len(r) for r in rows)
             if all(eos_id is not None and r and r[-1] == eos_id
                    for r in rows) else gen_tokens)
    out = np.full((b, width), pad, np.int32)
    n_gen = 0
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
        n_gen += len(r)
    preempted = sum(req.preempted for req in eng._requests.values())
    return out, {"prefill_s": 0.0, "decode_s": decode_s,
                 "tokens_per_s": n_gen / max(decode_s, 1e-9),
                 "tuned": {}, "engine": True, "preempted": preempted,
                 "page_size": eng.page_size}


def _generate_static(model: LM, params, prompts: np.ndarray, *,
                     gen_tokens: int, mesh=None, eos_id: int | None = None,
                     greedy: bool = True, rng=None,
                     max_len: int | None = None, temperature: float = 1.0,
                     pad_id: int | None = None):
    """Static batching: one prefill + a jitted decode step over a contiguous
    cache, every slot in lockstep. The engine's bit-parity oracle, and the
    serving path for non-pageable models."""
    cfg = model.cfg
    b, plen = prompts.shape
    max_len = max_len or (plen + gen_tokens)
    if model.has_positional_cache and plen + gen_tokens > max_len:
        raise ValueError(
            f"kv cache overflow: prompt_len {plen} + gen_tokens {gen_tokens} "
            f"= {plen + gen_tokens} tokens but max_len={max_len}; raise "
            "max_len (rolling-window archs are exempt — their caches rotate)")
    if not greedy and rng is None:
        rng = jax.random.PRNGKey(0)
    if temperature <= 0:
        raise ValueError(f"temperature must be > 0, got {temperature}")
    mesh = mesh or make_local_mesh(model=1)
    pad = _pad_token(eos_id, pad_id)

    # adopt persisted autotune winners BEFORE the steps trace: the traced
    # kernels bake in whatever block sizes the ops resolve to
    tuned = apply_tuned_winners(cfg, b, plen, max_len)

    prefill_fn, _ = build_prefill_step(model, mesh, batch=b, max_len=max_len)
    serve_fn, sh = build_serve_step(model, mesh, batch=b, max_len=max_len,
                                    greedy=greedy)

    t0 = time.time()
    logits, cache = prefill_fn(params, {"tokens": jnp.asarray(prompts)})
    cache = jax.device_put(cache, sh["cache"])
    prefill_s = time.time() - t0

    out = np.zeros((b, gen_tokens), np.int32)
    done = np.zeros((b,), bool)
    tok = np.asarray(model.greedy_token(logits))
    t0 = time.time()
    for t in range(gen_tokens):
        out[:, t] = np.where(done, pad, tok)
        if eos_id is not None:
            done |= tok == eos_id
            if done.all():
                out = out[:, :t + 1]
                break
        if greedy:
            nxt, logits, cache = serve_fn(params, cache,
                                          jnp.asarray(tok[:, None]))
            tok = np.asarray(nxt)
        else:
            logits, cache = serve_fn(params, cache, jnp.asarray(tok[:, None]))
            rng, sub = jax.random.split(rng)
            tok = np.asarray(jax.random.categorical(
                sub, logits[..., :cfg.vocab_size] / temperature))
    decode_s = time.time() - t0
    n_gen = out.shape[1] * b
    return out, {"prefill_s": prefill_s, "decode_s": decode_s,
                 "tokens_per_s": n_gen / max(decode_s, 1e-9),
                 "tuned": tuned, "engine": False}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "paged", "static"))
    from repro.core import ANALYZE_MODES, set_analysis_mode
    ap.add_argument("--analyze", default=None, choices=ANALYZE_MODES,
                    help="kernel static-analyzer strictness for every build "
                         "this run performs (default: $REPRO_ANALYZE or error)")
    args = ap.parse_args(argv)

    if args.analyze is not None:
        set_analysis_mode(args.analyze)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompts = np.random.RandomState(args.seed).randint(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    out, stats = generate(model, params, prompts, gen_tokens=args.gen,
                          engine=args.engine)
    if stats.get("tuned"):
        print(f"[serve] adopted persisted tune winners: {stats['tuned']}")
    path = "paged-engine" if stats["engine"] else "static"
    print(f"[serve] {path} batch={args.batch} prompt={args.prompt_len} "
          f"gen={out.shape[1]}: prefill {stats['prefill_s']:.2f}s, "
          f"{stats['tokens_per_s']:.1f} tok/s decode")
    print("[serve] first row:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
