from .pipeline import Prefetcher, SyntheticLMData, TextLMData, make_corpus  # noqa: F401
