"""Deterministic, host-sharded data pipelines.

Batches are a pure function of (seed, step, host) — counter-based Philox
bits, no pipeline state to checkpoint beyond the step counter, and every
host reads a disjoint slice of the global batch (the standard multi-host
JAX input contract). ``Prefetcher`` overlaps host batch synthesis with
device compute.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["SyntheticLMData", "TextLMData", "Prefetcher", "make_corpus"]


class SyntheticLMData:
    """Markov-chain token stream: learnable structure, fully deterministic."""

    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, num_hosts: int = 1, host_id: int = 0,
                 order_strength: float = 0.9):
        assert global_batch % num_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        # fixed sparse transition structure (same on every host)
        rs = np.random.RandomState(seed)
        self.next_tok = rs.randint(0, vocab_size, size=(vocab_size, 4))
        self.p_follow = order_strength

    def batch(self, step: int) -> np.ndarray:
        bits = np.random.Generator(np.random.Philox(
            key=[self.seed * 2654435761 + self.host_id, step]))
        b, s = self.local_batch, self.seq
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = bits.integers(0, self.vocab, b)
        follow = bits.random((b, s)) < self.p_follow
        choice = bits.integers(0, 4, (b, s))
        rand = bits.integers(0, self.vocab, (b, s))
        for t in range(1, s):
            nxt = self.next_tok[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, rand[:, t])
        return toks


def make_corpus(n_chars: int = 200_000, seed: int = 0) -> bytes:
    """Generates a word-like synthetic corpus (for the byte-level pipeline)."""
    rs = np.random.RandomState(seed)
    words = ["occa", "kernel", "device", "memory", "mesh", "pallas", "tile",
             "lattice", "shard", "stream", "barrier", "vector", "tensor",
             "spectral", "galerkin", "stencil", "roofline", "pipeline"]
    out = []
    size = 0
    while size < n_chars:
        w = words[rs.randint(len(words))]
        out.append(w)
        size += len(w) + 1
    return (" ".join(out)).encode()[:n_chars]


class TextLMData:
    """Byte-level windows over a corpus, deterministic per (seed, step, host)."""

    def __init__(self, corpus: bytes, *, seq_len: int, global_batch: int,
                 seed: int = 0, num_hosts: int = 1, host_id: int = 0):
        assert global_batch % num_hosts == 0
        self.data = np.frombuffer(corpus, np.uint8)
        self.seq = seq_len
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        self.vocab = 256

    def batch(self, step: int) -> np.ndarray:
        bits = np.random.Generator(np.random.Philox(
            key=[self.seed * 2654435761 + self.host_id, 2 ** 32 + step]))
        starts = bits.integers(0, len(self.data) - self.seq - 1,
                               self.local_batch)
        return np.stack([self.data[s:s + self.seq] for s in starts]).astype(np.int32)


class Prefetcher:
    """Background-thread prefetch of ``source.batch(step)``."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                item = (step, self.source.batch(step))
            except Exception as e:  # propagate to the consumer, don't hang
                item = e
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.2)
                    break
                except queue.Full:
                    continue
            if isinstance(item, Exception):
                return
            step += 1

    def next(self):
        item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
