"""musicgen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

Backbone only per assignment: the text-conditioning frontend is a STUB
(input_specs() provides precomputed conditioning embeddings prepended as a
prefix; the paper's cross-attention conditioning is replaced by prefix
conditioning — recorded in DESIGN.md). MHA (kv == heads), sinusoidal pos.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    frontend="audio_stub", num_prefix_embeddings=16,
    pos_embed="sinusoidal",
)
