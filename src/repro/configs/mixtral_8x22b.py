"""mixtral-8x22b [arXiv:2401.04088; hf] — MoE 8 experts top-2, sliding window.

8 experts < model-axis 16 -> TP-MoE layout (expert d_ff sharded over "model",
experts stacked); see DESIGN.md §Arch-applicability.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mixtral_8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    window=4096, rope_theta=1000000.0,
    n_experts=8, n_experts_per_tok=2, moe_d_ff=16384,
)
