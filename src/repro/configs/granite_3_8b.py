"""granite-3-8b [hf:ibm-granite/granite-3.0-8b-base; hf] — dense GQA.

vocab 49155 is not divisible by the model axis; the embedding is padded to a
multiple of 256 by parallel.vocab (Megatron convention) — see DESIGN.md.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="granite_3_8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab_size=49155, head_dim=128,
    rope_theta=10000000.0,
)
