"""paligemma-3b [arXiv:2407.07726; hf] — SigLIP frontend STUB (precomputed
patch embeddings) + gemma-style MQA decoder (kv=1), prefix-LM attention over
image+prefix, GeGLU-ish SwiGLU d_ff 16384, vocab 257216."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="paligemma_3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=257216, head_dim=256,
    rope_theta=10000.0, embed_scale=True,
    frontend="vision_stub", num_prefix_embeddings=256, prefix_lm=True,
)
