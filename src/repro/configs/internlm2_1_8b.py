"""internlm2-1.8b [arXiv:2403.17297; hf] — dense GQA."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="internlm2_1_8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92544, head_dim=128,
    rope_theta=1000000.0,
)
