"""deepseek-v2-lite-16b [arXiv:2405.04434; hf] — MLA + MoE 64 routed top-6,
2 shared experts, first layer dense (d_ff 10944), expert d_ff 1408,
kv_lora_rank 512, qk rope/nope 64/128."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_v2_lite", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    attn_type="mla", kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128, rope_theta=10000.0,
    n_experts=64, n_experts_per_tok=6, n_shared_experts=2,
    moe_d_ff=1408, first_dense_layers=1,
)
