"""Architecture configs (assigned pool) + input-shape grid.

Each assigned architecture lives in its own module (``configs/<id>.py``) with
the exact public config; ``reduced()`` derives the CPU smoke-test variant.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "ArchConfig", "Shape", "SHAPES", "ARCHS", "get_config", "reduced",
    "input_specs", "shape_applicable",
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                    # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # attention
    attn_type: str = "gqa"          # gqa | mla | none
    head_dim: Optional[int] = None  # default d_model // n_heads
    window: Optional[int] = None    # sliding-window size (mixtral)
    rope_theta: float = 500000.0
    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024      # GShard dispatch group (tokens)
    # SSM
    ssm_type: str = ""              # mamba1 | mamba2
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0
    ssm_head_dim: int = 64          # mamba2
    dt_rank: int = 0                # mamba1 (0 => ceil(d_model/16))
    ssm_bcdt_norm: bool = False     # falcon-mamba: RMS-normalize dt/B/C
    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0
    # modality frontends (stubs per assignment)
    frontend: str = ""              # "" | audio_stub | vision_stub
    num_prefix_embeddings: int = 0  # patches / conditioning frames
    prefix_lm: bool = False         # bidirectional prefix (paligemma)
    pos_embed: str = "rope"         # rope | sinusoidal
    embed_scale: bool = False       # gemma-style sqrt(d_model) scaling
    # numerics
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def has_attention(self) -> bool:
        return self.attn_type != "none"

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.window is not None


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "llama3_2_1b", "internlm2_20b", "internlm2_1_8b", "granite_3_8b",
    "mixtral_8x22b", "deepseek_v2_lite", "musicgen_medium", "zamba2_7b",
    "falcon_mamba_7b", "paligemma_3b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "llama3.2-1b": "llama3_2_1b", "internlm2-1.8b": "internlm2_1_8b",
    "granite-3-8b": "granite_3_8b", "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "mixtral-8x22b": "mixtral_8x22b", "musicgen-medium": "musicgen_medium",
    "zamba2-7b": "zamba2_7b", "falcon-mamba-7b": "falcon_mamba_7b",
    "paligemma-3b": "paligemma_3b",
})


def get_config(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def shape_applicable(cfg: ArchConfig, shape: Shape) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md)"
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """CPU smoke-test variant of the same family (small dims, same structure)."""
    changes = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.shared_attn_every else 2),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
    )
    if cfg.has_attention:
        changes.update(
            n_heads=4,
            n_kv_heads=1 if cfg.n_kv_heads == 1 else (4 if cfg.n_kv_heads == cfg.n_heads else 2),
            head_dim=32,
        )
    if cfg.attn_type == "mla":
        changes.update(kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32)
    if cfg.n_experts:
        changes.update(n_experts=4, n_experts_per_tok=min(cfg.n_experts_per_tok, 2),
                       moe_d_ff=64 if cfg.moe_d_ff else 0,
                       n_shared_experts=min(cfg.n_shared_experts, 1),
                       first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.ssm_type:
        changes.update(d_inner=256, ssm_state=min(cfg.ssm_state, 16),
                       dt_rank=8 if cfg.ssm_type == "mamba1" else 0,
                       ssm_head_dim=32)
    if cfg.window:
        changes.update(window=32)
    if cfg.shared_attn_every:
        changes.update(shared_attn_every=2)
    if cfg.num_prefix_embeddings:
        changes.update(num_prefix_embeddings=8)
    return dataclasses.replace(cfg, **changes)


def input_specs(cfg: ArchConfig, shape: Shape, *, for_smoke: bool = False):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb_dtype = jnp.dtype(cfg.dtype)
    specs = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.frontend:
            specs["prefix_embeddings"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeddings, cfg.d_model), emb_dtype)
    else:  # decode: one new token against a cache of length S
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    return specs
