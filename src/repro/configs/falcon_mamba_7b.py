"""falcon-mamba-7b [arXiv:2410.05355; unverified] — pure mamba1, attn-free."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="falcon_mamba_7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024, attn_type="none",
    ssm_type="mamba1", ssm_state=16, ssm_conv=4, d_inner=8192,
    ssm_bcdt_norm=True,
)
