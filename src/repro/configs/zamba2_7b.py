"""zamba2-7b [arXiv:2411.15242; unverified] — mamba2 backbone + SHARED
attention block (one set of weights applied every 6th layer with its own KV
cache per application). ssm_state=64, d_inner=2*d_model, head_dim 64."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_type="mamba2", ssm_state=64, ssm_conv=4, ssm_head_dim=64,
    shared_attn_every=6,
)
