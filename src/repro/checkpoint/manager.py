"""Fault-tolerant checkpointing: atomic async sharded save, keep-k GC,
resume, and RESHARD-on-restore (elastic mesh changes).

Layout: <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
atomically renamed (a crash mid-save never corrupts the latest checkpoint).
Leaves are addressed by their tree path, so restore works against any
template with the same structure; ``shardings`` at restore time places each
leaf for the *current* mesh — a checkpoint written on 512 chips restores on
any mesh whose axes divide the dims (elastic down/up-scaling).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["save_tree", "restore_tree", "CheckpointManager"]


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = leaf
    return out


def save_tree(tree, directory: str, *, meta: dict | None = None):
    """Atomic synchronous save."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    named = _flatten_with_names(tree)
    arrays = {k: np.asarray(v) for k, v in named.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"meta": meta or {}, "keys": sorted(arrays),
                   "time": time.time()}, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_tree(template, directory: str, *, shardings=None):
    """Restore into the structure of ``template``; optionally place each leaf
    with a matching ``shardings`` pytree (reshard-on-restore)."""
    with np.load(os.path.join(directory, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree.leaves(shardings,
                                  is_leaf=lambda s: hasattr(s, "spec") or s is None)
                  if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (path, leaf), shard in zip(flat_t, shard_flat):
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(directory: str) -> dict:
    with open(os.path.join(directory, "meta.json")) as f:
        return json.load(f)["meta"]


class CheckpointManager:
    """Async keep-k checkpointing with atomic rename."""

    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, *, meta: dict | None = None,
             async_: bool = True):
        self.wait()
        # snapshot to host BEFORE going async (device buffers may be donated)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _do():
            try:
                save_tree(host_tree, self._step_dir(step),
                          meta=dict(meta or {}, step=step))
                self._gc()
            except Exception as e:      # pragma: no cover
                self._error = e

        if async_:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()
            if self._error:
                raise self._error

    def restore(self, template, *, step: int | None = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        tree = restore_tree(template, self._step_dir(step), shardings=shardings)
        return step, tree, load_meta(self._step_dir(step))

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
