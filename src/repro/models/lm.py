"""Unified decoder LM covering all assigned architecture families.

A model is a *program* of homogeneous layer stacks (dense / moe / mamba1 /
mamba2 / zamba groups), each scanned with ``lax.scan`` over stacked layer
params so compile time and HLO size are ~O(1) in depth. Modality frontends
are stubs per the assignment: precomputed prefix embeddings are prepended to
the token embeddings (vision patches / audio conditioning).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.kernels.lm_head import lm_head_ce, lm_head_logits
from repro.layers import blocks
from repro.layers.common import dense_init, rmsnorm
from repro.layers.rope import sinusoidal_embedding
from repro.parallel.context import shard_activation

__all__ = ["LM", "StackSpec", "build_program", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Megatron-style vocab padding so embeddings always shard."""
    return -(-v // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class StackSpec:
    kind: str           # dense | moe | mamba1 | mamba2 | zamba_group
    n: int
    group: int = 0      # zamba_group: mamba layers per shared-attn application


def build_program(cfg: ArchConfig) -> list[StackSpec]:
    if cfg.shared_attn_every:                       # zamba2 hybrid
        g = cfg.shared_attn_every
        ngroups = cfg.n_layers // g
        tail = cfg.n_layers - ngroups * g
        prog = [StackSpec("zamba_group", ngroups, group=g)]
        if tail:
            prog.append(StackSpec("mamba2", tail))
        return prog
    if cfg.ssm_type == "mamba1":
        return [StackSpec("mamba1", cfg.n_layers)]
    if cfg.ssm_type == "mamba2":
        return [StackSpec("mamba2", cfg.n_layers)]
    if cfg.n_experts:
        prog = []
        if cfg.first_dense_layers:
            prog.append(StackSpec("dense", cfg.first_dense_layers))
        prog.append(StackSpec("moe", cfg.n_layers - cfg.first_dense_layers))
        return prog
    return [StackSpec("dense", cfg.n_layers)]


class LM:
    def __init__(self, cfg: ArchConfig, *, remat: str = "none",
                 moe_dispatch: str = "einsum", scan_layers: bool = True,
                 ce_chunks: int = 1, fused_head: bool = True,
                 head_backend: str = "auto"):
        assert remat in ("none", "full", "dots")
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.program = build_program(cfg)
        self.vpad = pad_vocab(cfg.vocab_size)
        self.remat = remat
        self.moe_dispatch = moe_dispatch
        # ce_chunks > 1: compute CE in sequence chunks with rematerialized
        # per-chunk logits — peak logits memory drops by the chunk count
        self.ce_chunks = ce_chunks
        # fused_head: route the LM head through the fused unified-language
        # kernels — loss uses lm_head_ce (one matmul + online-softmax pass;
        # nothing (B, S, Vpad)-shaped materializes, so ce_chunks is moot),
        # _logits/decode use lm_head_logits (logits + row max + greedy argmax
        # from the same pass). head_backend picks the kernel expansion
        # ("auto" = pallas, or $REPRO_BACKEND).
        # DEPRECATION: the default flipped False -> True — the fused head is
        # the served configuration. Pass fused_head=False explicitly to keep
        # the einsum + pad-mask reference head (tests do, as the baseline).
        self.fused_head = fused_head
        self.head_backend = head_backend
        # scan_layers=False unrolls the layer loops (python for). Used by the
        # dry-run cost extrapolation: HLO cost analysis counts a while-loop
        # body ONCE regardless of trip count, so per-layer costs are measured
        # on small unrolled variants and extrapolated linearly.
        self.scan_layers = scan_layers

    def _scan_or_loop(self, body, x, xs, n):
        if self.scan_layers:
            return jax.lax.scan(body, x, xs)
        ys = []
        for i in range(n):
            x, y = body(x, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        ystack = jax.tree.map(lambda *v: jnp.stack(v), *ys)
        return x, ystack

    # ------------------------------------------------------------------ init
    def _layer_init(self, rng, kind):
        cfg, dtype = self.cfg, self.dtype
        if kind == "dense":
            return blocks.tblock_init(rng, cfg, dtype, moe=False)
        if kind == "moe":
            return blocks.tblock_init(rng, cfg, dtype, moe=True)
        if kind in ("mamba1", "mamba2"):
            return blocks.mamba_block_init(rng, cfg, dtype)
        raise ValueError(kind)

    def _stack_init(self, rng, spec: StackSpec):
        if spec.kind == "zamba_group":
            keys = jax.random.split(rng, spec.n * spec.group)
            keys = keys.reshape(spec.n, spec.group, *keys.shape[1:])
            inner = jax.vmap(lambda k: self._layer_init(k, "mamba2"))
            return jax.vmap(inner)(keys)
        keys = jax.random.split(rng, spec.n)
        return jax.vmap(lambda k: self._layer_init(k, spec.kind))(keys)

    def init(self, rng):
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(rng, len(self.program) + 3)
        params = {
            "embed": dense_init(keys[0], (self.vpad, cfg.d_model), dtype,
                                scale=cfg.d_model ** -0.5),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(keys[1], (cfg.d_model, self.vpad), dtype)
        if cfg.shared_attn_every:
            params["shared_attn"] = blocks.tblock_init(keys[2], cfg, dtype, moe=False)
        params["stacks"] = [self._stack_init(k, spec)
                            for k, spec in zip(keys[3:], self.program)]
        return params

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))

    def active_param_count(self, params) -> int:
        """Parameters touched per token (MoE: only top-k experts count)."""
        cfg = self.cfg
        total = self.param_count(params)
        if not cfg.n_experts:
            return total
        # subtract inactive expert fraction
        stack = params["stacks"][-1]
        expert_leaves = [stack["moe"][k] for k in ("w_gate", "w_up", "w_down")]
        expert_params = sum(x.size for x in expert_leaves)
        inactive = expert_params * (1 - cfg.n_experts_per_tok / cfg.n_experts)
        return int(total - inactive)

    # --------------------------------------------------------------- embed
    def _embed(self, params, tokens, prefix_embeddings=None, pos0=0):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.embed_scale:
            x = x * math.sqrt(cfg.d_model)
        if prefix_embeddings is not None:
            x = jnp.concatenate([prefix_embeddings.astype(x.dtype), x], axis=1)
        if cfg.pos_embed == "sinusoidal":
            pos = sinusoidal_embedding(pos0 + jnp.arange(x.shape[1]), cfg.d_model)
            x = x + pos[None].astype(x.dtype)
        return shard_activation(x, "act_btd")

    def _head(self, params):
        """The (d_model, Vpad) head matrix (tied embeddings transposed)."""
        cfg = self.cfg
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        return head

    def _logits(self, params, x):
        cfg = self.cfg
        head = self._head(params)
        if self.fused_head:
            b, s, d = x.shape
            logits = lm_head_logits(x.reshape(b * s, d),
                                    head.astype(x.dtype),
                                    vocab=cfg.vocab_size,
                                    backend=self.head_backend)
            return shard_activation(logits.reshape(b, s, self.vpad),
                                    "act_btv")
        logits = jnp.einsum("...d,dv->...v", x, head,
                            preferred_element_type=jnp.float32)
        # mask padded vocab entries
        pad_mask = jnp.where(jnp.arange(self.vpad) < cfg.vocab_size, 0.0, -1e30)
        logits = logits + pad_mask
        return shard_activation(logits, "act_btv")

    # -------------------------------------------------------------- forward
    def _wrap_remat(self, body):
        if self.remat == "none":
            return body
        policy = None
        if self.remat == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(body, policy=policy)

    def _stack_forward(self, params, stack_params, x, spec, prefix_len):
        cfg = self.cfg

        if spec.kind == "zamba_group":
            shared = params["shared_attn"]

            def body(x, gp):
                def inner(x, lp):
                    return blocks.mamba_block_forward(lp, x, cfg)
                x, auxs = self._scan_or_loop(inner, x, gp, spec.group)
                x, aux2 = blocks.tblock_forward(shared, x, cfg, moe=False)
                return x, auxs.sum(0) + aux2
        else:
            moe = spec.kind == "moe"

            def body(x, lp):
                if spec.kind in ("mamba1", "mamba2"):
                    return blocks.mamba_block_forward(lp, x, cfg)
                return blocks.tblock_forward(lp, x, cfg, moe=moe,
                                             prefix_len=prefix_len,
                                             dispatch=self.moe_dispatch)

        x, auxs = self._scan_or_loop(self._wrap_remat(body), x, stack_params,
                                     spec.n)
        return x, auxs.sum(0)

    def _hidden_states(self, params, tokens, prefix_embeddings=None):
        """Embed -> layer stacks -> final norm: the shared forward trunk.
        Returns (hidden (B, S*, d), aux[2])."""
        cfg = self.cfg
        x = self._embed(params, tokens, prefix_embeddings)
        prefix_len = (prefix_embeddings.shape[1]
                      if (prefix_embeddings is not None and cfg.prefix_lm) else 0)
        aux = blocks.ZERO_AUX
        for spec, sp in zip(self.program, params["stacks"]):
            x, a = self._stack_forward(params, sp, x, spec, prefix_len)
            aux = aux + a
        return rmsnorm(x, params["final_norm"], eps=cfg.norm_eps), aux

    def forward(self, params, tokens, prefix_embeddings=None):
        """Full-sequence forward. Returns (logits (B,S*,Vpad) f32, aux[2])."""
        x, aux = self._hidden_states(params, tokens, prefix_embeddings)
        return self._logits(params, x), aux

    def _ce_from_hidden(self, params, x, labels):
        """CE over sequence chunks with rematerialized logits (peak-memory
        lever: nothing (B, S, Vpad)-f32-shaped is live across the step)."""
        b, s, d = x.shape
        k = self.ce_chunks
        while s % k:
            k -= 1

        def chunk_ce(args):
            xc, lc = args
            logits = self._logits(params, xc)
            logz = jax.nn.logsumexp(logits, axis=-1)
            onehot = jax.nn.one_hot(lc, self.vpad, dtype=logits.dtype)
            gold = jnp.sum(logits * onehot, axis=-1)
            return jnp.sum(logz - gold)

        body = jax.checkpoint(chunk_ce)
        xs = x.reshape(b, k, s // k, d).swapaxes(0, 1)
        ls = labels.reshape(b, k, s // k).swapaxes(0, 1)
        total, _ = jax.lax.scan(lambda acc, a: (acc + body(a), None), 0.0,
                                (xs, ls))
        return total / (b * s)

    def _fused_ce(self, params, x, labels):
        """Fused chunked CE through ``lm_head_ce``: one matmul + online-
        softmax pass streams logsumexp and the gold logit out of the kernel
        block by block — nothing (B, S, Vpad)-shaped is ever live, forward
        OR backward (the custom VJP recomputes softmax - onehot blockwise
        from the saved row stats)."""
        b, s, d = x.shape
        head = self._head(params).astype(x.dtype)
        nll = lm_head_ce(x.reshape(b * s, d), head,
                         labels.reshape(b * s, 1).astype(jnp.int32),
                         vocab=self.cfg.vocab_size,
                         backend=self.head_backend)
        return nll.mean()

    def _check_labels(self, labels):
        """Labels >= vocab_size index PADDED-vocab columns: ``one_hot`` over
        vpad plus the -1e30 pad mask keeps the loss finite, so training
        would silently optimize against pad logits. Raise host-side whenever
        the values are concrete (eager loss calls; jitted steps see tracers
        and rely on the data pipeline / eager first step)."""
        if isinstance(labels, jax.core.Tracer) or labels.size == 0:
            return
        host = np.asarray(labels)            # one device pull, checked on host
        lo, hi = int(host.min()), int(host.max())
        if lo < 0 or hi >= self.cfg.vocab_size:
            raise ValueError(
                f"labels out of range [{lo}, {hi}] for vocab_size="
                f"{self.cfg.vocab_size} (vpad={self.vpad}): CE would "
                "silently train on padded-vocab logits; clean the batch")

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        prefix = batch.get("prefix_embeddings")
        p = prefix.shape[1] if prefix is not None else 0
        labels = tokens[:, 1:]
        self._check_labels(labels)
        if self.fused_head or self.ce_chunks > 1:
            # forward to the final hidden states; CE never sees full logits
            x, aux = self._hidden_states(params, tokens, prefix)
            pred_x = x[:, p:-1] if x.shape[1] > p + 1 else x[:, p:]
            if self.fused_head:
                ce = self._fused_ce(params, pred_x, labels)
            else:
                ce = self._ce_from_hidden(params, pred_x, labels)
        else:
            logits, aux = self.forward(params, tokens, prefix_embeddings=prefix)
            pred = logits[:, p:-1] if logits.shape[1] > p + 1 else logits[:, p:]
            logz = jax.nn.logsumexp(pred, axis=-1)
            onehot = jax.nn.one_hot(labels, self.vpad, dtype=pred.dtype)
            gold = jnp.sum(pred * onehot, axis=-1)
            ce = jnp.mean(logz - gold)
        lb, z = aux[0], aux[1]
        nl = max(sum(s.n * max(s.group, 1) for s in self.program), 1)
        total = ce + (0.02 * lb + 1e-3 * z) / nl
        metrics = {"ce": ce, "moe_lb": lb, "moe_z": z}
        return total, metrics

    # ---------------------------------------------------------------- cache
    def _stack_cache_init(self, spec, batch, max_len, dtype):
        cfg = self.cfg

        def stacked(n, single):
            return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype), single)

        if spec.kind == "zamba_group":
            mamba_single = blocks.mamba_block_cache_init(cfg, batch, dtype)
            attn_single = blocks.tblock_cache_init(cfg, batch, max_len, dtype)
            return {
                "mamba": stacked(spec.n, stacked(spec.group, mamba_single)),
                "attn": stacked(spec.n, attn_single),
            }
        if spec.kind in ("mamba1", "mamba2"):
            return stacked(spec.n, blocks.mamba_block_cache_init(cfg, batch, dtype))
        return stacked(spec.n, blocks.tblock_cache_init(cfg, batch, max_len, dtype))

    def init_cache(self, batch, max_len, dtype=None):
        dtype = dtype or self.dtype
        return {"pos": jnp.zeros((), jnp.int32),
                "stacks": [self._stack_cache_init(s, batch, max_len, dtype)
                           for s in self.program]}

    @property
    def has_positional_cache(self) -> bool:
        """True when decode positions are bounded by the cache's max_len:
        attention stacks WITHOUT a rolling window (rolling caches rotate and
        never overflow; SSM stacks carry O(1) state)."""
        return (not self.cfg.window and
                any(s.kind in ("dense", "moe", "zamba_group")
                    for s in self.program))

    def cache_capacity(self, cache) -> int | None:
        """Token positions the attention caches can hold, or None when
        unbounded (rolling-window or attention-free programs)."""
        if not self.has_positional_cache:
            return None
        caps = []
        for spec, sc in zip(self.program, cache["stacks"]):
            if spec.kind in ("dense", "moe"):
                c = sc
            elif spec.kind == "zamba_group":
                c = sc["attn"]
            else:
                continue
            # stacked leaves: ckv (n, B, max_len, lora) / k (n, B, Hk, m, hd)
            caps.append(c["ckv"].shape[2] if "ckv" in c else c["k"].shape[3])
        return min(caps) if caps else None

    # -------------------------------------------------------------- prefill
    def prefill(self, params, tokens, prefix_embeddings=None, max_len=None):
        """Returns (last-token logits (B, Vpad), cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens, prefix_embeddings)
        max_len = max_len or x.shape[1]
        if self.has_positional_cache and x.shape[1] > max_len:
            raise ValueError(
                f"kv cache overflow: prefilling {x.shape[1]} tokens into a "
                f"cache of max_len={max_len}; decode would silently attend "
                "truncated history — raise max_len")
        prefix_len = (prefix_embeddings.shape[1]
                      if (prefix_embeddings is not None and cfg.prefix_lm) else 0)
        caches = []
        for spec, sp in zip(self.program, params["stacks"]):
            if spec.kind == "zamba_group":
                shared = params["shared_attn"]

                def body(x, gp):
                    def inner(x, lp):
                        y, aux, c = blocks.mamba_block_prefill(lp, x, cfg,
                                                               cache_dtype=self.dtype)
                        return y, c
                    x, cm = self._scan_or_loop(inner, x, gp, spec.group)
                    x, _, ca = blocks.tblock_prefill(shared, x, cfg, moe=False,
                                                     max_len=max_len,
                                                     cache_dtype=self.dtype)
                    return x, {"mamba": cm, "attn": ca}
            elif spec.kind in ("mamba1", "mamba2"):
                def body(x, lp):
                    y, _, c = blocks.mamba_block_prefill(lp, x, cfg,
                                                         cache_dtype=self.dtype)
                    return y, c
            else:
                moe = spec.kind == "moe"

                def body(x, lp, moe=moe):
                    y, _, c = blocks.tblock_prefill(lp, x, cfg, moe=moe,
                                                    max_len=max_len,
                                                    prefix_len=prefix_len,
                                                    dispatch=self.moe_dispatch,
                                                    cache_dtype=self.dtype)
                    return y, c

            x, cache = self._scan_or_loop(body, x, sp, spec.n)
            caches.append(cache)
        x = rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, {"pos": jnp.asarray(x.shape[1], jnp.int32),
                        "stacks": caches}

    # ------------------------------------------------------------- decoding
    def _decode_hidden(self, params, tokens, cache):
        """One decode step up to the final norm: tokens (B, 1) -> (hidden
        (B, 1, d), new_cache). The head (logits / fused greedy) goes on top."""
        cfg = self.cfg
        pos = cache.get("pos", 0)
        # cache overflow is an ERROR, not a silent clobber of the last slot:
        # checked here when pos is concrete (eager decode loops); jitted
        # loops are guarded host-side by launch.serve.generate
        cap = self.cache_capacity(cache) if "stacks" in cache else None
        if (cap is not None and not isinstance(pos, jax.core.Tracer)
                and int(pos) >= cap):
            raise ValueError(
                f"kv cache overflow: decode at position {int(pos)} but the "
                f"cache holds {cap} tokens; grow max_len at prefill/"
                "init_cache (the layer-level write would silently overwrite "
                "the last slot and attend corrupted history)")
        x = self._embed(params, tokens, pos0=pos)
        new_caches = []
        for spec, sp, sc in zip(self.program, params["stacks"], cache["stacks"]):
            if spec.kind == "zamba_group":
                shared = params["shared_attn"]

                def body(x, args):
                    gp, gc = args

                    def inner(x, a):
                        lp, lc = a
                        y, nc = blocks.mamba_block_decode(lp, x, lc, cfg)
                        return y, nc
                    x, ncm = self._scan_or_loop(inner, x, (gp, gc["mamba"]),
                                                spec.group)
                    x, nca = blocks.tblock_decode(shared, x, gc["attn"], cfg)
                    return x, {"mamba": ncm, "attn": nca}
            elif spec.kind in ("mamba1", "mamba2"):
                def body(x, args):
                    lp, lc = args
                    y, nc = blocks.mamba_block_decode(lp, x, lc, cfg)
                    return y, nc
            else:
                moe = spec.kind == "moe"

                def body(x, args, moe=moe):
                    lp, lc = args
                    y, nc = blocks.tblock_decode(lp, x, lc, cfg, moe=moe,
                                                 dispatch=self.moe_dispatch)
                    return y, nc

            x, nc = self._scan_or_loop(body, x, (sp, sc), spec.n)
            new_caches.append(nc)
        x = rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
        return x, {"pos": pos + 1, "stacks": new_caches}

    def decode_step(self, params, tokens, cache):
        """One token for every sequence. tokens: (B, 1). Returns
        (logits (B, Vpad), new_cache)."""
        x, new_cache = self._decode_hidden(params, tokens, cache)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    def greedy_step(self, params, tokens, cache):
        """One greedy decode step: tokens (B, 1) -> (next token (B,),
        logits (B, Vpad), new_cache). With ``fused_head`` the argmax comes
        straight out of the fused LM-head kernel (its row-max/argmax outputs
        share the logits pass) instead of a second scan over the vocab;
        otherwise it falls back to ``greedy_token`` on the logits."""
        x, new_cache = self._decode_hidden(params, tokens, cache)
        if not self.fused_head:
            logits = self._logits(params, x)[:, 0]
            return self.greedy_token(logits), logits, new_cache
        b, s, d = x.shape                    # s == 1
        # .raw returns the kernel outputs unsliced — drop any pre-hook row
        # padding (none at decode batch sizes, but keep the contract local)
        logits, _m, arg = lm_head_logits.raw(
            x.reshape(b, d), self._head(params).astype(x.dtype),
            vocab=self.cfg.vocab_size, backend=self.head_backend)
        logits = shard_activation(logits[:b].reshape(b, 1, self.vpad),
                                  "act_btv")[:, 0]
        return arg[:b, 0], logits, new_cache

    def greedy_token(self, logits):
        return jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1)

    # -------------------------------------------------------- paged decoding
    @property
    def pageable(self) -> bool:
        """True when the program can decode against a paged KV pool: pure
        attention stacks (dense/moe) with GQA, rope positions and no rolling
        window. SSM state is O(1) (nothing to page), MLA's latent cache and
        rotated windowed caches use different layouts."""
        cfg = self.cfg
        return (all(s.kind in ("dense", "moe") for s in self.program)
                and cfg.attn_type != "mla" and not cfg.window
                and cfg.pos_embed == "rope")

    def init_paged_cache(self, batch, num_pages, page_size, nseq_pages,
                         dtype=None):
        """A paged decode cache: per-layer KV pools of ``num_pages`` fixed
        ``page_size``-token pages shared by all ``batch`` slots, plus the
        per-slot block tables (``nseq_pages`` logical pages each), lengths,
        and the pool-wide slot -> absolute-position map. Page 0 is reserved
        as the NULL page (idle slots point at it; its positions stay -1)."""
        if not self.pageable:
            raise ValueError(
                "paged decode needs an attention-only GQA program with rope "
                "positions and no rolling window "
                f"(program={[s.kind for s in self.program]}, "
                f"attn_type={self.cfg.attn_type}, window={self.cfg.window}, "
                f"pos_embed={self.cfg.pos_embed})")
        dtype = dtype or self.dtype

        def stacked(n, single):
            return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype),
                                single)

        stacks = [stacked(s.n, blocks.tblock_paged_cache_init(
                      self.cfg, num_pages, page_size, dtype))
                  for s in self.program]
        return {"table": jnp.zeros((batch, nseq_pages), jnp.int32),
                "len": jnp.zeros((batch,), jnp.int32),
                "pos_pages": jnp.full((num_pages, page_size), -1, jnp.int32),
                "stacks": stacks}

    def _paged_decode_hidden(self, params, tokens, cache):
        """One paged decode step up to the final norm. Every slot decodes
        every step — idle slots carry len 0 and a zero block table, writing
        into and reading from the null page (their output is ignored)."""
        cfg = self.cfg
        table, lens = cache["table"], cache["len"]
        pos_pages = cache["pos_pages"]
        b, nsp = table.shape
        pg = pos_pages.shape[1]
        # pool coordinates of this step's KV write, shared by every layer
        page_ids = table[jnp.arange(b), jnp.clip(lens // pg, 0, nsp - 1)]
        offs = lens % pg
        # stamp the new positions; the null page is pinned to -1 so idle
        # slots' writes never masquerade as valid history for live tables
        pos_pages = pos_pages.at[page_ids, offs].set(lens).at[0].set(-1)
        x = self._embed(params, tokens)
        new_stacks = []
        for spec, sp, sc in zip(self.program, params["stacks"],
                                cache["stacks"]):
            moe = spec.kind == "moe"

            def body(x, args, moe=moe):
                lp, lc = args
                y, nc = blocks.tblock_paged_decode(
                    lp, x, lc, cfg, moe=moe, dispatch=self.moe_dispatch,
                    table=table, lens=lens, pos_pages=pos_pages,
                    page_ids=page_ids, offs=offs)
                return y, nc

            x, nc = self._scan_or_loop(body, x, (sp, sc), spec.n)
            new_stacks.append(nc)
        x = rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
        return x, dict(cache, len=lens + 1, pos_pages=pos_pages,
                       stacks=new_stacks)

    def paged_decode_step(self, params, tokens, cache):
        """One paged decode token for every slot. tokens: (B, 1). Returns
        (logits (B, Vpad), new_cache)."""
        x, new_cache = self._paged_decode_hidden(params, tokens, cache)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    def paged_greedy_step(self, params, tokens, cache):
        """Paged twin of ``greedy_step``: (next (B,), logits, new_cache)."""
        x, new_cache = self._paged_decode_hidden(params, tokens, cache)
        if not self.fused_head:
            logits = self._logits(params, x)[:, 0]
            return self.greedy_token(logits), logits, new_cache
        b, s, d = x.shape
        logits, _m, arg = lm_head_logits.raw(
            x.reshape(b, d), self._head(params).astype(x.dtype),
            vocab=self.cfg.vocab_size, backend=self.head_backend)
        logits = shard_activation(logits[:b].reshape(b, 1, self.vpad),
                                  "act_btv")[:, 0]
        return arg[:b, 0], logits, new_cache
