from .lm import LM, StackSpec, build_program, pad_vocab  # noqa: F401
