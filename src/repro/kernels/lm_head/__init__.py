from .kernel import lm_head_builder, lm_head_bwd_builder
from .ops import lm_head_ce, lm_head_logits
from .ref import lm_head_ce_ref, lm_head_logits_ref, masked_logits_ref

__all__ = ["lm_head_builder", "lm_head_bwd_builder", "lm_head_ce",
           "lm_head_logits", "lm_head_ce_ref", "lm_head_logits_ref",
           "masked_logits_ref"]
