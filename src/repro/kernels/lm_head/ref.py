"""Pure-jnp oracles for the fused LM head (what the kernels MUST compute).

These mirror the unfused model path exactly: full ``(R, V)`` f32 logits with
the Megatron vocab-padding mask (``-1e30`` on columns >= vocab), then
``logsumexp`` / gold gather / argmax on top. The kernels compute the same
functions without materializing the logits (CE) or with a single fused pass
(decode); the test suite asserts agreement across backends, dtypes and
padding configurations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lm_head_ce_ref", "lm_head_logits_ref", "masked_logits_ref"]

_PAD_LOGIT = -1e30


def masked_logits_ref(x, w, *, vocab=None):
    """x: (R, d) @ w: (d, V) in f32 with padded columns masked to -1e30."""
    V = w.shape[1]
    vocab = V if vocab is None else int(vocab)
    logits = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    pad = jnp.where(jnp.arange(V) < vocab, 0.0, _PAD_LOGIT)
    return logits + pad


def lm_head_ce_ref(x, w, labels, *, vocab=None):
    """Per-row token NLL: ``logsumexp(logits) - logits[label]``. labels may be
    (R,) or (R, 1); returns (R,) f32."""
    logits = masked_logits_ref(x, w, vocab=vocab)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = labels.reshape(-1)
    gold = jnp.take_along_axis(logits, lab[:, None], axis=-1)[:, 0]
    return lse - gold


def lm_head_logits_ref(x, w, *, vocab=None):
    """The decode-path oracle: (masked logits (R, V) f32, row max (R, 1) f32,
    first-occurrence argmax over the TRUE vocab (R, 1) i32)."""
    V = w.shape[1]
    vocab = V if vocab is None else int(vocab)
    logits = masked_logits_ref(x, w, vocab=vocab)
    live = logits[:, :vocab]
    m = live.max(-1, keepdims=True)
    arg = jnp.argmax(live, axis=-1).astype(jnp.int32)[:, None]
    return logits, m, arg
