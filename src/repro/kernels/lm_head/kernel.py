"""Fused LM-head kernels in the unified language — the multi-output-reduce op.

The LM head is the largest single matmul of every decode and train step:
``x (R, d) @ w (d, V)`` with ``R = B*S`` rows and ``V`` the padded vocab.
The unfused model path materializes the full ``(R, V)`` f32 logits and then
runs a separate ``logsumexp`` over them — the hottest unfused path left in
the repo. These kernels fuse the matmul with the row statistics the LM
actually wants, flash-attention-style (online softmax over vocab blocks), so
the softmax normalizer and the gold-token logit come out of ONE pass without
materializing anything ``(R, V)``-shaped beyond a block.

``lm_head_builder`` — grid ``(rows, nv, nk)``, ``reduce_axes=(1, 2)`` (the
vocab-block axis ``nv`` OUTER-sequential, the d-block axis ``nk`` inner).
A logits block accumulates over the ``nk`` sweep in f32 scratch; once
complete (``reduce_last(1)``) it feeds the per-row ONLINE-SOFTMAX state
(running max m, rescaled sum-of-exp l) carried across the ``nv`` sweep in
scratch, plus the gold-token gather against a dynamic ``labels`` input tile.
Its outputs span DIFFERENT reduce granularities in one grid — the
multi-output-reduce direction ``Tile(reduce=...)`` was built for:

  ``emit_logits=1`` (decode):   logits ``Tile(reduce=(2,))`` — one block per
                                (row-block, vocab-block), accumulated over
                                the d sweep; row max ``m`` and first-
                                occurrence ``argmax`` ``Tile(reduce=(1, 2))``
                                — one block per row-block, accumulated over
                                BOTH sweeps (cheap greedy decode).
  ``emit_logits=0`` (chunked CE): ``lse`` (logsumexp) and ``gold`` (the
                                label's logit) ``Tile(reduce=(1, 2))`` ONLY —
                                the ``(R, V)`` logits never exist.

``lm_head_bwd_builder`` — the CE backward ``softmax(logits) - onehot``
recomputed blockwise from the saved ``lse`` stats (no logits residual), the
same transposed-granularity pairing as the fused flash backward: grid
``(nr, nv)`` with BOTH axes sequential, ``dx = Tile(reduce=(1,))``
accumulating over vocab blocks in consecutively-revisited output blocks
while ``dw = Tile(reduce=(0,))`` accumulates over row blocks (write-back/
refetch revisits — exact on jnp/loops/interpret, flagged for real-TPU
validation in ROADMAP alongside flash's dk/dv).

Vocab padding (``vocab < V``, Megatron-style pad to a sharding multiple) is
handled INSIDE the kernel: padded columns are excluded from m/l/argmax/gold
and the emitted logits carry the same ``-1e30`` mask as the unfused path.
Host paths live in the ``define_op`` declarations in ``ops.py``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import Scratch, Spec, Tile

__all__ = ["lm_head_builder", "lm_head_bwd_builder"]

_NEG_INF = float("-inf")
_PAD_LOGIT = -1e30


def _vocab_positions(vi, bv):
    """(1, bv) absolute vocab positions of block ``vi`` (2D iota: TPU-safe)."""
    return vi * bv + lax.broadcasted_iota(jnp.int32, (1, bv), 1)


def lm_head_builder(D):
    """x: (R, d) @ w: (d, V) -> fused logits/row-stat outputs (see module doc).

    Defines: R, d, V (padded vocab), vocab (true size; columns >= vocab are
    padding), block_r/block_v/block_k block sizes (the autotune surface),
    emit_logits (output-set selector), dtype.
    """
    R, d, V, vocab = D.R, D.d, D.V, D.vocab
    br, bv, bk = D.block_r, D.block_v, D.block_k
    emit = bool(D.emit_logits)
    dtype = jnp.dtype(D.dtype)
    nv, nk = V // bv, d // bk

    def body(ctx, *refs):
        if emit:
            x_ref, w_ref, logits_ref, m_ref, arg_ref = refs
            acc, m_scr, amax_scr = ctx.scratch
        else:
            x_ref, w_ref, lab_ref, lse_ref, gold_ref = refs
            acc, m_scr, l_scr, gold_scr = ctx.scratch
        vi = ctx.reduce_id(0)

        @ctx.when(ctx.is_first)                 # vi == 0 & ki == 0: fresh row
        def _init_row_state():
            m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
            if emit:
                amax_scr[...] = jnp.zeros(amax_scr.shape, jnp.int32)
            else:
                l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
                gold_scr[...] = jnp.zeros(gold_scr.shape, jnp.float32)

        @ctx.when(ctx.reduce_first(1))          # ki == 0: fresh vocab block
        def _init_acc():
            acc[...] = jnp.zeros(acc.shape, jnp.float32)

        acc[...] += lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        @ctx.when(ctx.reduce_last(1))           # ki == nk-1: block complete
        def _fold_block():
            s = acc[...]                                    # (br, bv) f32
            v_pos = _vocab_positions(vi, bv)                # (1, bv)
            valid = v_pos < vocab                           # (1, bv)
            s_m = jnp.where(valid, s, _NEG_INF)             # padding excluded
            bm = s_m.max(-1, keepdims=True)                 # (br, 1)
            m_prev = m_scr[:, :1]
            m_cur = jnp.maximum(m_prev, bm)
            if emit:
                logits_ref[...] = (s + jnp.where(valid, 0.0, _PAD_LOGIT)
                                   ).astype(logits_ref.dtype)
                # first-occurrence argmax: within the block jnp.argmax picks
                # the first max; across blocks only a STRICTLY larger max
                # displaces the recorded index (earlier block wins ties)
                in_arg = jnp.argmax(s_m, axis=-1).astype(jnp.int32)  # (br,)
                better = bm > m_prev                        # (br, 1)
                amax_scr[:, :1] = jnp.where(better, vi * bv + in_arg[:, None],
                                            amax_scr[:, :1])
                m_scr[:, :1] = m_cur
            else:
                # online-softmax rescale (flash's m/l update over vocab blocks)
                corr = jnp.where(m_prev == _NEG_INF, 0.0,
                                 jnp.exp(m_prev - m_cur))
                p = jnp.where(valid & (m_cur > _NEG_INF),
                              jnp.exp(s - m_cur), 0.0)
                l_scr[:, :1] = l_scr[:, :1] * corr + p.sum(-1, keepdims=True)
                m_scr[:, :1] = m_cur
                # gold-token gather: each row's label lands in exactly one
                # vocab block; padded columns never match a valid label
                lab = lab_ref[...]                          # (br, 1) i32
                hit = (lab == v_pos) & valid                # (br, bv)
                gold_scr[:, :1] += jnp.where(hit, s, 0.0).sum(-1, keepdims=True)

        @ctx.when(ctx.is_last)                  # vocab sweep done: flush
        def _flush():
            if emit:
                m_ref[...] = m_scr[:, :1]
                arg_ref[...] = amax_scr[:, :1]
            else:
                l = l_scr[:, :1]
                lse_ref[...] = m_scr[:, :1] + jnp.log(
                    jnp.where(l == 0.0, 1.0, l))
                gold_ref[...] = gold_scr[:, :1]

    inputs = [
        Tile("x", (R, d), dtype, block=(br, bk),
             index=lambda ri, vi, ki: (ri, ki)),
        Tile("w", (d, V), dtype, block=(bk, bv),
             index=lambda ri, vi, ki: (ki, vi)),
    ]
    row_tile = dict(block=(br, 1), index=lambda ri, vi, ki: (ri, 0))
    if emit:
        outputs = [
            Tile("logits", (R, V), jnp.float32, block=(br, bv),
                 index=lambda ri, vi, ki: (ri, vi), reduce=(2,)),
            Tile("m", (R, 1), jnp.float32, reduce=(1, 2), **row_tile),
            Tile("arg", (R, 1), jnp.int32, reduce=(1, 2), **row_tile),
        ]
        scratch = [Scratch((br, bv), jnp.float32),      # logits accumulator
                   Scratch((br, 128), jnp.float32),     # running max (col 0)
                   Scratch((br, 128), jnp.int32)]       # running argmax
    else:
        inputs.append(Tile("labels", (R, 1), jnp.int32, **row_tile))
        outputs = [
            Tile("lse", (R, 1), jnp.float32, reduce=(1, 2), **row_tile),
            Tile("gold", (R, 1), jnp.float32, reduce=(1, 2), **row_tile),
        ]
        scratch = [Scratch((br, bv), jnp.float32),      # logits accumulator
                   Scratch((br, 128), jnp.float32),     # running max
                   Scratch((br, 128), jnp.float32),     # running sum-of-exp
                   Scratch((br, 128), jnp.float32)]     # gold-token logit
    return Spec(
        "lm_head_logits" if emit else "lm_head_ce",
        grid=(R // br, nv, nk),
        reduce_axes=(1, 2),
        scratch=scratch,
        inputs=inputs,
        outputs=outputs,
        body=body)


def lm_head_bwd_builder(D):
    """CE backward: x, w, labels, lse, g -> dx (R, d) f32, dw (d, V) f32.

    ``dlogits = g * (softmax(logits) - onehot(labels))`` recomputed blockwise
    from the saved ``lse`` (p = exp(s - lse); no logits residual). Grid
    ``(nr, nv)`` with BOTH axes sequential — the flash-bwd transposed-
    granularity pairing: ``dx`` accumulates over the inner vocab sweep
    (consecutive revisits of its output block), ``dw`` over the outer row
    sweep (write-back/refetch revisits, init under ``reduce_first(0)``).
    Padded columns produce p == 0 and can never match a valid label, so they
    contribute nothing — exactly the oracle's gradient through the -1e30
    mask. The d dimension is unblocked (one (br, d) x tile / (d, bv) w tile
    per cell), like flash's head_dim."""
    R, d, V, vocab = D.R, D.d, D.V, D.vocab
    br, bv = D.block_r, D.block_v
    dtype = jnp.dtype(D.dtype)

    def body(ctx, x_ref, w_ref, lab_ref, lse_ref, g_ref, dx_ref, dw_ref):
        vi = ctx.reduce_id(1)

        @ctx.when(ctx.reduce_first(1))       # vi == 0: fresh row block
        def _init_dx():
            dx_ref[...] = jnp.zeros((br, d), jnp.float32)

        @ctx.when(ctx.reduce_first(0))       # ri == 0: first visit of this
        def _init_dw():                      # dw block (undefined on real TPU)
            dw_ref[...] = jnp.zeros((d, bv), jnp.float32)

        x = x_ref[...].astype(jnp.float32)                  # (br, d)
        w = w_ref[...].astype(jnp.float32)                  # (d, bv)
        s = lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        v_pos = _vocab_positions(vi, bv)                    # (1, bv)
        valid = v_pos < vocab
        p = jnp.where(valid, jnp.exp(s - lse_ref[...]), 0.0)
        hit = (lab_ref[...] == v_pos) & valid               # (br, bv)
        dl = (p - jnp.where(hit, 1.0, 0.0)) * g_ref[...]    # (br, bv)
        dx_ref[...] = dx_ref[...] + lax.dot_general(
            dl, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # dl @ w^T
        dw_ref[...] = dw_ref[...] + lax.dot_general(
            x, dl, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # x^T @ dl

    row_tile = dict(block=(br, 1), index=lambda ri, vi: (ri, 0))
    return Spec(
        "lm_head_ce_bwd",
        grid=(R // br, V // bv),
        reduce_axes=(0, 1),
        inputs=[
            Tile("x", (R, d), dtype, block=(br, d),
                 index=lambda ri, vi: (ri, 0)),
            Tile("w", (d, V), dtype, block=(d, bv),
                 index=lambda ri, vi: (0, vi)),
            Tile("labels", (R, 1), jnp.int32, **row_tile),
            Tile("lse", (R, 1), jnp.float32, **row_tile),
            Tile("g", (R, 1), jnp.float32, **row_tile),
        ],
        outputs=[
            Tile("dx", (R, d), jnp.float32, block=(br, d),
                 index=lambda ri, vi: (ri, 0), reduce=(1,)),
            Tile("dw", (d, V), jnp.float32, block=(d, bv),
                 index=lambda ri, vi: (0, vi), reduce=(0,)),
        ],
        body=body)
