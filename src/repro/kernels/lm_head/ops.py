"""Public fused LM-head ops — ``define_op`` declarations.

``lm_head_ce`` is the training path: ``(x, w, labels) -> per-row NLL`` with a
custom VJP — the forward runs the fused matmul + online-softmax kernel (the
``(R, V)`` logits never materialize; only the ``lse``/``gold`` row stats come
back), and the backward recomputes ``softmax - onehot`` blockwise from the
saved ``lse`` through ``lm_head_bwd_builder`` on the SAME backend as the
forward. ``labels`` is a regular (integer) primal argument, so it threads
through ``jax.custom_vjp`` (its cotangent is the canonical ``float0``).

``lm_head_logits`` is the decode path: ``(x, w) -> logits`` publicly, with
the fused row max and first-occurrence argmax available on ``.raw`` — one
pass gives serving both the logits tensor and the greedy token.

Both declarations share ONE builder (``lm_head_builder``); the output set is
an ``emit_logits`` define. ``vocab`` (the true vocabulary size) masks the
Megatron padding columns inside the kernel.
"""

from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OpVJP, cdiv, define_op, default_device, fit_block
from .kernel import lm_head_bwd_builder, lm_head_builder
from .ref import lm_head_ce_ref, lm_head_logits_ref, masked_logits_ref

__all__ = ["lm_head_ce", "lm_head_logits"]


def _row_padding(R: int, block_r) -> int:
    """Rows to append so the row-block tiles exactly. R = B*(S-1) is almost
    never divisible by a power-of-two block (S-1 is odd for power-of-two
    seq lens), so the pre hooks pad x/labels up to the next block multiple
    instead of letting ``fit_block`` degrade to an awkward divisor; the post
    hooks slice the padded rows back off."""
    br = min(int(block_r), int(R))
    return (-int(R)) % br if br > 0 else 0


def _pad_rows(a, pad: int, fill=0):
    """Append ``pad`` constant rows; shape-only probes stay shape-only."""
    if pad == 0:
        return a
    if isinstance(a, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((a.shape[0] + pad,) + tuple(a.shape[1:]),
                                    a.dtype)
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1),
                   constant_values=fill)


def _base_defines(x, w, params, *, op_name):
    R, d = x.shape
    d2, V = w.shape
    if d != d2:
        raise ValueError(f"{op_name}: inner dims disagree ({d} vs {d2})")
    if x.dtype != w.dtype:
        raise ValueError(f"{op_name}: dtypes disagree ({x.dtype} vs {w.dtype})")
    vocab = params["vocab"]
    vocab = V if vocab is None else int(vocab)
    if not 0 < vocab <= V:
        raise ValueError(f"{op_name}: vocab={vocab} outside (0, {V}] "
                         f"(w has {V} padded columns)")
    want = (params["block_r"], params["block_v"], params["block_k"])
    br, bv, bk = fit_block(want[0], R), fit_block(want[1], V), fit_block(want[2], d)
    ncells = (R // br) * (V // bv) * (d // bk)
    # Degradation guard keyed on grid BLOWUP, not on any shrink: a mild fit
    # (vpad = 256*501 fitting block_v 512 -> 501) is a legitimate production
    # shape, while prime-ish dims collapsing blocks to ~1 would make Spec
    # validation and the expansions pathologically slow — only the latter
    # (grid >> what the requested blocks would give) fails loudly.
    want_cells = (cdiv(R, min(want[0], R)) * cdiv(V, min(want[1], V))
                  * cdiv(d, min(want[2], d)))
    if ncells > 1 << 16 and ncells > 8 * want_cells:
        raise ValueError(
            f"{op_name}: shapes ({R}x{d}x{V}) degraded the requested blocks "
            f"to ({br},{bv},{bk}) = {ncells} grid cells "
            f"(~{want_cells} requested); pad the operands or pass block "
            "sizes that divide the shapes")
    return dict(R=R, d=d, V=V, vocab=vocab, block_r=br, block_v=bv,
                block_k=bk, dtype=jnp.dtype(x.dtype).name)


def _ce_defines(args, params):
    x, w, labels = args[:3]
    D = _base_defines(x, w, params, op_name="lm_head_ce")
    if tuple(labels.shape) != (D["R"], 1):
        raise ValueError(
            f"lm_head_ce: labels shape {tuple(labels.shape)} != "
            f"({D['R']}, 1) — one gold token id per row")
    if jnp.dtype(labels.dtype) != jnp.int32:
        raise ValueError(f"lm_head_ce: labels must be int32, "
                         f"got {labels.dtype}")
    D["emit_logits"] = 0
    return D


def _logits_defines(args, params):
    x, w = args
    D = _base_defines(x, w, params, op_name="lm_head_logits")
    D["emit_logits"] = 1
    return D


def _ce_pre(args, params):
    # pad rows up to a block multiple (labels pad with 0 — a valid token id;
    # the padded rows' NLL is sliced off by the post hook / zeroed in bwd)
    x, w, labels = args
    pad = _row_padding(x.shape[0], params["block_r"])
    return _pad_rows(x, pad), w, _pad_rows(labels, pad)


def _ce_post(outs, args, params):
    lse, gold = outs                            # padded-row stats
    R = args[0].shape[0]                        # ORIGINAL row count
    return (lse - gold)[:R, 0]                  # per-row NLL, (R,) f32


def _ce_residuals(outs, args, params):
    lse, _ = outs                               # lse is PADDED-rows-shaped
    x, w, labels = args
    return x, w, labels, lse


def _fit_bwd_vmem(bdef: dict) -> dict:
    """The bwd working set carries f32 dx AND dw blocks on top of x/w — at
    large (d, V) the forward's fitted ``block_v`` can blow the VMEM budget.
    Shrink the vocab block (largest divisor of V first) until the static
    footprint fits; if nothing fits, keep the smallest candidate and let the
    build-time VMEM_OVERFLOW verdict report it."""
    from repro.core import analyze as _an

    budget = _an.vmem_budget()
    V, bv = int(bdef["V"]), int(bdef["block_v"])
    while True:
        spec = lm_head_bwd_builder(SimpleNamespace(**dict(bdef, block_v=bv)))
        if _an.vmem_footprint(spec)[0] <= budget:
            break
        smaller = next((b for b in range(bv // 2, 0, -1) if V % b == 0), None)
        if smaller is None:
            break
        bv = smaller
    return dict(bdef, block_v=bv)


def _ce_bwd(params, res, g):
    x, w, labels, lse = res
    R = x.shape[0]
    # same padding + fitting policy as the forward (_ce_pre/_ce_defines);
    # padded rows get a ZERO cotangent so they contribute nothing to dw
    pad = _row_padding(R, params["block_r"])
    xp, labp = _pad_rows(x, pad), _pad_rows(labels, pad)
    D = _ce_defines((xp, w, labp), params)
    dev = default_device(params["backend"], params.get("interpret"))
    kern = dev.build_kernel(lm_head_bwd_builder, _fit_bwd_vmem(dict(
        R=D["R"], d=D["d"], V=D["V"], vocab=D["vocab"],
        block_r=D["block_r"], block_v=D["block_v"], dtype=D["dtype"])))
    g2 = _pad_rows(jnp.asarray(g, jnp.float32).reshape(-1, 1), pad)
    dx, dw = kern.run(xp, w, labp, lse, g2)
    # integer primals carry the canonical float0 cotangent
    dlabels = np.zeros(np.shape(labels), jax.dtypes.float0)
    return dx[:R].astype(x.dtype), dw.astype(w.dtype), dlabels


def _ce_tune_ref(args, params):
    # kernel-granularity oracle: autotune validates ALL kernel outputs
    x, w, labels = args
    logits = masked_logits_ref(x, w, vocab=params["vocab"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels.reshape(-1, 1).astype(jnp.int32),
                               axis=-1)[:, 0]
    return lse[:, None], gold[:, None]


def _ce_example(rng):
    x = rng.randn(24, 16).astype("float32")
    w = rng.randn(16, 64).astype("float32")
    labels = rng.randint(0, 50, (24, 1)).astype("int32")
    return (x, w, labels), dict(vocab=50, block_r=8, block_v=16, block_k=8)


lm_head_ce = define_op(
    "lm_head_ce",
    builder=lm_head_builder,
    ref=lm_head_ce_ref,
    derive_defines=_ce_defines,
    pre=_ce_pre,
    vjp=OpVJP(bwd=_ce_bwd, residuals=_ce_residuals),
    post=_ce_post,
    defaults=dict(vocab=None, block_r=256, block_v=512, block_k=512),
    ref_params=("vocab",),
    tune_ref=_ce_tune_ref,
    sweep=dict(block_r=[128, 256, 512], block_v=[256, 512, 1024],
               block_k=[128, 256, 512]),
    example=_ce_example,
    doc="""Fused LM-head cross-entropy: x (R, d) @ w (d, V) -> per-row NLL
    (R,) f32 in ONE pass (online softmax over vocab blocks; the (R, V)
    logits never materialize). labels (R, 1) i32; ``vocab`` masks Megatron
    padding columns >= vocab. Differentiable: the backward recomputes
    softmax - onehot blockwise from the saved lse on the same backend.""",
)


def _logits_pre(args, params):
    x, w = args
    return _pad_rows(x, _row_padding(x.shape[0], params["block_r"])), w


def _logits_post(outs, args, params):
    logits, = outs                              # public output only
    return logits[:args[0].shape[0]]


def _logits_tune_ref(args, params):
    return lm_head_logits_ref(*args, vocab=params["vocab"])


def _logits_example(rng):
    x = rng.randn(8, 16).astype("float32")
    w = rng.randn(16, 64).astype("float32")
    return (x, w), dict(vocab=50, block_r=8, block_v=16, block_k=8)


def _logits_public_ref(x, w, *, vocab=None):
    return masked_logits_ref(x, w, vocab=vocab)


lm_head_logits = define_op(
    "lm_head_logits",
    builder=lm_head_builder,
    ref=_logits_public_ref,
    derive_defines=_logits_defines,
    pre=_logits_pre,
    post=_logits_post,
    public_outputs=1,                           # m/arg via .raw (serving)
    defaults=dict(vocab=None, block_r=256, block_v=512, block_k=512),
    ref_params=("vocab",),
    tune_ref=_logits_tune_ref,
    sweep=dict(block_v=[256, 512, 1024], block_k=[128, 256, 512]),
    example=_logits_example,
    doc="""Fused LM-head logits for decode: x (R, d) @ w (d, V) -> masked
    logits (R, V) f32, plus (on ``.raw``) the per-row max and first-
    occurrence argmax over the true vocab — the greedy token comes out of
    the SAME pass as the logits.""",
)
