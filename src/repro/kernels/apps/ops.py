"""The paper's three benchmark workloads as ``define_op`` declarations.

OCCA's headline results are finite-difference, spectral-element and
discontinuous-Galerkin kernels; here each one is a registered op over the
unified kernel language — oracle-validated, statically analyzed, autotunable
with persisted winners — instead of a bespoke driver-only code path:

  ``fd2d``        one leapfrog step of the §4.1 acoustic wave stencil
                  (halo input tile; tuned over 2-D ``(bh, bw)`` blocks)
  ``sem_apply``   the §4.2 screened-Coulomb SEM operator on local dofs
                  (tuned over elements-per-block ``eb``)
  ``dg_volume``   the §4.3 DG shallow-water volume RHS        (tuned ``eb``)
  ``dg_surface``  the DG surface-flux RHS (Lax-Friedrichs + LIFT) on
                  pre-gathered face traces                    (tuned ``eb``)

The app drivers (``repro.apps``) run THROUGH these ops, adopting persisted
autotune winners the same way serving adopts LM-kernel winners.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps.dg_swe import (
    GRAV, dg_surface_builder, dg_volume_builder, surface_ref, volume_ref)
from repro.apps.fd2d import fd2d_builder, reference_step
from repro.apps.sem import apply_ref, sem_builder
from repro.core import define_op, fit_block, oracle_vjp

__all__ = ["fd2d", "sem_apply", "dg_volume", "dg_surface"]


# ---------------------------------------------------------------------------
# fd2d — §4.1 finite-difference wave step
# ---------------------------------------------------------------------------

def _fd_defines(args, params):
    u1, u2 = args
    h, w = u1.shape
    weights = tuple(float(x) for x in params["weights"])
    return dict(w=w, h=h, r=(len(weights) - 1) // 2, weights=weights,
                dt=float(params["dt"]), dx=float(params["dx"]),
                bh=fit_block(params["bh"], h), bw=fit_block(params["bw"], w),
                dtype=jnp.dtype(u1.dtype).name)


def _fd_example(rng):
    u1 = rng.standard_normal((32, 32)).astype("float32")
    u2 = rng.standard_normal((32, 32)).astype("float32")
    return (u1, u2), dict(weights=(1.0, -2.0, 1.0), dx=2.0 / 32, dt=0.02,
                          bh=16, bw=32)


fd2d = define_op(
    "fd2d",
    builder=fd2d_builder,
    ref=reference_step,
    derive_defines=_fd_defines,
    vjp=oracle_vjp(reference_step, params=("weights", "dx", "dt")),
    defaults=dict(weights=(1.0, -2.0, 1.0), dx=1.0, dt=0.1, bh=32, bw=256),
    ref_params=("weights", "dx", "dt"),
    sweep=dict(bh=[8, 16, 32, 64, 128], bw=[32, 64, 128, 256]),
    example=_fd_example,
    doc="""One leapfrog step: u3 = 2 u1 - u2 + dt^2 (u_xx + u_yy).

    ``u1``/``u2``: (h, w) fields at t_n / t_{n-1}; ``weights`` the order-2r
    central second-derivative stencil. Periodic boundaries via the kernel
    language's halo tiles — each grid cell reads only its
    ``(bh + 2r, bw + 2r)`` window, never the whole field.""",
)


# ---------------------------------------------------------------------------
# sem_apply — §4.2 spectral-element operator
# ---------------------------------------------------------------------------

def _sem_defines(args, params):
    u, geo, dmat = args
    E, nq = u.shape[0], u.shape[1]
    return dict(E=E, nq=nq, eb=fit_block(params["eb"], E),
                dtype=jnp.dtype(u.dtype).name)


def _sem_example(rng):
    E, nq = 8, 3
    u = rng.standard_normal((E, nq, nq, nq)).astype("float32")
    geo = rng.standard_normal((E, 7, nq, nq, nq)).astype("float32")
    dmat = rng.standard_normal((nq, nq)).astype("float32")
    return (u, geo, dmat), dict(eb=4)


sem_apply = define_op(
    "sem_apply",
    builder=sem_builder,
    ref=apply_ref,
    derive_defines=_sem_defines,
    vjp=oracle_vjp(apply_ref),
    defaults=dict(eb=32),
    sweep=dict(eb=[1, 2, 4, 8, 16, 32, 64]),
    example=_sem_example,
    doc="""A u = K u + alpha M u on local dofs: ``u`` (E, nq, nq, nq),
    ``geo`` (E, 7, nq, nq, nq) symmetric geometric factors, ``dmat``
    (nq, nq) the 1-D GLL derivative matrix (a whole-array shared tile).""",
)


# ---------------------------------------------------------------------------
# dg_volume / dg_surface — §4.3 DG shallow-water RHS
# ---------------------------------------------------------------------------

def _dgv_defines(args, params):
    q, geom, db, dr, ds = args
    E, np_ = q.shape[0], q.shape[1]
    return dict(E=E, np_=np_, eb=fit_block(params["eb"], E),
                g=float(params["g"]), dtype=jnp.dtype(q.dtype).name)


def _dgv_example(rng):
    E, np_ = 16, 6
    q = rng.standard_normal((E, np_, 3)).astype("float32") * 0.1
    q[..., 0] += 1.5                          # positive water height
    geom = rng.standard_normal((E, 4)).astype("float32")
    db = rng.standard_normal((E, np_, 2)).astype("float32")
    dr = rng.standard_normal((np_, np_)).astype("float32")
    ds = rng.standard_normal((np_, np_)).astype("float32")
    return (q, geom, db, dr, ds), dict(eb=4)


dg_volume = define_op(
    "dg_volume",
    builder=dg_volume_builder,
    ref=volume_ref,
    derive_defines=_dgv_defines,
    vjp=oracle_vjp(volume_ref, params=("g",)),
    defaults=dict(g=GRAV, eb=64),
    ref_params=("g",),
    sweep=dict(eb=[1, 2, 4, 8, 16, 32, 64]),
    example=_dgv_example,
    doc="""DG SWE volume RHS: -(dF/dx + dG/dy) + S on nodal triangles.
    ``q`` (E, np, 3) conserved variables, ``geom`` (E, 4) affine factors,
    ``db`` (E, np, 2) bathymetry gradients, ``dr``/``ds`` shared (np, np)
    derivative matrices.""",
)


def _dgs_defines(args, params):
    qm, qp, nrm, lift = args
    E, nfp3 = qm.shape[0], qm.shape[1]
    return dict(E=E, np_=lift.shape[0], nfp3=nfp3,
                eb=fit_block(params["eb"], E), g=float(params["g"]),
                dtype=jnp.dtype(qm.dtype).name)


def _dgs_example(rng):
    E, np_, nfp3 = 16, 6, 9
    qm = rng.standard_normal((E, nfp3, 3)).astype("float32") * 0.1
    qp = rng.standard_normal((E, nfp3, 3)).astype("float32") * 0.1
    qm[..., 0] += 1.5
    qp[..., 0] += 1.5
    theta = rng.standard_normal((E, nfp3)).astype("float32")
    nrm = np.stack([np.cos(theta), np.sin(theta),
                    np.abs(rng.standard_normal((E, nfp3))).astype("float32")],
                   axis=-1).astype("float32")
    lift = rng.standard_normal((np_, nfp3)).astype("float32")
    return (qm, qp, nrm, lift), dict(eb=4)


dg_surface = define_op(
    "dg_surface",
    builder=dg_surface_builder,
    ref=surface_ref,
    derive_defines=_dgs_defines,
    vjp=oracle_vjp(surface_ref, params=("g",)),
    defaults=dict(g=GRAV, eb=64),
    ref_params=("g",),
    sweep=dict(eb=[1, 2, 4, 8, 16, 32, 64]),
    example=_dgs_example,
    doc="""DG SWE surface RHS: local Lax-Friedrichs flux on pre-gathered
    face traces ``qm``/``qp`` (E, 3nfp, 3) lifted to volume nodes.
    ``nrm`` (E, 3nfp, 3) packs (nx, ny, fscale); ``lift`` (np, 3nfp) is
    the shared LIFT matrix. The face gather (the 'communication') stays
    outside the kernel — GPU-DG practice.""",
)
