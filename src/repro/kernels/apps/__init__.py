"""Paper-workload ops (FD/SEM/DG) as first-class ``define_op`` citizens."""

from .ops import dg_surface, dg_volume, fd2d, sem_apply

__all__ = ["fd2d", "sem_apply", "dg_volume", "dg_surface"]
