"""Pure-jnp oracle for (GQA / causal / sliding-window) attention."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["mha_ref", "decode_ref", "paged_decode_ref", "rolling_slot_pos"]


def rolling_slot_pos(window: int, t: int):
    """The slot -> absolute-position map of a rolling cache of ``window``
    slots after ``t`` decoded tokens (slot = pos % window; -1 = never
    written). THE definition of the rolling-cache layout contract — shared
    by benchmarks, examples and the decode oracle's callers."""
    import numpy as np

    sp = np.full((window,), -1, np.int32)
    for p in range(max(t - window, 0), t):
        sp[p % window] = p
    return sp


def _expand_kv(k, n_q_heads):
    """(B, Hk, S, D) -> (B, H, S, D) by group broadcast."""
    b, hk, s, d = k.shape
    g = n_q_heads // hk
    return jnp.repeat(k, g, axis=1)


def _mask(sq, skv, *, causal, window, prefix_len):
    q_pos = jnp.arange(sq) + (skv - sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    if prefix_len:
        # prefix-LM (paligemma): keys inside the prefix are always visible
        mask |= jnp.broadcast_to(k_pos[None, :] < prefix_len, mask.shape)
    return mask


def mha_ref(q, k, v, *, causal=True, window=None, sm_scale=None, prefix_len=0):
    """q: (B, H, Sq, Dqk); k: (B, Hk, Skv, Dqk); v: (B, Hk, Skv, Dv).

    ``window`` (int) masks keys with q_pos - k_pos >= window (sliding window,
    mixtral-style; the diagonal is always kept). ``prefix_len`` makes the
    first ``prefix_len`` keys visible to every query (prefix-LM). Query
    positions are aligned to the END of the kv sequence (prefill: Sq == Skv;
    decode: Sq < Skv).
    """
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    dv = v.shape[-1]
    g = h // hk
    if sm_scale is None:
        sm_scale = 1.0 / d ** 0.5
    # grouped einsums: no repeated-kv materialization, no f32 kv copies
    # (f32 MXU accumulation via preferred_element_type)
    qg = q.reshape(b, hk, g, sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * sm_scale
    mask = _mask(sq, skv, causal=causal, window=window, prefix_len=prefix_len)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask[None, None, None], p, 0.0)
    denom = p.sum(-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, sq, dv).astype(q.dtype)


def mha_chunked(q, k, v, *, causal=True, window=None, sm_scale=None,
                prefix_len=0, block_q=1024):
    """Memory-sane jnp attention: lax.scan over query blocks (online softmax
    not needed — full key dim per block, O(B*H*block_q*Skv) working set).
    Used by the models for long prefills (the XLA path of the flash design).
    """
    import jax

    b, h, sq, dqk = q.shape
    _, hk, skv, dv = v.shape
    if sm_scale is None:
        sm_scale = 1.0 / dqk ** 0.5
    block_q = min(block_q, sq)
    while sq % block_q:
        block_q -= 1
    nq = sq // block_q
    g = h // hk
    q4 = q.reshape(b, hk, g, sq, dqk)
    k_pos = jnp.arange(skv)
    q_off = skv - sq

    def one_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(q4, qi * block_q, block_q, axis=3)
        s = jnp.einsum("bkgqd,bksd->bkgqs", qb, k,
                       preferred_element_type=jnp.float32) * sm_scale
        q_pos = qi * block_q + jnp.arange(block_q) + q_off
        mask = jnp.ones((block_q, skv), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        if prefix_len:
            mask |= k_pos[None, :] < prefix_len
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jnp.exp(s - s.max(-1, keepdims=True))
        p = jnp.where(mask[None, None, None], p, 0.0)
        denom = p.sum(-1, keepdims=True)
        p = p / jnp.where(denom == 0, 1.0, denom)
        return jnp.einsum("bkgqs,bksd->bkgqd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32)

    blocks = jax.lax.map(one_block, jnp.arange(nq))       # (nq,b,hk,g,block_q,dv)
    out = jnp.moveaxis(blocks, 0, 3).reshape(b, hk, g, sq, dv)
    return out.reshape(b, h, sq, dv).astype(q.dtype)


def decode_ref(q, k, v, *, window=None, sm_scale=None, kv_len=None,
               slot_pos=None):
    """Single-token decode oracle: q (B, H, 1, D) vs a cache (B, Hk, S, D).

    Positional caches (slot i holds position i): ``kv_len`` (a concrete int)
    truncates to the valid prefix; masking is mha_ref's causal/window mask.
    ROTATED rolling-window caches: pass ``slot_pos`` ((S,) i32 — each slot's
    absolute position, -1 for never-written) plus ``kv_len``; masking is then
    slot_pos-driven, scoring the same function as the unified ``flash_decode``
    kernel. This is the oracle the windowed autotune validates against."""
    if slot_pos is None:
        if kv_len is not None:
            k, v = k[:, :, :kv_len], v[:, :, :kv_len]
        return mha_ref(q, k, v, causal=True, window=window, sm_scale=sm_scale)
    b, h, _, d = q.shape
    hk, m = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hk
    if sm_scale is None:
        sm_scale = 1.0 / d ** 0.5
    sp = jnp.asarray(slot_pos, jnp.int32).reshape(-1)
    q_pos = (sp.max() if kv_len is None
             else jnp.asarray(kv_len, jnp.int32).reshape(()) - 1)
    mask = (sp >= 0) & (sp <= q_pos)
    if window is not None:
        mask &= (q_pos - sp) < window
    qg = q.reshape(b, hk, g, d)
    s = jnp.einsum("bkgd,bkmd->bkgm", qg, k,
                   preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask[None, None, None], p, 0.0)
    denom = p.sum(-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    o = jnp.einsum("bkgm,bkmd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, 1, dv).astype(q.dtype)


def paged_decode_ref(q, k_pages, v_pages, *, block_table, kv_len=None,
                     pos_pages=None, window=None, sm_scale=None):
    """Paged single-token decode oracle: q (B, H, 1, D) against page POOLS.

    The cache is a pool of fixed-size pages shared by every sequence —
    k_pages (P, Hk, page, D), v_pages (P, Hk, page, Dv) — and each sequence
    owns the pages its ``block_table`` row names: block_table (B, n_seq_pages)
    i32, logical block j of sequence b living in pool page block_table[b, j].
    ``kv_len`` ((B,) or (B, 1) i32) is each sequence's valid prefix length;
    ``pos_pages`` ((P, page) i32, -1 = empty) gives each pool slot's absolute
    position (rotated-window layouts); omitted, logical order is positional.
    This is the function ``flash_decode_paged`` computes; per-sequence it
    equals ``decode_ref`` on the gathered contiguous cache."""
    b, h, _, d = q.shape
    npages, hk, page, _ = k_pages.shape
    dv = v_pages.shape[-1]
    g = h // hk
    if sm_scale is None:
        sm_scale = 1.0 / d ** 0.5
    tab = jnp.asarray(block_table, jnp.int32).reshape(b, -1)
    nsp = tab.shape[1]
    m = nsp * page
    if kv_len is None:
        kv_len = m
    n = jnp.asarray(kv_len, jnp.int32).reshape(-1)
    if n.shape[0] == 1:
        n = jnp.broadcast_to(n, (b,))
    n = n.reshape(b)
    # gather each sequence's pages into logical-contiguous (B, Hk, m, D)
    kb = jnp.moveaxis(k_pages[tab], 2, 1).reshape(b, hk, m, d)
    vb = jnp.moveaxis(v_pages[tab], 2, 1).reshape(b, hk, m, dv)
    if pos_pages is None:
        sp = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (b, m))
    else:
        sp = jnp.asarray(pos_pages, jnp.int32)[tab].reshape(b, m)
    q_pos = n - 1                                          # (B,)
    mask = (sp >= 0) & (sp <= q_pos[:, None])
    if window is not None:
        mask &= (q_pos[:, None] - sp) < window
    qg = q.reshape(b, hk, g, d)
    s = jnp.einsum("bkgd,bkmd->bkgm", qg, kb,
                   preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask[:, None, None], p, 0.0)
    denom = p.sum(-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    o = jnp.einsum("bkgm,bkmd->bkgd", p.astype(vb.dtype), vb,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, 1, dv).astype(q.dtype)
