"""Public flash-attention ops — ``define_op`` declarations, fwd AND bwd.

``flash_attention`` is one declaration with a fully unified custom VJP: the
forward runs ``flash_fwd_builder`` on any backend; the backward runs the
delta-precompute and the ONE fused dq/dk/dv kernel (``flash_bwd_builder``,
per-output reduce granularity) on the SAME backend, wired through the
front-end's VJP declaration. No O(S^2) residuals are saved — only
(q, k, v, o, lse); the backward recomputes p blockwise from the lse stats.

``flash_decode`` is a second declaration for single-token serving: the same
online-softmax kernel specialized to one query row, with TWO dynamic inputs
— ``kv_len`` masking the unfilled tail of the cache and ``slot_pos`` mapping
each cache slot to its absolute position, so rotated rolling-window caches
run the same kernel (no grad needed at serving time). ``decode_attention``
is its thin public wrapper.

``flash_decode_paged`` is the continuous-batching variant: KV lives in a
POOL of fixed-size pages shared by every sequence, and a per-sequence
``block_table`` (the vLLM PagedAttention idiom) is declared as a
tile-indexed index map (``Tile(index_tile=...)``) — the kernel's K/V index
maps read the table at runtime to gather non-contiguous pages, on every
backend, with the indirection analyzer-bounds-checked (``BOUNDS_TABLE``)
and cost-priced as a gather. ``paged_decode_attention`` is its wrapper.
There is no kernel-side tuning knob: the block size IS the page size, a
property of the pool layout the serving engine owns (it adopts
``flash_decode``'s tuned ``block_kv`` winner as its page size).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core import OpVJP, define_op, fit_block
from .kernel import (flash_attention_bwd, flash_decode_builder,
                     flash_fwd_builder, paged_decode_builder)
from .ref import decode_ref, mha_ref, paged_decode_ref

__all__ = ["flash_attention", "flash_decode", "decode_attention",
           "flash_decode_paged", "paged_decode_attention",
           "flash_attention_fwd"]


def _defines(args, params):
    q, k, v = args
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    dv = v.shape[-1]
    if h % hk:
        raise ValueError(f"flash_attention: {h} query heads not a multiple of "
                         f"{hk} kv heads")
    if q.dtype != k.dtype or q.dtype != v.dtype:
        raise ValueError(f"flash_attention: dtypes disagree "
                         f"({q.dtype}/{k.dtype}/{v.dtype})")
    block_q, block_kv = params["block_q"], params["block_kv"]
    bq, bkv = fit_block(block_q, sq), fit_block(block_kv, skv)
    ncells = b * h * (sq // bq) * (skv // bkv)
    degraded = bq < min(block_q, sq) or bkv < min(block_kv, skv)
    if degraded and ncells > 1 << 16:
        raise ValueError(
            f"flash_attention: seq lens ({sq}, {skv}) degraded blocks to "
            f"({bq}, {bkv}) = {ncells} grid cells; pad the sequences or pass "
            "block sizes that divide them")
    sm_scale = params["sm_scale"]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    window = params["window"]
    return dict(
        b=b, h=h, hk=hk, sq=sq, skv=skv, d=d, dv=dv,
        block_q=bq, block_kv=bkv,
        causal=bool(params["causal"]),
        window=None if window is None else int(window),
        prefix_len=int(params["prefix_len"]),
        sm_scale=float(sm_scale),
        dtype=jnp.dtype(q.dtype).name)


def _residuals(outs, args, params):
    o, lse = outs
    q, k, v = args
    return q, k, v, o, lse


def _bwd(params, res, g):
    q, k, v, o, lse = res
    backend = params["backend"]   # already resolved by the VJP front-end
    # re-derive through _defines so fwd and bwd share ONE fitting policy
    # (block sizes, sm_scale default) — the raw requested blocks may not
    # divide the sequence lengths
    D = _defines((q, k, v), params)
    return flash_attention_bwd(
        q, k, v, o, g, lse, causal=D["causal"], window=D["window"],
        sm_scale=D["sm_scale"], prefix_len=D["prefix_len"],
        block_q=D["block_q"], block_kv=D["block_kv"], backend=backend,
        interpret=params.get("interpret"))


def _tune_ref(args, params):
    q, k, v = args
    kw = {k_: params[k_] for k_ in ("causal", "window", "sm_scale", "prefix_len")}
    return mha_ref(q, k, v, **kw)  # validates o; lse has no oracle here


def _example(rng):
    q = rng.randn(1, 4, 64, 32).astype("float32")
    k = rng.randn(1, 2, 64, 32).astype("float32")
    v = rng.randn(1, 2, 64, 32).astype("float32")
    return (q, k, v), dict(causal=True, block_q=32, block_kv=32)


flash_attention = define_op(
    "flash_attention",
    builder=flash_fwd_builder,
    ref=mha_ref,
    derive_defines=_defines,
    vjp=OpVJP(bwd=_bwd, residuals=_residuals),
    public_outputs=1,                       # lse is residual-only
    defaults=dict(causal=True, window=None, sm_scale=None, prefix_len=0,
                  block_q=128, block_kv=128),
    ref_params=("causal", "window", "sm_scale", "prefix_len"),
    tune_ref=_tune_ref,
    sweep=dict(block_q=[64, 128, 256, 512], block_kv=[64, 128, 256, 512]),
    example=_example,
    doc="""Differentiable flash attention. q (B,H,Sq,Dqk), k (B,Hk,Skv,Dqk),
    v (B,Hk,Skv,Dv); supports GQA/MQA, causal, sliding-window and prefix-LM
    masking. Unified-language forward AND backward (one fused dq/dk/dv
    kernel) on every backend.""",
)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, sm_scale=None,
                        prefix_len=0, block_q=128, block_kv=128,
                        backend="auto", interpret=None):
    """Forward + lse stats (functional; the op's full kernel output)."""
    return flash_attention.raw(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        prefix_len=prefix_len, block_q=block_q, block_kv=block_kv,
        backend=backend, interpret=interpret)


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------

def _decode_pre(args, params):
    # read-only on params (.get, never .pop): pre hooks must not eat keys
    # from a dict a caller may reuse across calls
    q, k, v = args
    skv = k.shape[2]
    kv_len = params.get("kv_len")
    if kv_len is None:
        kv_len = skv                         # full cache valid
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1, 1)
    slot_pos = params.get("slot_pos")
    if slot_pos is None:
        # positional default — slot i holds absolute position i — so callers
        # without rotated caches are untouched (the old iota mask, exactly)
        slot_pos = jnp.arange(skv, dtype=jnp.int32)
    slot_pos = jnp.asarray(slot_pos, jnp.int32).reshape(1, skv)
    return q, k, v, kv_len, slot_pos


def _decode_defines(args, params):
    q, k, v, kv_len, slot_pos = args
    b, h, one, d = q.shape
    if one != 1:
        raise ValueError(f"flash_decode: expected a single query token, "
                         f"got q of shape {q.shape}")
    _, hk, skv, _ = k.shape
    dv = v.shape[-1]
    if h % hk:
        raise ValueError(f"flash_decode: {h} query heads not a multiple of "
                         f"{hk} kv heads")
    if q.dtype != k.dtype or q.dtype != v.dtype:
        raise ValueError(f"flash_decode: dtypes disagree "
                         f"({q.dtype}/{k.dtype}/{v.dtype})")
    if tuple(slot_pos.shape) != (1, skv):
        raise ValueError(f"flash_decode: slot_pos shape {slot_pos.shape} "
                         f"does not match the cache length ({skv} slots)")
    want = params["block_kv"]
    bkv = fit_block(want, skv)
    ncells = b * h * (skv // bkv)
    if bkv < min(want, skv) and ncells > 1 << 16:
        raise ValueError(
            f"flash_decode: cache len {skv} degraded block_kv to {bkv} = "
            f"{ncells} grid cells; pad the cache or pass a dividing block_kv")
    sm_scale = params["sm_scale"]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    window = params["window"]
    return dict(
        b=b, h=h, hk=hk, skv=skv, d=d, dv=dv, block_kv=bkv,
        window=None if window is None else int(window),
        sm_scale=float(sm_scale),
        dtype=jnp.dtype(q.dtype).name)


def _decode_tune_ref(args, params):
    import numpy as np

    # slot_pos-aware oracle: the tune validation scores rotated caches the
    # same way the kernel does (a truncating positional oracle would declare
    # every windowed candidate wrong)
    q, k, v, kv_len, slot_pos = args
    n = int(np.asarray(kv_len).reshape(-1)[0])
    return decode_ref(q, k, v, window=params["window"],
                      sm_scale=params["sm_scale"], kv_len=n,
                      slot_pos=jnp.asarray(slot_pos).reshape(-1))


def _decode_example(rng):
    q = rng.randn(1, 4, 1, 32).astype("float32")
    k = rng.randn(1, 2, 128, 32).astype("float32")
    v = rng.randn(1, 2, 128, 32).astype("float32")
    return (q, k, v), dict(block_kv=32)


flash_decode = define_op(
    "flash_decode",
    builder=flash_decode_builder,
    ref=decode_ref,
    derive_defines=_decode_defines,
    pre=_decode_pre,
    defaults=dict(window=None, sm_scale=None, block_kv=512),
    array_params=("kv_len", "slot_pos"),    # dynamic length + slot positions
    ref_params=("window", "sm_scale"),
    tune_ref=_decode_tune_ref,
    sweep=dict(block_kv=[128, 256, 512, 1024]),
    example=_decode_example,
    doc="""Single-token decode attention: q (B,H,1,D) against a kv cache
    (B,Hk,S,D). ``kv_len`` (int or traced scalar) masks the unfilled tail of
    the cache — the query sits at position kv_len-1 — so one compiled kernel
    serves every step of an incremental-decode loop. ``slot_pos`` ((S,) i32,
    -1 = empty) gives each cache slot's absolute position for ROTATED
    rolling-window caches (slot = pos % W); omitted, slots are positional.""",
)


# ---------------------------------------------------------------------------
# paged single-token decode (continuous batching)
# ---------------------------------------------------------------------------

def _paged_pre(args, params):
    # read-only on params (.get, never .pop) — same contract as _decode_pre
    q, k, v = args
    npages, _, page, _ = k.shape
    b = q.shape[0]
    table = params.get("block_table")
    if table is None:
        raise ValueError(
            "flash_decode_paged: block_table= is required — per-sequence "
            "page indices into the pool, shape (B, n_seq_pages) i32")
    table = jnp.asarray(table, jnp.int32)
    if table.ndim == 1:
        table = table[None]
    nsp = table.shape[-1]
    table = table.reshape(b, nsp)
    kv_len = params.get("kv_len")
    if kv_len is None:
        kv_len = nsp * page                  # full logical capacity valid
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(-1)
    if kv_len.shape[0] == 1:
        kv_len = jnp.broadcast_to(kv_len, (b,))
    kv_len = kv_len.reshape(b, 1)
    pos = params.get("pos_pages")
    if pos is None:
        # positional default: logical block j of sequence b holds absolute
        # positions [j*page, (j+1)*page), scattered through the table into
        # pool layout. Pages no sequence's valid prefix reaches stay -1
        # (empty), so junk table entries past kv_len can never score.
        logical = jnp.arange(nsp * page, dtype=jnp.int32).reshape(nsp, page)
        valid = (jnp.arange(nsp, dtype=jnp.int32) * page)[None, :] < kv_len
        tgt = jnp.where(valid, table, npages)        # sentinel rows drop
        pos = jnp.full((npages, page), -1, jnp.int32).at[tgt.reshape(-1)].set(
            jnp.broadcast_to(logical, (b, nsp, page)).reshape(-1, page),
            mode="drop")
    pos = jnp.asarray(pos, jnp.int32).reshape(npages, page)
    return q, k, v, table, kv_len, pos


def _paged_defines(args, params):
    q, k, v, table, kv_len, pos = args
    b, h, one, d = q.shape
    if one != 1:
        raise ValueError(f"flash_decode_paged: expected a single query token, "
                         f"got q of shape {q.shape}")
    npages, hk, page, _ = k.shape
    dv = v.shape[-1]
    if h % hk:
        raise ValueError(f"flash_decode_paged: {h} query heads not a multiple "
                         f"of {hk} kv heads")
    if q.dtype != k.dtype or q.dtype != v.dtype:
        raise ValueError(f"flash_decode_paged: dtypes disagree "
                         f"({q.dtype}/{k.dtype}/{v.dtype})")
    if tuple(v.shape[:3]) != (npages, hk, page):
        raise ValueError(f"flash_decode_paged: v pool shape {v.shape} does "
                         f"not match k pool {k.shape}")
    nsp = table.shape[-1]
    if tuple(pos.shape) != (npages, page):
        raise ValueError(f"flash_decode_paged: pos_pages shape {pos.shape} "
                         f"does not match the pool ({npages} pages of "
                         f"{page} slots)")
    sm_scale = params["sm_scale"]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    window = params["window"]
    return dict(
        b=b, h=h, hk=hk, d=d, dv=dv, npages=npages, page=page,
        nseq_pages=nsp,
        window=None if window is None else int(window),
        sm_scale=float(sm_scale),
        dtype=jnp.dtype(q.dtype).name)


def _paged_tune_ref(args, params):
    q, k, v, table, kv_len, pos = args
    return paged_decode_ref(q, k, v, block_table=table, kv_len=kv_len,
                            pos_pages=pos, window=params["window"],
                            sm_scale=params["sm_scale"])


def _paged_example(rng):
    import numpy as np

    q = rng.randn(1, 4, 1, 32).astype("float32")
    k = rng.randn(8, 2, 32, 32).astype("float32")
    v = rng.randn(8, 2, 32, 32).astype("float32")
    table = np.array([[1, 3, 2, 5]], np.int32)   # non-contiguous pages
    return (q, k, v), dict(block_table=table, kv_len=100)


flash_decode_paged = define_op(
    "flash_decode_paged",
    builder=paged_decode_builder,
    ref=paged_decode_ref,
    derive_defines=_paged_defines,
    pre=_paged_pre,
    defaults=dict(window=None, sm_scale=None),
    array_params=("block_table", "kv_len", "pos_pages"),
    # the array params ride ref_params too: the oracle needs the table
    ref_params=("window", "sm_scale", "block_table", "kv_len", "pos_pages"),
    tune_ref=_paged_tune_ref,
    sweep=dict(),             # the page size IS the block size (pool layout)
    example=_paged_example,
    doc="""Paged single-token decode attention: q (B,H,1,D) against page
    POOLS k (P,Hk,page,D) / v (P,Hk,page,Dv), gathered through a per-sequence
    ``block_table`` ((B,n_seq_pages) i32) read by the kernel's index maps at
    runtime (a tile-indexed index map — no contiguous copy on any backend).
    ``kv_len`` ((B,) i32) is per-sequence; ``pos_pages`` ((P,page) i32, -1 =
    empty) gives pool slots' absolute positions for rotated-window layouts;
    omitted, logical order is positional.""",
)


def paged_decode_attention(q, k_pages, v_pages, *, block_table, kv_len=None,
                           pos_pages=None, window=None, sm_scale=None,
                           backend="auto", interpret=None):
    """Paged decode attention over a shared KV page pool (no grad).

    The serving-engine hot path: each sequence reads its KV through its
    ``block_table`` row, so mixed-length continuous batches share one pool
    with zero copying (see ``flash_decode_paged``)."""
    return flash_decode_paged(
        q, k_pages, v_pages, block_table=block_table, kv_len=kv_len,
        pos_pages=pos_pages, window=window, sm_scale=sm_scale,
        backend=backend, interpret=interpret)


def decode_attention(q, k, v, *, window=None, sm_scale=None, block_kv=None,
                     kv_len=None, slot_pos=None, backend="auto",
                     interpret=None):
    """Single-token decode attention (no grad needed at serving time).

    ``block_kv=None`` (the default) defers to the op's current default —
    which serving warmup may have replaced with a persisted tune winner; an
    explicit value always wins. ``slot_pos`` routes rotated rolling-window
    caches through the SAME kernel (see ``flash_decode``)."""
    kw = {} if block_kv is None else {"block_kv": block_kv}
    return flash_decode(q, k, v, window=window, sm_scale=sm_scale,
                        kv_len=kv_len, slot_pos=slot_pos, backend=backend,
                        interpret=interpret, **kw)
