"""Public flash-attention ops — ``define_op`` declarations, fwd AND bwd.

``flash_attention`` is one declaration with a fully unified custom VJP: the
forward runs ``flash_fwd_builder`` on any backend; the backward runs the
delta-precompute and the ONE fused dq/dk/dv kernel (``flash_bwd_builder``,
per-output reduce granularity) on the SAME backend, wired through the
front-end's VJP declaration. No O(S^2) residuals are saved — only
(q, k, v, o, lse); the backward recomputes p blockwise from the lse stats.

``flash_decode`` is a second declaration for single-token serving: the same
online-softmax kernel specialized to one query row, with TWO dynamic inputs
— ``kv_len`` masking the unfilled tail of the cache and ``slot_pos`` mapping
each cache slot to its absolute position, so rotated rolling-window caches
run the same kernel (no grad needed at serving time). ``decode_attention``
is its thin public wrapper.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core import OpVJP, define_op, fit_block
from .kernel import flash_attention_bwd, flash_decode_builder, flash_fwd_builder
from .ref import decode_ref, mha_ref

__all__ = ["flash_attention", "flash_decode", "decode_attention",
           "flash_attention_fwd"]


def _defines(args, params):
    q, k, v = args
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    dv = v.shape[-1]
    if h % hk:
        raise ValueError(f"flash_attention: {h} query heads not a multiple of "
                         f"{hk} kv heads")
    if q.dtype != k.dtype or q.dtype != v.dtype:
        raise ValueError(f"flash_attention: dtypes disagree "
                         f"({q.dtype}/{k.dtype}/{v.dtype})")
    block_q, block_kv = params["block_q"], params["block_kv"]
    bq, bkv = fit_block(block_q, sq), fit_block(block_kv, skv)
    ncells = b * h * (sq // bq) * (skv // bkv)
    degraded = bq < min(block_q, sq) or bkv < min(block_kv, skv)
    if degraded and ncells > 1 << 16:
        raise ValueError(
            f"flash_attention: seq lens ({sq}, {skv}) degraded blocks to "
            f"({bq}, {bkv}) = {ncells} grid cells; pad the sequences or pass "
            "block sizes that divide them")
    sm_scale = params["sm_scale"]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    window = params["window"]
    return dict(
        b=b, h=h, hk=hk, sq=sq, skv=skv, d=d, dv=dv,
        block_q=bq, block_kv=bkv,
        causal=bool(params["causal"]),
        window=None if window is None else int(window),
        prefix_len=int(params["prefix_len"]),
        sm_scale=float(sm_scale),
        dtype=jnp.dtype(q.dtype).name)


def _residuals(outs, args, params):
    o, lse = outs
    q, k, v = args
    return q, k, v, o, lse


def _bwd(params, res, g):
    q, k, v, o, lse = res
    backend = params["backend"]   # already resolved by the VJP front-end
    # re-derive through _defines so fwd and bwd share ONE fitting policy
    # (block sizes, sm_scale default) — the raw requested blocks may not
    # divide the sequence lengths
    D = _defines((q, k, v), params)
    return flash_attention_bwd(
        q, k, v, o, g, lse, causal=D["causal"], window=D["window"],
        sm_scale=D["sm_scale"], prefix_len=D["prefix_len"],
        block_q=D["block_q"], block_kv=D["block_kv"], backend=backend,
        interpret=params.get("interpret"))


def _tune_ref(args, params):
    q, k, v = args
    kw = {k_: params[k_] for k_ in ("causal", "window", "sm_scale", "prefix_len")}
    return mha_ref(q, k, v, **kw)  # validates o; lse has no oracle here


def _example(rng):
    q = rng.randn(1, 4, 64, 32).astype("float32")
    k = rng.randn(1, 2, 64, 32).astype("float32")
    v = rng.randn(1, 2, 64, 32).astype("float32")
    return (q, k, v), dict(causal=True, block_q=32, block_kv=32)


flash_attention = define_op(
    "flash_attention",
    builder=flash_fwd_builder,
    ref=mha_ref,
    derive_defines=_defines,
    vjp=OpVJP(bwd=_bwd, residuals=_residuals),
    public_outputs=1,                       # lse is residual-only
    defaults=dict(causal=True, window=None, sm_scale=None, prefix_len=0,
                  block_q=128, block_kv=128),
    ref_params=("causal", "window", "sm_scale", "prefix_len"),
    tune_ref=_tune_ref,
    sweep=dict(block_q=[64, 128, 256, 512], block_kv=[64, 128, 256, 512]),
    example=_example,
    doc="""Differentiable flash attention. q (B,H,Sq,Dqk), k (B,Hk,Skv,Dqk),
    v (B,Hk,Skv,Dv); supports GQA/MQA, causal, sliding-window and prefix-LM
    masking. Unified-language forward AND backward (one fused dq/dk/dv
    kernel) on every backend.""",
)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, sm_scale=None,
                        prefix_len=0, block_q=128, block_kv=128,
                        backend="auto", interpret=None):
    """Forward + lse stats (functional; the op's full kernel output)."""
    return flash_attention.raw(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        prefix_len=prefix_len, block_q=block_q, block_kv=block_kv,
        backend=backend, interpret=interpret)


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------

def _decode_pre(args, params):
    # read-only on params (.get, never .pop): pre hooks must not eat keys
    # from a dict a caller may reuse across calls
    q, k, v = args
    skv = k.shape[2]
    kv_len = params.get("kv_len")
    if kv_len is None:
        kv_len = skv                         # full cache valid
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1, 1)
    slot_pos = params.get("slot_pos")
    if slot_pos is None:
        # positional default — slot i holds absolute position i — so callers
        # without rotated caches are untouched (the old iota mask, exactly)
        slot_pos = jnp.arange(skv, dtype=jnp.int32)
    slot_pos = jnp.asarray(slot_pos, jnp.int32).reshape(1, skv)
    return q, k, v, kv_len, slot_pos


def _decode_defines(args, params):
    q, k, v, kv_len, slot_pos = args
    b, h, one, d = q.shape
    if one != 1:
        raise ValueError(f"flash_decode: expected a single query token, "
                         f"got q of shape {q.shape}")
    _, hk, skv, _ = k.shape
    dv = v.shape[-1]
    if h % hk:
        raise ValueError(f"flash_decode: {h} query heads not a multiple of "
                         f"{hk} kv heads")
    if q.dtype != k.dtype or q.dtype != v.dtype:
        raise ValueError(f"flash_decode: dtypes disagree "
                         f"({q.dtype}/{k.dtype}/{v.dtype})")
    if tuple(slot_pos.shape) != (1, skv):
        raise ValueError(f"flash_decode: slot_pos shape {slot_pos.shape} "
                         f"does not match the cache length ({skv} slots)")
    want = params["block_kv"]
    bkv = fit_block(want, skv)
    ncells = b * h * (skv // bkv)
    if bkv < min(want, skv) and ncells > 1 << 16:
        raise ValueError(
            f"flash_decode: cache len {skv} degraded block_kv to {bkv} = "
            f"{ncells} grid cells; pad the cache or pass a dividing block_kv")
    sm_scale = params["sm_scale"]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    window = params["window"]
    return dict(
        b=b, h=h, hk=hk, skv=skv, d=d, dv=dv, block_kv=bkv,
        window=None if window is None else int(window),
        sm_scale=float(sm_scale),
        dtype=jnp.dtype(q.dtype).name)


def _decode_tune_ref(args, params):
    import numpy as np

    # slot_pos-aware oracle: the tune validation scores rotated caches the
    # same way the kernel does (a truncating positional oracle would declare
    # every windowed candidate wrong)
    q, k, v, kv_len, slot_pos = args
    n = int(np.asarray(kv_len).reshape(-1)[0])
    return decode_ref(q, k, v, window=params["window"],
                      sm_scale=params["sm_scale"], kv_len=n,
                      slot_pos=jnp.asarray(slot_pos).reshape(-1))


def _decode_example(rng):
    q = rng.randn(1, 4, 1, 32).astype("float32")
    k = rng.randn(1, 2, 128, 32).astype("float32")
    v = rng.randn(1, 2, 128, 32).astype("float32")
    return (q, k, v), dict(block_kv=32)


flash_decode = define_op(
    "flash_decode",
    builder=flash_decode_builder,
    ref=decode_ref,
    derive_defines=_decode_defines,
    pre=_decode_pre,
    defaults=dict(window=None, sm_scale=None, block_kv=512),
    array_params=("kv_len", "slot_pos"),    # dynamic length + slot positions
    ref_params=("window", "sm_scale"),
    tune_ref=_decode_tune_ref,
    sweep=dict(block_kv=[128, 256, 512, 1024]),
    example=_decode_example,
    doc="""Single-token decode attention: q (B,H,1,D) against a kv cache
    (B,Hk,S,D). ``kv_len`` (int or traced scalar) masks the unfilled tail of
    the cache — the query sits at position kv_len-1 — so one compiled kernel
    serves every step of an incremental-decode loop. ``slot_pos`` ((S,) i32,
    -1 = empty) gives each cache slot's absolute position for ROTATED
    rolling-window caches (slot = pos % W); omitted, slots are positional.""",
)


def decode_attention(q, k, v, *, window=None, sm_scale=None, block_kv=None,
                     kv_len=None, slot_pos=None, backend="auto",
                     interpret=None):
    """Single-token decode attention (no grad needed at serving time).

    ``block_kv=None`` (the default) defers to the op's current default —
    which serving warmup may have replaced with a persisted tune winner; an
    explicit value always wins. ``slot_pos`` routes rotated rolling-window
    caches through the SAME kernel (see ``flash_decode``)."""
    kw = {} if block_kv is None else {"block_kv": block_kv}
    return flash_decode(q, k, v, window=window, sm_scale=sm_scale,
                        kv_len=kv_len, slot_pos=slot_pos, backend=backend,
                        interpret=interpret, **kw)
