"""Jitted public wrappers for flash attention with a custom VJP.

Forward and backward both run Pallas kernels (interpret-mode on CPU,
compiled on TPU). No O(S^2) residuals are saved — only (q, k, v, o, lse);
the backward kernels recompute p blockwise from the lse stats.
"""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_bwd, flash_attention_fwd, flash_decode
from .ref import decode_ref, mha_ref

__all__ = ["flash_attention", "decode_attention"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, sm_scale, prefix_len, block_q, block_kv):
    o, _ = flash_attention_fwd(q, k, v, causal=causal, window=window,
                               sm_scale=sm_scale, prefix_len=prefix_len,
                               block_q=block_q, block_kv=block_kv)
    return o


def _flash_fwd(q, k, v, causal, window, sm_scale, prefix_len, block_q, block_kv):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 sm_scale=sm_scale, prefix_len=prefix_len,
                                 block_q=block_q, block_kv=block_kv)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, sm_scale, prefix_len, block_q, block_kv, res, g):
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, g, lse, causal=causal,
                               window=window, sm_scale=sm_scale,
                               prefix_len=prefix_len, block_q=block_q,
                               block_kv=block_kv)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=None, sm_scale=None,
                    prefix_len=0, block_q=128, block_kv=128):
    """Differentiable flash attention. q (B,H,Sq,Dqk), k (B,Hk,Skv,Dqk),
    v (B,Hk,Skv,Dv)."""
    return _flash(q, k, v, causal, window, sm_scale, prefix_len, block_q,
                  block_kv)


def decode_attention(q, k, v, *, window=None, sm_scale=None, block_kv=512):
    """Single-token decode attention (no grad needed at serving time)."""
    return flash_decode(q, k, v, window=window, sm_scale=sm_scale,
                        block_kv=block_kv)
