"""Blocked online-softmax attention (FlashAttention) in the unified language.

TPU adaptation (DESIGN.md §2): work-groups -> grid cells holding one
(block_q x head_dim) query tile in VMEM; the kv dimension is the trailing
*reduce* axis so the softmax running state (m, l, acc) lives in VMEM scratch
and persists across sequential grid steps — the TPU realization of the CUDA
flash-attention inner loop. Causal/sliding-window blocks that are fully
masked are skipped whole with ``ctx.cell_when`` (no MXU work issued on
pallas; a ``lax.cond`` skip on the functional expansions).

Every kernel here is one unified-language source expanding to
jnp/loops/pallas — the bespoke hand-tiled Pallas era is over:

* ``flash_fwd_builder``    forward + lse stats (reduce over kv blocks)
* ``flash_delta_builder``  fused rowwise ``sum(do * o)`` precompute
* ``flash_bwd_builder``    ONE fused dq/dk/dv pass: grid (b, h, nq, nk) with
  BOTH block axes sequential and per-output reduce granularity —
  ``dq = Tile(reduce=(3,))`` accumulates over k-blocks in scratch while
  ``dk``/``dv = Tile(reduce=(2,))`` accumulate over q-blocks directly in
  their (revisited) output blocks. The two hand-tiled backward kernels this
  replaces had *transposed* reduce orderings; ``Tile(reduce=...)`` expresses
  both orderings in one grid, recomputing ``p`` once per (qi, ki) tile
  instead of twice.
* ``flash_decode_builder`` single-token decode against a (possibly partially
  filled, possibly ROTATED rolling-window) kv cache; the valid length is a
  dynamic ``kv_len`` input and the slot->absolute-position map a dynamic
  ``slot_pos`` input tile, so one compiled kernel serves every step of an
  incremental-decode loop — including past the wrap of a rolling cache.

Host paths live in the ``define_op`` declarations in ``ops.py``;
``flash_attention_bwd`` below is the backward's host wrapper (kernel builds
via the shared Device cache + the GQA head-group reduction).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax

from repro.core import Scratch, ShardAxis, Spec, Tile, default_device

__all__ = ["flash_fwd_builder", "flash_delta_builder", "flash_bwd_builder",
           "flash_decode_builder", "flash_attention_bwd",
           "ring_flash_fwd_builder", "ring_flash_bwd_builder"]

_NEG_INF = float("-inf")


def flash_fwd_builder(D):
    """q: (b, h, sq, d); k: (b, hk, skv, d); v: (b, hk, skv, dv) ->
    o: (b, h, sq, dv), lse: (b, h, sq) f32 (softmax stats for the backward).

    Grid (b, h, nq, nk) with nk the sequential reduce axis; m/l/acc running
    state in scratch, init under ``is_first``, flushed under ``is_last``;
    fully-masked (q, kv)-blocks are ``cell_when``-skipped."""
    b, h, hk = D.b, D.h, D.hk
    sq, skv, d, dv = D.sq, D.skv, D.d, D.dv
    bq, bkv = D.block_q, D.block_kv
    causal, window, prefix = D.causal, D.window, D.prefix_len
    sm_scale = D.sm_scale
    g = h // hk
    q_offset = skv - sq  # queries aligned to the end of the kv stream
    dtype = jnp.dtype(D.dtype)

    def body(ctx, q_ref, k_ref, v_ref, o_ref, lse_ref):
        m_scr, l_scr, acc_scr = ctx.scratch
        qi = ctx.outer_id(2)
        ki = ctx.reduce_id(0)

        @ctx.when(ctx.is_first)
        def _init():
            m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
            l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
            acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

        run = _run_cond(qi, ki, causal=causal, window=window,
                        prefix_len=prefix, block_q=bq, block_kv=bkv,
                        q_offset=q_offset)

        @ctx.cell_when(run)
        def _step():
            q_pos = qi * bq + lax.iota(jnp.int32, bq) + q_offset
            k_pos = ki * bkv + lax.iota(jnp.int32, bkv)
            q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
            k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
            s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                               prefix_len=prefix)
            s = jnp.where(mask, s, _NEG_INF)

            m_prev = m_scr[:, :1]                         # (bq, 1)
            l_prev = l_scr[:, :1]
            m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
            # correction for fully-masked history (m_prev == -inf): acc is 0
            corr = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
            p = jnp.exp(s - m_cur)
            p = jnp.where(mask, p, 0.0)                   # kills -inf - -inf NaNs
            v = v_ref[0, 0].astype(jnp.float32)
            acc_scr[...] = acc_scr[...] * corr + lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            l_scr[:, :1] = l_prev * corr + p.sum(-1, keepdims=True)
            m_scr[:, :1] = m_cur

        @ctx.when(ctx.is_last)
        def _fin():
            l = l_scr[:, :1]
            o_ref[0, 0] = (acc_scr[...] /
                           jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)
            # log-sum-exp per query row (softmax stats for the backward kernel)
            lse_ref[0, 0] = (m_scr[:, 0] +
                             jnp.log(jnp.where(l[:, 0] == 0.0, 1.0, l[:, 0])))

    return Spec(
        "flash_attention_fwd",
        grid=(b, h, sq // bq, skv // bkv),
        reduce_axes=(3,),
        scratch=[Scratch((bq, 128), jnp.float32),   # m (lane-replicated col 0)
                 Scratch((bq, 128), jnp.float32),   # l
                 Scratch((bq, dv), jnp.float32)],   # acc
        inputs=[
            Tile("q", (b, h, sq, d), dtype, block=(1, 1, bq, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("k", (b, hk, skv, d), dtype, block=(1, 1, bkv, d),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("v", (b, hk, skv, dv), dtype, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        outputs=[
            Tile("o", (b, h, sq, dv), dtype, block=(1, 1, bq, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("lse", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi)),
        ],
        body=body)


# ---------------------------------------------------------------------------
# shared masking / recompute helpers (pure jnp — usable from any expansion)
# ---------------------------------------------------------------------------

def _mask_block(q_pos, k_pos, *, causal, window, prefix_len):
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    if prefix_len:
        mask |= jnp.broadcast_to(k_pos[None, :] < prefix_len, mask.shape)
    return mask


def _p_block(q, k, lse, mask, sm_scale):
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * sm_scale
    p = jnp.exp(s - lse[:, None])
    return jnp.where(mask, p, 0.0)


def _run_cond(qi, ki, *, causal, window, prefix_len, block_q, block_kv,
              q_offset):
    """Whole-block skip: strictly-above-diagonal (causal) or out-of-window."""
    run = jnp.bool_(True)
    if causal:
        run &= (ki * block_kv) <= (qi * block_q + q_offset + block_q - 1)
    if window is not None:
        run &= (qi * block_q + q_offset) - (ki * block_kv + block_kv - 1) < window
    if prefix_len:
        run |= (ki * block_kv) < prefix_len   # prefix keys always visible
    return run


# ---------------------------------------------------------------------------
# backward: delta precompute + ONE fused dq/dk/dv kernel
# ---------------------------------------------------------------------------

def flash_delta_builder(D):
    """do, o: (b, h, sq, dv) -> delta: (b, h, sq) f32, rowwise sum(do * o).

    The multiply and the row reduction fuse in one grid cell — the (b,h,sq,dv)
    product never materializes."""
    b, h, sq, dv = D.b, D.h, D.sq, D.dv
    bq = D.block_q
    dtype = jnp.dtype(D.dtype)

    def body(ctx, do_ref, o_ref, delta_ref):
        do = do_ref[0, 0].astype(jnp.float32)
        o = o_ref[0, 0].astype(jnp.float32)
        delta_ref[0, 0] = (do * o).sum(-1)

    return Spec(
        "flash_delta",
        grid=(b, h, sq // bq),
        inputs=[
            Tile("do", (b, h, sq, dv), dtype, block=(1, 1, bq, dv),
                 index=lambda b_, h_, qi: (b_, h_, qi, 0)),
            Tile("o", (b, h, sq, dv), dtype, block=(1, 1, bq, dv),
                 index=lambda b_, h_, qi: (b_, h_, qi, 0)),
        ],
        outputs=[
            Tile("delta", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi: (b_, h_, qi)),
        ],
        body=body)


def flash_bwd_builder(D):
    """Fused flash backward: q/k/v/do/lse/delta -> dq, dk, dv (per query head).

    Grid (b, h, nq, nk) with BOTH block axes sequential (qi outer, ki inner).
    ``p`` is recomputed once per (qi, ki) tile from the lse stats and feeds all
    three cotangents — the per-output reduce granularity does the rest:

      dq  (``reduce=(3,)``)  row state in scratch across the inner ki sweep,
                             init at ``reduce_first(1)``, flushed at
                             ``reduce_last(1)`` — nq distinct blocks along the
                             OUTER sequential axis
      dk/dv (``reduce=(2,)``) accumulate over the qi sweep directly in their
                             revisited output blocks (init at
                             ``reduce_first(0)``) — nk distinct blocks along
                             the INNER sequential axis

    GQA head-group reduction (dk/dv summed over the query-head group) happens
    on the host in :func:`flash_attention_bwd`."""
    b, h, hk = D.b, D.h, D.hk
    sq, skv, d, dv = D.sq, D.skv, D.d, D.dv
    bq, bkv = D.block_q, D.block_kv
    causal, window, prefix = D.causal, D.window, D.prefix_len
    sm_scale = D.sm_scale
    g = h // hk
    q_offset = skv - sq
    nq, nk = sq // bq, skv // bkv
    dtype = jnp.dtype(D.dtype)

    def body(ctx, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
             dq_ref, dk_ref, dv_ref):
        dq_scr, = ctx.scratch
        qi = ctx.reduce_id(0)
        ki = ctx.reduce_id(1)

        @ctx.when(ctx.reduce_first(1))       # ki == 0: a fresh query row
        def _init_dq():
            dq_scr[...] = jnp.zeros(dq_scr.shape, jnp.float32)

        @ctx.when(ctx.reduce_first(0))       # qi == 0: first visit of dk/dv
        def _init_dkv():                     # blocks (undefined on real TPU)
            dk_ref[0, 0] = jnp.zeros((bkv, d), jnp.float32)
            dv_ref[0, 0] = jnp.zeros((bkv, dv), jnp.float32)

        run = _run_cond(qi, ki, causal=causal, window=window,
                        prefix_len=prefix, block_q=bq, block_kv=bkv,
                        q_offset=q_offset)

        @ctx.cell_when(run)
        def _step():
            q = q_ref[0, 0].astype(jnp.float32)
            k = k_ref[0, 0].astype(jnp.float32)
            v = v_ref[0, 0].astype(jnp.float32)
            do = do_ref[0, 0].astype(jnp.float32)
            lse = lse_ref[0, 0]
            delta = delta_ref[0, 0]
            q_pos = qi * bq + lax.iota(jnp.int32, bq) + q_offset
            k_pos = ki * bkv + lax.iota(jnp.int32, bkv)
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                               prefix_len=prefix)
            p = _p_block(q, k, lse, mask, sm_scale)              # (bq, bkv)
            dv_ref[0, 0] = dv_ref[0, 0] + lax.dot_general(
                p, do, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)              # p^T @ do
            dp = lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
            ds = p * (dp - delta[:, None]) * sm_scale            # (bq, bkv)
            dk_ref[0, 0] = dk_ref[0, 0] + lax.dot_general(
                ds, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)              # ds^T @ q
            dq_scr[...] += lax.dot_general(
                ds, k, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)              # ds @ k

        @ctx.when(ctx.reduce_last(1))        # ki == nk-1: flush the query row
        def _flush_dq():
            dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)

    return Spec(
        "flash_attention_bwd",
        grid=(b, h, nq, nk),
        reduce_axes=(2, 3),
        scratch=[Scratch((bq, d), jnp.float32)],
        inputs=[
            Tile("q", (b, h, sq, d), dtype, block=(1, 1, bq, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("k", (b, hk, skv, d), dtype, block=(1, 1, bkv, d),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("v", (b, hk, skv, dv), dtype, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("do", (b, h, sq, dv), dtype, block=(1, 1, bq, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("lse", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi)),
            Tile("delta", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi)),
        ],
        outputs=[
            Tile("dq", (b, h, sq, d), dtype, block=(1, 1, bq, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0), reduce=(3,)),
            Tile("dk", (b, h, skv, d), jnp.float32, block=(1, 1, bkv, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, ki, 0), reduce=(2,)),
            Tile("dv", (b, h, skv, dv), jnp.float32, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_, ki, 0), reduce=(2,)),
        ],
        body=body)


def flash_attention_bwd(q, k, v, o, do, lse, *, causal=True, window=None,
                        sm_scale=None, prefix_len=0, block_q=128,
                        block_kv=128, backend="pallas", interpret=None):
    """Flash backward host path: delta kernel + fused dq/dk/dv kernel +
    GQA head-group reduction. Returns (dq, dk, dv)."""
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    dv_dim = v.shape[-1]
    g = h // hk
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    dev = default_device(backend, interpret)
    dtype = jnp.dtype(q.dtype).name
    do = do.astype(q.dtype)

    delta_kern = dev.build_kernel(flash_delta_builder, dict(
        b=b, h=h, sq=sq, dv=dv_dim, block_q=block_q, dtype=dtype))
    delta, = delta_kern.run(do, o.astype(q.dtype))

    bwd_kern = dev.build_kernel(flash_bwd_builder, dict(
        b=b, h=h, hk=hk, sq=sq, skv=skv, d=d, dv=dv_dim,
        block_q=block_q, block_kv=block_kv, causal=bool(causal),
        window=None if window is None else int(window),
        prefix_len=int(prefix_len), sm_scale=float(sm_scale), dtype=dtype))
    dq, dk_h, dv_h = bwd_kern.run(q, k, v, do, lse, delta)

    # GQA: reduce dk/dv over the query-head group
    dk = dk_h.reshape(b, hk, g, skv, d).sum(2).astype(k.dtype)
    dv = dv_h.reshape(b, hk, g, skv, dv_dim).sum(2).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------

def flash_decode_builder(D):
    """q: (b, h, 1, d) vs cache k: (b, hk, skv, d), v: (b, hk, skv, dv),
    kv_len: (1, 1) i32, slot_pos: (1, skv) i32 -> o: (b, h, 1, dv).

    Same online-softmax reduce over kv blocks as the forward, with TWO
    dynamic inputs serving one compiled kernel for every step of a decode
    loop: ``kv_len`` (a whole-array scalar tile) is the number of tokens
    decoded so far — the query sits at absolute position ``kv_len - 1`` —
    and ``slot_pos`` (blocked along the kv axis like k/v) carries each cache
    slot's ABSOLUTE position, ``-1`` for never-written slots. The mask reads
    ``slot_pos`` instead of assuming positional order, so a rolling-window
    cache storing ROTATED slots (slot = pos % W) runs the same kernel: slot
    ``i`` is attended iff ``(slot_pos >= 0) & (slot_pos <= q_pos) &
    (q_pos - slot_pos < window)``. Positional caches pass the identity map
    (the op front-end's default), which recovers the old iota mask exactly.

    The ``kv_len``-driven ``cell_when`` whole-block skip survives for the
    un-wrapped prefix: while ``kv_len <= skv`` a rolling cache has not yet
    rotated (slot == position), so blocks past the query — or fully below
    the window — are skipped without issuing MXU work; once wrapped
    (``kv_len > skv``) every slot may be live and all blocks run."""
    b, h, hk = D.b, D.h, D.hk
    skv, d, dv = D.skv, D.d, D.dv
    bkv = D.block_kv
    window = D.window
    sm_scale = D.sm_scale
    g = h // hk
    dtype = jnp.dtype(D.dtype)

    def body(ctx, q_ref, k_ref, v_ref, len_ref, sp_ref, o_ref):
        m_scr, l_scr, acc_scr = ctx.scratch
        ki = ctx.reduce_id(0)

        @ctx.when(ctx.is_first)
        def _init():
            m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
            l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
            acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

        q_pos = len_ref[0, 0] - 1            # query at the end of the stream
        run = (ki * bkv) <= q_pos
        if window is not None:
            run &= (q_pos - (ki * bkv + bkv - 1)) < window
        # wrapped rotated cache: slots lose positional order, every block may
        # hold live (recent) tokens — the positional skip no longer applies
        run |= q_pos >= skv

        @ctx.cell_when(run)
        def _step():
            sp = sp_ref[0]                   # (bkv,) absolute slot positions
            q = q_ref[0, 0].astype(jnp.float32)          # (1, d)
            k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
            s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
            mask = ((sp >= 0) & (sp <= q_pos))[None, :]  # (1, bkv)
            if window is not None:
                mask &= ((q_pos - sp) < window)[None, :]
            s = jnp.where(mask, s, _NEG_INF)
            m_prev = m_scr[:, :1]
            l_prev = l_scr[:, :1]
            m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
            corr = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
            p = jnp.exp(s - m_cur)
            p = jnp.where(mask, p, 0.0)
            v = v_ref[0, 0].astype(jnp.float32)
            acc_scr[...] = acc_scr[...] * corr + lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            l_scr[:, :1] = l_prev * corr + p.sum(-1, keepdims=True)
            m_scr[:, :1] = m_cur

        @ctx.when(ctx.is_last)
        def _fin():
            l = l_scr[:, :1]
            o_ref[0, 0] = (acc_scr[...] /
                           jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)

    return Spec(
        "flash_decode",
        grid=(b, h, skv // bkv),
        reduce_axes=(2,),
        scratch=[Scratch((1, 128), jnp.float32),   # m
                 Scratch((1, 128), jnp.float32),   # l
                 Scratch((1, dv), jnp.float32)],   # acc
        inputs=[
            Tile("q", (b, h, 1, d), dtype, block=(1, 1, 1, d),
                 index=lambda b_, h_, ki: (b_, h_, 0, 0)),
            Tile("k", (b, hk, skv, d), dtype, block=(1, 1, bkv, d),
                 index=lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
            Tile("v", (b, hk, skv, dv), dtype, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
            Tile("kv_len", (1, 1), jnp.int32),     # whole-array (dynamic len)
            Tile("slot_pos", (1, skv), jnp.int32,  # slot -> absolute position
                 block=(1, bkv), index=lambda b_, h_, ki: (0, ki)),
        ],
        outputs=[
            Tile("o", (b, h, 1, dv), dtype, block=(1, 1, 1, dv),
                 index=lambda b_, h_, ki: (b_, h_, 0, 0)),
        ],
        body=body)

def paged_decode_builder(D):
    """q: (b, h, 1, d) vs a PAGED cache pool k: (P, hk, page, d),
    v: (P, hk, page, dv), block_table: (b, NP) i32, kv_len: (b, 1) i32,
    pos_pages: (P, page) i32 -> o: (b, h, 1, dv).

    The continuous-batching decode kernel (vLLM's PagedAttention idiom
    through the unified language): each sequence owns a per-slot list of
    fixed-size pages scattered through a shared pool, and the KV index maps
    READ the block table at run time — ``Tile(index_tile=("block_table",
    0))`` — to gather logical page ``j`` of sequence ``b`` from pool page
    ``block_table[b, j]``. ``pos_pages`` rides the pool through the same
    table: row ``p`` carries pool page ``p``'s absolute slot positions
    (``-1`` for never-written slots, exactly ``flash_decode``'s ``slot_pos``
    contract), so rolling-window rotated caches and partially-filled tail
    pages mask identically to the contiguous kernel. ``kv_len`` is
    per-sequence — mixed prompt/generation lengths share one compiled grid.

    Bit parity with :func:`flash_decode_builder`: with ``page == block_kv``
    and pages in logical order the online-softmax visits identical blocks in
    identical order, and fully-masked blocks are exact no-ops — so a paged
    decode is bitwise the contiguous decode, pages scattered or not.

    The ``cell_when`` whole-block skip is the contiguous kernel's, applied
    per sequence: while un-wrapped (``kv_len <= capacity``) logical page
    ``j`` holds positions ``[j*page, (j+1)*page)``; never-allocated tail
    pages point at the engine's null page, whose positions are all ``-1``."""
    b, h, hk = D.b, D.h, D.hk
    d, dv = D.d, D.dv
    npages, page, nsp = D.npages, D.page, D.nseq_pages
    window = D.window
    sm_scale = D.sm_scale
    g = h // hk
    cap = nsp * page                       # per-sequence slot capacity
    dtype = jnp.dtype(D.dtype)

    def body(ctx, q_ref, k_ref, v_ref, tab_ref, len_ref, sp_ref, o_ref):
        m_scr, l_scr, acc_scr = ctx.scratch
        j = ctx.reduce_id(0)

        @ctx.when(ctx.is_first)
        def _init():
            m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
            l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
            acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

        q_pos = len_ref[0, 0] - 1            # this sequence's query position
        run = (j * page) <= q_pos
        if window is not None:
            run &= (q_pos - (j * page + page - 1)) < window
        # wrapped rotated cache: slots lose positional order, every page may
        # hold live (recent) tokens — the positional skip no longer applies
        run |= q_pos >= cap

        @ctx.cell_when(run)
        def _step():
            sp = sp_ref[0]                   # (page,) absolute slot positions
            q = q_ref[0, 0].astype(jnp.float32)          # (1, d)
            k = k_ref[0, 0].astype(jnp.float32)          # (page, d)
            s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
            mask = ((sp >= 0) & (sp <= q_pos))[None, :]  # (1, page)
            if window is not None:
                mask &= ((q_pos - sp) < window)[None, :]
            s = jnp.where(mask, s, _NEG_INF)
            m_prev = m_scr[:, :1]
            l_prev = l_scr[:, :1]
            m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
            corr = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
            p = jnp.exp(s - m_cur)
            p = jnp.where(mask, p, 0.0)
            v = v_ref[0, 0].astype(jnp.float32)
            acc_scr[...] = acc_scr[...] * corr + lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            l_scr[:, :1] = l_prev * corr + p.sum(-1, keepdims=True)
            m_scr[:, :1] = m_cur

        @ctx.when(ctx.is_last)
        def _fin():
            l = l_scr[:, :1]
            o_ref[0, 0] = (acc_scr[...] /
                           jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)

    return Spec(
        "flash_decode_paged",
        grid=(b, h, nsp),
        reduce_axes=(2,),
        scratch=[Scratch((1, 128), jnp.float32),   # m
                 Scratch((1, 128), jnp.float32),   # l
                 Scratch((1, dv), jnp.float32)],   # acc
        inputs=[
            Tile("q", (b, h, 1, d), dtype, block=(1, 1, 1, d),
                 index=lambda b_, h_, j: (b_, h_, 0, 0)),
            # pool page axis: dynamic, read from the block table per cell
            # (the static map's 0 there is the ignored placeholder)
            Tile("k", (npages, hk, page, d), dtype, block=(1, 1, page, d),
                 index=lambda b_, h_, j: (0, h_ // g, 0, 0),
                 index_tile=("block_table", 0)),
            Tile("v", (npages, hk, page, dv), dtype, block=(1, 1, page, dv),
                 index=lambda b_, h_, j: (0, h_ // g, 0, 0),
                 index_tile=("block_table", 0)),
            Tile("block_table", (b, nsp), jnp.int32, block=(1, 1),
                 index=lambda b_, h_, j: (b_, j)),
            Tile("kv_len", (b, 1), jnp.int32, block=(1, 1),
                 index=lambda b_, h_, j: (b_, 0)),
            Tile("pos_pages", (npages, page), jnp.int32, block=(1, page),
                 index=lambda b_, h_, j: (0, 0),
                 index_tile=("block_table", 0)),
        ],
        outputs=[
            Tile("o", (b, h, 1, dv), dtype, block=(1, 1, 1, dv),
                 index=lambda b_, h_, j: (b_, h_, 0, 0)),
        ],
        body=body)


# ---------------------------------------------------------------------------
# ring attention: one ring step, offsets as dynamic inputs
# ---------------------------------------------------------------------------

def ring_flash_fwd_builder(D):
    """One RING STEP of sequence-parallel flash attention.

    Identical online-softmax math to :func:`flash_fwd_builder`, with the
    static end-of-stream alignment (``q_offset = skv - sq``) replaced by TWO
    dynamic (1, 1) i32 inputs: ``q_start`` (absolute position of this shard's
    first query row) and ``k_start`` (absolute position of the kv chunk
    currently resident — it changes every ring step as chunks rotate). One
    compiled kernel therefore serves every (shard, step) pair; the causal /
    window block-skip becomes a data-dependent ``cell_when`` predicate, like
    flash-decode's ``kv_len`` skip.

    Outputs are the chunk-local softmax (``o`` normalized by the chunk's own
    ``l``, plus the chunk ``lse``); the host merges steps exactly via the
    standard logsumexp reweighting. A fully-masked query row yields
    ``o = 0, lse = -inf`` — the merge's identity element.

    The spec declares its mesh binding: grid axis 3 (the kv-chunk reduce
    axis) lives across ``ring_steps`` shards of mesh axis ``mesh_axis``, with
    k/v rotating on a declared ``ppermute`` ring.
    """
    b, h, hk = D.b, D.h, D.hk
    sq, skv, d, dv = D.sq, D.skv, D.d, D.dv
    bq, bkv = D.block_q, D.block_kv
    causal, window, prefix = D.causal, D.window, D.prefix_len
    sm_scale = D.sm_scale
    g = h // hk
    dtype = jnp.dtype(D.dtype)

    def body(ctx, q_ref, k_ref, v_ref, qs_ref, ks_ref, o_ref, lse_ref):
        m_scr, l_scr, acc_scr = ctx.scratch
        qi = ctx.outer_id(2)
        ki = ctx.reduce_id(0)

        @ctx.when(ctx.is_first)
        def _init():
            m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
            l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
            acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

        q0 = qs_ref[0, 0]
        k0 = ks_ref[0, 0]
        # the block-skip of _run_cond, with dynamic absolute offsets
        run = jnp.bool_(True)
        if causal:
            run &= (k0 + ki * bkv) <= (q0 + qi * bq + bq - 1)
        if window is not None:
            run &= ((q0 + qi * bq) - (k0 + ki * bkv + bkv - 1)) < window
        if prefix:
            run |= (k0 + ki * bkv) < prefix    # prefix keys always visible

        @ctx.cell_when(run)
        def _step():
            q_pos = q0 + qi * bq + lax.iota(jnp.int32, bq)
            k_pos = k0 + ki * bkv + lax.iota(jnp.int32, bkv)
            q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
            k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
            s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                               prefix_len=prefix)
            s = jnp.where(mask, s, _NEG_INF)
            m_prev = m_scr[:, :1]
            l_prev = l_scr[:, :1]
            m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
            corr = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
            p = jnp.exp(jnp.where(mask, s - m_cur, 0.0))
            p = jnp.where(mask, p, 0.0)
            v = v_ref[0, 0].astype(jnp.float32)
            acc_scr[...] = acc_scr[...] * corr + lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            l_scr[:, :1] = l_prev * corr + p.sum(-1, keepdims=True)
            m_scr[:, :1] = m_cur

        @ctx.when(ctx.is_last)
        def _fin():
            l = l_scr[:, :1]
            o_ref[0, 0] = (acc_scr[...] /
                           jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)
            # lse = -inf for fully-masked rows (m stays -inf, l stays 0):
            # exactly the merge identity the host combiner expects
            lse_ref[0, 0] = (m_scr[:, 0] +
                             jnp.log(jnp.where(l[:, 0] == 0.0, 1.0, l[:, 0])))

    return Spec(
        "ring_flash_fwd",
        grid=(b, h, sq // bq, skv // bkv),
        reduce_axes=(3,),
        scratch=[Scratch((bq, 128), jnp.float32),   # m
                 Scratch((bq, 128), jnp.float32),   # l
                 Scratch((bq, dv), jnp.float32)],   # acc
        inputs=[
            Tile("q", (b, h, sq, d), dtype, block=(1, 1, bq, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("k", (b, hk, skv, d), dtype, block=(1, 1, bkv, d),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("v", (b, hk, skv, dv), dtype, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("q_start", (1, 1), jnp.int32),     # whole-array (dynamic)
            Tile("k_start", (1, 1), jnp.int32),     # whole-array (dynamic)
        ],
        outputs=[
            Tile("o", (b, h, sq, dv), dtype, block=(1, 1, bq, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("lse", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi)),
        ],
        body=body,
        shard=ShardAxis(mesh_axis=D.mesh_axis, axis=3, extent=D.ring_steps,
                        collective="ppermute", rotate=("k", "v")))


def ring_flash_bwd_builder(D):
    """The backward of ONE ring step (see :func:`flash_bwd_builder`).

    Same fused dq/dk/dv pass with the dynamic ``q_start``/``k_start``
    offsets, run once per ring step by the host VJP with the step's own lse
    and an lse-cotangent-adjusted delta (``delta' = delta - g_lse``, since
    ``ds = p * (dp - delta + g_lse)`` when lse is a public output).

    The mesh binding mirrors the forward's ring and additionally declares
    ``dk``/``dv`` as shard-resident (grid axis 3 is their SLOT axis — each
    ring step writes the chunk owned by ANOTHER shard; under autodiff their
    cotangents ride the transposed ppermute ring home). Without that
    declaration the analyzer flags RACE_MESH_WRITE.
    """
    b, h, hk = D.b, D.h, D.hk
    sq, skv, d, dv = D.sq, D.skv, D.d, D.dv
    bq, bkv = D.block_q, D.block_kv
    causal, window, prefix = D.causal, D.window, D.prefix_len
    sm_scale = D.sm_scale
    g = h // hk
    nq, nk = sq // bq, skv // bkv
    dtype = jnp.dtype(D.dtype)

    def body(ctx, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
             qs_ref, ks_ref, dq_ref, dk_ref, dv_ref):
        dq_scr, = ctx.scratch
        qi = ctx.reduce_id(0)
        ki = ctx.reduce_id(1)

        @ctx.when(ctx.reduce_first(1))
        def _init_dq():
            dq_scr[...] = jnp.zeros(dq_scr.shape, jnp.float32)

        @ctx.when(ctx.reduce_first(0))
        def _init_dkv():
            dk_ref[0, 0] = jnp.zeros((bkv, d), jnp.float32)
            dv_ref[0, 0] = jnp.zeros((bkv, dv), jnp.float32)

        q0 = qs_ref[0, 0]
        k0 = ks_ref[0, 0]
        run = jnp.bool_(True)
        if causal:
            run &= (k0 + ki * bkv) <= (q0 + qi * bq + bq - 1)
        if window is not None:
            run &= ((q0 + qi * bq) - (k0 + ki * bkv + bkv - 1)) < window
        if prefix:
            run |= (k0 + ki * bkv) < prefix

        @ctx.cell_when(run)
        def _step():
            q = q_ref[0, 0].astype(jnp.float32)
            k = k_ref[0, 0].astype(jnp.float32)
            v = v_ref[0, 0].astype(jnp.float32)
            do = do_ref[0, 0].astype(jnp.float32)
            lse = lse_ref[0, 0]
            delta = delta_ref[0, 0]
            q_pos = q0 + qi * bq + lax.iota(jnp.int32, bq)
            k_pos = k0 + ki * bkv + lax.iota(jnp.int32, bkv)
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                               prefix_len=prefix)
            # fully-masked rows carry lse = -inf; keep the exp argument
            # finite so p is an exact 0, not a masked NaN
            s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
            p = jnp.exp(jnp.where(mask, s - lse[:, None], 0.0))
            p = jnp.where(mask, p, 0.0)
            dv_ref[0, 0] = dv_ref[0, 0] + lax.dot_general(
                p, do, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dp = lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
            ds = p * (dp - delta[:, None]) * sm_scale
            dk_ref[0, 0] = dk_ref[0, 0] + lax.dot_general(
                ds, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dq_scr[...] += lax.dot_general(
                ds, k, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @ctx.when(ctx.reduce_last(1))
        def _flush_dq():
            dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)

    return Spec(
        "ring_flash_bwd",
        grid=(b, h, nq, nk),
        reduce_axes=(2, 3),
        scratch=[Scratch((bq, d), jnp.float32)],
        inputs=[
            Tile("q", (b, h, sq, d), dtype, block=(1, 1, bq, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("k", (b, hk, skv, d), dtype, block=(1, 1, bkv, d),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("v", (b, hk, skv, dv), dtype, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("do", (b, h, sq, dv), dtype, block=(1, 1, bq, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("lse", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi)),
            Tile("delta", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi)),
            Tile("q_start", (1, 1), jnp.int32),
            Tile("k_start", (1, 1), jnp.int32),
        ],
        outputs=[
            Tile("dq", (b, h, sq, d), dtype, block=(1, 1, bq, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0), reduce=(3,)),
            Tile("dk", (b, h, skv, d), jnp.float32, block=(1, 1, bkv, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, ki, 0), reduce=(2,)),
            Tile("dv", (b, h, skv, dv), jnp.float32, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_, ki, 0), reduce=(2,)),
        ],
        body=body,
        shard=ShardAxis(mesh_axis=D.mesh_axis, axis=3, extent=D.ring_steps,
                        collective="ppermute", rotate=("k", "v"),
                        sharded_outputs=("dk", "dv")))
