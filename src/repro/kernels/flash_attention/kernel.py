"""Blocked online-softmax attention (FlashAttention) in the unified language.

TPU adaptation (DESIGN.md §2): work-groups -> grid cells holding one
(block_q x head_dim) query tile in VMEM; the kv dimension is the trailing
*reduce* axis so the softmax running state (m, l, acc) lives in VMEM scratch
and persists across sequential grid steps — the TPU realization of the CUDA
flash-attention inner loop. Causal/sliding-window blocks that are fully
masked are skipped whole with ``ctx.cell_when`` (no MXU work issued on
pallas; a ``lax.cond`` skip on the functional expansions).

The FORWARD is one kernel source (``flash_fwd_builder``) expanding to
jnp/loops/pallas — its former bespoke ``pl.pallas_call`` is gone; the host
path lives in the ``define_op`` declaration in ``ops.py``. The backward and
single-token decode remain hand-tiled Pallas kernels (ROADMAP: port bwd next).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import Scratch, Spec, Tile

__all__ = ["flash_fwd_builder", "flash_attention_bwd", "flash_decode"]

_NEG_INF = float("-inf")


def flash_fwd_builder(D):
    """q: (b, h, sq, d); k: (b, hk, skv, d); v: (b, hk, skv, dv) ->
    o: (b, h, sq, dv), lse: (b, h, sq) f32 (softmax stats for the backward).

    Grid (b, h, nq, nk) with nk the sequential reduce axis; m/l/acc running
    state in scratch, init under ``is_first``, flushed under ``is_last``;
    fully-masked (q, kv)-blocks are ``cell_when``-skipped."""
    b, h, hk = D.b, D.h, D.hk
    sq, skv, d, dv = D.sq, D.skv, D.d, D.dv
    bq, bkv = D.block_q, D.block_kv
    causal, window, prefix = D.causal, D.window, D.prefix_len
    sm_scale = D.sm_scale
    g = h // hk
    q_offset = skv - sq  # queries aligned to the end of the kv stream
    dtype = jnp.dtype(D.dtype)

    def body(ctx, q_ref, k_ref, v_ref, o_ref, lse_ref):
        m_scr, l_scr, acc_scr = ctx.scratch
        qi = ctx.outer_id(2)
        ki = ctx.reduce_id(0)

        @ctx.when(ctx.is_first)
        def _init():
            m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
            l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
            acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

        # whole-block skip: strictly-above-diagonal (causal) or out-of-window
        run = jnp.bool_(True)
        if causal:
            run &= (ki * bkv) <= (qi * bq + q_offset + bq - 1)
        if window is not None:
            run &= (qi * bq + q_offset) - (ki * bkv + bkv - 1) < window
        if prefix:
            run |= (ki * bkv) < prefix   # prefix keys always visible

        @ctx.cell_when(run)
        def _step():
            q_pos = qi * bq + lax.iota(jnp.int32, bq) + q_offset
            k_pos = ki * bkv + lax.iota(jnp.int32, bkv)
            q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
            k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
            s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
            mask = jnp.ones((bq, bkv), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            if prefix:
                mask |= jnp.broadcast_to(k_pos[None, :] < prefix, mask.shape)
            s = jnp.where(mask, s, _NEG_INF)

            m_prev = m_scr[:, :1]                         # (bq, 1)
            l_prev = l_scr[:, :1]
            m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
            # correction for fully-masked history (m_prev == -inf): acc is 0
            corr = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
            p = jnp.exp(s - m_cur)
            p = jnp.where(mask, p, 0.0)                   # kills -inf - -inf NaNs
            v = v_ref[0, 0].astype(jnp.float32)
            acc_scr[...] = acc_scr[...] * corr + lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            l_scr[:, :1] = l_prev * corr + p.sum(-1, keepdims=True)
            m_scr[:, :1] = m_cur

        @ctx.when(ctx.is_last)
        def _fin():
            l = l_scr[:, :1]
            o_ref[0, 0] = (acc_scr[...] /
                           jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)
            # log-sum-exp per query row (softmax stats for the backward kernel)
            lse_ref[0, 0] = (m_scr[:, 0] +
                             jnp.log(jnp.where(l[:, 0] == 0.0, 1.0, l[:, 0])))

    return Spec(
        "flash_attention_fwd",
        grid=(b, h, sq // bq, skv // bkv),
        reduce_axes=(3,),
        scratch=[Scratch((bq, 128), jnp.float32),   # m (lane-replicated col 0)
                 Scratch((bq, 128), jnp.float32),   # l
                 Scratch((bq, dv), jnp.float32)],   # acc
        inputs=[
            Tile("q", (b, h, sq, d), dtype, block=(1, 1, bq, d),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("k", (b, hk, skv, d), dtype, block=(1, 1, bkv, d),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            Tile("v", (b, hk, skv, dv), dtype, block=(1, 1, bkv, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        outputs=[
            Tile("o", (b, h, sq, dv), dtype, block=(1, 1, bq, dv),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            Tile("lse", (b, h, sq), jnp.float32, block=(1, 1, bq),
                 index=lambda b_, h_, qi, ki: (b_, h_, qi)),
        ],
        body=body)


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   sm_scale, window, block_kv, kv_len, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
    q_pos = kv_len - 1

    run = jnp.bool_(True)
    if window is not None:
        run &= (q_pos - (ki * block_kv + block_kv - 1)) < window

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, d) -> use as (d,)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_kv, d)
        s = (k @ q[0]) * sm_scale                      # (block_kv,)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[0, 0]
        m_cur = jnp.maximum(m_prev, s.max())
        corr = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
        p = jnp.exp(s - m_cur)
        p = jnp.where(mask, p, 0.0)
        v = v_ref[0, 0].astype(jnp.float32)            # (block_kv, d)
        acc_scr[...] = acc_scr[...] * corr + (p[None, :] @ v)
        l_scr[0, 0] = l_scr[0, 0] * corr + p.sum()
        m_scr[0, 0] = m_cur

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_scr[0, 0]
        o_ref[0, 0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_decode(q, k, v, *, window=None, sm_scale=None, block_kv=512,
                 interpret=True):
    """Single-token decode: q (B, H, 1, D) vs cache k/v (B, Hk, S, D)."""
    b, h, one, d = q.shape
    assert one == 1
    _, hk, skv, _ = k.shape
    g = h // hk
    block_kv = min(block_kv, skv)
    assert skv % block_kv == 0
    nk = skv // block_kv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale, window=window,
                               block_kv=block_kv, kv_len=skv, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward kernels (flash bwd: dq / dk / dv with recomputed p from lse)
# ---------------------------------------------------------------------------

def _mask_block(q_pos, k_pos, *, causal, window, prefix_len):
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    if prefix_len:
        mask |= jnp.broadcast_to(k_pos[None, :] < prefix_len, mask.shape)
    return mask


def _p_block(q, k, lse, mask, sm_scale):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    p = jnp.exp(s - lse[:, None])
    return jnp.where(mask, p, 0.0)


def _run_cond(qi, ki, *, causal, window, prefix_len, block_q, block_kv,
              q_offset):
    run = jnp.bool_(True)
    if causal:
        run &= (ki * block_kv) <= (qi * block_q + q_offset + block_q - 1)
    if window is not None:
        run &= (qi * block_q + q_offset) - (ki * block_kv + block_kv - 1) < window
    if prefix_len:
        run |= (ki * block_kv) < prefix_len
    return run


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *, sm_scale, causal,
                    window, prefix_len, block_q, block_kv, q_offset, nq):
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = _run_cond(qi, ki, causal=causal, window=window,
                    prefix_len=prefix_len, block_q=block_q,
                    block_kv=block_kv, q_offset=q_offset)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset
        k_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
        mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                           prefix_len=prefix_len)
        p = _p_block(q, k, lse, mask, sm_scale)              # (bq, bkv)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # p^T @ do
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale            # (bq, bkv)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # ds^T @ q

    @pl.when(qi == nq - 1)
    def _fin():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_scr, *, sm_scale, causal, window, prefix_len,
                   block_q, block_kv, q_offset, nk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    run = _run_cond(qi, ki, causal=causal, window=window,
                    prefix_len=prefix_len, block_q=block_q,
                    block_kv=block_kv, q_offset=q_offset)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset
        k_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
        mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                           prefix_len=prefix_len)
        p = _p_block(q, k, lse, mask, sm_scale)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # ds @ k

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def flash_attention_bwd(q, k, v, o, do, lse, *, causal=True, window=None,
                        sm_scale=None, prefix_len=0, block_q=128,
                        block_kv=128, interpret=True):
    """Flash backward. Returns (dq, dk, dv) with GQA group reduction."""
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    dv_dim = v.shape[-1]
    g = h // hk
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    nq, nk = sq // block_q, skv // block_kv
    q_offset = skv - sq
    kw = dict(sm_scale=sm_scale, causal=causal, window=window,
              prefix_len=prefix_len, block_q=block_q, block_kv=block_kv,
              q_offset=q_offset)

    # delta_i = sum_d do_i * o_i (rowwise) — tiny elementwise precompute
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)

    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, ki, qi: (b_, h_, qi, 0))
    do_spec = pl.BlockSpec((1, 1, block_q, dv_dim), lambda b_, h_, ki, qi: (b_, h_, qi, 0))
    stat_spec = pl.BlockSpec((1, 1, block_q), lambda b_, h_, ki, qi: (b_, h_, qi))
    k_spec = pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki, qi: (b_, h_ // g, ki, 0))
    v_spec = pl.BlockSpec((1, 1, block_kv, dv_dim), lambda b_, h_, ki, qi: (b_, h_ // g, ki, 0))

    dk_h, dv_h = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, nq=nq, **kw),
        grid=(b, h, nk, nq),
        in_specs=[q_spec, k_spec, v_spec, do_spec, stat_spec, stat_spec],
        out_specs=[
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki, qi: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, dv_dim), lambda b_, h_, ki, qi: (b_, h_, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, skv, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, skv, dv_dim), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_kv, d), jnp.float32),
            pltpu.VMEM((block_kv, dv_dim), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    q_spec2 = pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0))
    do_spec2 = pl.BlockSpec((1, 1, block_q, dv_dim), lambda b_, h_, qi, ki: (b_, h_, qi, 0))
    stat_spec2 = pl.BlockSpec((1, 1, block_q), lambda b_, h_, qi, ki: (b_, h_, qi))
    k_spec2 = pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0))
    v_spec2 = pl.BlockSpec((1, 1, block_kv, dv_dim), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, nk=nk, **kw),
        grid=(b, h, nq, nk),
        in_specs=[q_spec2, k_spec2, v_spec2, do_spec2, stat_spec2, stat_spec2],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # GQA: reduce dk/dv over the query-head group
    dk = dk_h.reshape(b, hk, g, skv, d).sum(2).astype(k.dtype)
    dv = dv_h.reshape(b, hk, g, skv, dv_dim).sum(2).astype(v.dtype)
    return dq, dk, dv
