"""Sequence-parallel RING flash attention — the kernel language on a mesh.

One ``define_op`` declaration (``ring_flash``) is three things at once:

* a single-device kernel — one ring STEP: flash attention of a query shard
  against one kv chunk at dynamic absolute offsets (``q_start``/``k_start``
  input tiles), emitting the chunk-local ``(o, lse)``;
* a declared schedule — the spec binds its kv reduce axis to a mesh axis
  (``lang.ShardAxis``: ``ppermute`` ring, k/v rotating), which the analyzer
  validates over the mesh-extended grid and the cost model prices in
  interconnect bytes;
* a distributed op — calling it with ``mesh=`` wraps the step in
  ``shard_map`` (``core.op.OpShard``): a static Python ring loop runs the
  step per chunk, merges partials with the exact logsumexp reweighting, and
  ``lax.ppermute``-rotates k/v between steps.

The backward needs no ring-specific plumbing: each step is a
``jax.custom_vjp`` (``_ring_step``) whose backward feeds the step's own lse
and the lse-cotangent-adjusted delta into ``ring_flash_bwd_builder``; jax
then transposes the ring loop itself — every ``ppermute`` becomes its
inverse, carrying the dk/dv cotangents back around the ring to their owner.

``ring_flash_attention`` is the public wrapper: with ``mesh=`` it runs the
distributed ring; without, it runs the SAME per-step kernel + merge over
locally-split chunks (``ring_steps=``) — a bit-comparable single-process
reference, which is also how CPU CI proves the schedule correct.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import OpShard, default_device, define_op, fit_block
from .kernel import (_mask_block, flash_delta_builder, ring_flash_bwd_builder,
                     ring_flash_fwd_builder)

__all__ = ["ring_flash", "ring_flash_attention", "ring_merge",
           "ring_step_ref"]

_NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# defines / hooks for the registered per-step op
# ---------------------------------------------------------------------------

def _ring_pre(args, params):
    # read-only on params (.get, never .pop) — same contract as flash_decode
    q, k, v = args
    q_start = params.get("q_start")
    if q_start is None:
        q_start = 0
    q_start = jnp.asarray(q_start, jnp.int32).reshape(1, 1)
    k_start = params.get("k_start")
    if k_start is None:
        k_start = 0
    k_start = jnp.asarray(k_start, jnp.int32).reshape(1, 1)
    return q, k, v, q_start, k_start


def _ring_defines(args, params):
    q, k, v = args[:3]
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    dv = v.shape[-1]
    if h % hk:
        raise ValueError(f"ring_flash: {h} query heads not a multiple of "
                         f"{hk} kv heads")
    if q.dtype != k.dtype or q.dtype != v.dtype:
        raise ValueError(f"ring_flash: dtypes disagree "
                         f"({q.dtype}/{k.dtype}/{v.dtype})")
    block_q, block_kv = params["block_q"], params["block_kv"]
    bq, bkv = fit_block(block_q, sq), fit_block(block_kv, skv)
    ncells = b * h * (sq // bq) * (skv // bkv)
    degraded = bq < min(block_q, sq) or bkv < min(block_kv, skv)
    if degraded and ncells > 1 << 16:
        raise ValueError(
            f"ring_flash: shard seq lens ({sq}, {skv}) degraded blocks to "
            f"({bq}, {bkv}) = {ncells} grid cells; pad the shards or pass "
            "block sizes that divide them")
    sm_scale = params["sm_scale"]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    window = params["window"]
    return dict(
        b=b, h=h, hk=hk, sq=sq, skv=skv, d=d, dv=dv,
        block_q=bq, block_kv=bkv,
        causal=bool(params["causal"]),
        window=None if window is None else int(window),
        prefix_len=int(params["prefix_len"]),
        sm_scale=float(sm_scale),
        ring_steps=int(params["ring_steps"]),
        mesh_axis=str(params["mesh_axis"]),
        dtype=jnp.dtype(q.dtype).name)


def ring_step_ref(q, k, v, *, q_start=None, k_start=None, causal=True,
                  window=None, sm_scale=None, prefix_len=0):
    """Dense oracle for ONE ring step: masked softmax attention of q (absolute
    positions ``q_start + i``) against one kv chunk (positions
    ``k_start + j``). Fully-masked rows return 0 (the merge identity)."""
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    g = h // hk
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    q0 = 0 if q_start is None else jnp.asarray(q_start, jnp.int32).reshape(())
    k0 = 0 if k_start is None else jnp.asarray(k_start, jnp.int32).reshape(())
    kf = jnp.repeat(k, g, axis=1) if g > 1 else k
    vf = jnp.repeat(v, g, axis=1) if g > 1 else v
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * sm_scale
    q_pos = q0 + jnp.arange(sq, dtype=jnp.int32)
    k_pos = k0 + jnp.arange(skv, dtype=jnp.int32)
    mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                       prefix_len=prefix_len)[None, None]
    s = jnp.where(mask, s, _NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - jnp.where(m == _NEG_INF, 0.0, m)), 0.0)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkv->bhqv", p, vf.astype(jnp.float32))
    return (o / jnp.where(l == 0.0, 1.0, l)).astype(q.dtype)


def _ring_tune_ref(args, params):
    q, k, v, qs, ks = args
    kw = {n: params[n] for n in ("causal", "window", "sm_scale", "prefix_len")}
    return ring_step_ref(q, k, v, q_start=qs, k_start=ks, **kw)


def _ring_example(rng):
    q = rng.randn(1, 4, 64, 32).astype("float32")
    k = rng.randn(1, 2, 64, 32).astype("float32")
    v = rng.randn(1, 2, 64, 32).astype("float32")
    # ring_steps=4: the linted/benchmarked default config is MESH-BOUND (the
    # spec carries an active ShardAxis), so the analyzer's cross-shard checks
    # and the cost model's comm column run in CI, not just under a mesh
    return (q, k, v), dict(causal=True, block_q=32, block_kv=32, ring_steps=4)


# ---------------------------------------------------------------------------
# the exact step merge + the differentiable per-step call
# ---------------------------------------------------------------------------

def ring_merge(a, b):
    """Exactly merge two chunk-local softmax partials ``(o, lse)``.

    Standard flash/logsumexp reweighting, guarded (double-``where``) so
    fully-masked partials (``lse = -inf``) contribute an exact 0 with clean
    gradients — no ``-inf - -inf`` NaNs forward or backward."""
    o_a, lse_a = a
    o_b, lse_b = b
    m = jnp.maximum(lse_a, lse_b)
    m_s = jnp.where(m == _NEG_INF, 0.0, m)
    ea = jnp.where(lse_a == _NEG_INF, 0.0,
                   jnp.exp(jnp.where(lse_a == _NEG_INF, 0.0, lse_a - m_s)))
    eb = jnp.where(lse_b == _NEG_INF, 0.0,
                   jnp.exp(jnp.where(lse_b == _NEG_INF, 0.0, lse_b - m_s)))
    tot = ea + eb
    den = jnp.where(tot == 0.0, 1.0, tot)
    o = (o_a.astype(jnp.float32) * (ea / den)[..., None] +
         o_b.astype(jnp.float32) * (eb / den)[..., None]).astype(o_a.dtype)
    lse = jnp.where(tot == 0.0, _NEG_INF, m_s + jnp.log(den))
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ring_step(frozen, q, k, v, q_start, k_start):
    """One differentiable ring step: ``(o, lse)`` at the given offsets.

    ``frozen`` is the sorted-items tuple of the op params (hashable). Runs
    inside ``shard_map`` — the raw op call underneath re-resolves backend=
    per shard."""
    o, lse = ring_flash.raw(q, k, v, q_start=q_start, k_start=k_start,
                            **dict(frozen))
    return o, lse


def _ring_step_fwd(frozen, q, k, v, q_start, k_start):
    o, lse = ring_flash.raw(q, k, v, q_start=q_start, k_start=k_start,
                            **dict(frozen))
    return (o, lse), (q, k, v, q_start, k_start, o, lse)


def _ring_step_bwd(frozen, res, g):
    q, k, v, q_start, k_start, o, lse = res
    g_o, g_lse = g
    backend, interpret, params = ring_flash._resolve(dict(frozen))
    D = _ring_defines((q, k, v), params)
    b, h, hk = D["b"], D["h"], D["hk"]
    skv, d, dv = D["skv"], D["d"], D["dv"]
    grp = h // hk
    dev = default_device(backend, interpret)
    do = g_o.astype(q.dtype)

    delta_kern = dev.build_kernel(flash_delta_builder, dict(
        b=b, h=h, sq=D["sq"], dv=dv, block_q=D["block_q"], dtype=D["dtype"]))
    delta, = delta_kern.run(do, o.astype(q.dtype))
    # lse is a PUBLIC output of the step (the merge consumes it), so its
    # cotangent lands in the softmax jacobian: ds = p * (dp - delta + g_lse)
    # — the existing fused backward with an adjusted delta
    delta = delta - g_lse

    bwd_kern = dev.build_kernel(ring_flash_bwd_builder, D)
    dq, dk_h, dv_h = bwd_kern.run(q, k, v, do, lse, delta, q_start, k_start)
    dk = dk_h.reshape(b, hk, grp, skv, d).sum(2).astype(k.dtype)
    dvv = dv_h.reshape(b, hk, grp, skv, dv).sum(2).astype(v.dtype)

    def f0(a):  # integer offsets: zero-sized tangent space
        return np.zeros(a.shape, jax.dtypes.float0)

    return dq.astype(q.dtype), dk, dvv, f0(q_start), f0(k_start)


_ring_step.defvjp(_ring_step_fwd, _ring_step_bwd)


# ---------------------------------------------------------------------------
# the declared mesh schedule (OpShard hooks)
# ---------------------------------------------------------------------------

def _ring_shard_step(op, args, params, *, t, n, axis):
    """Ring step ``t`` inside shard_map: shard ``i`` holds kv chunk
    ``(i + t) % n``; queries sit at the end of the GLOBAL kv stream."""
    q, k, v = args[:3]
    sq, skv = q.shape[2], k.shape[2]
    i = lax.axis_index(axis)
    base = n * skv - n * sq
    qs = jnp.reshape(base + i * sq, (1, 1)).astype(jnp.int32)
    ks = jnp.reshape(((i + t) % n) * skv, (1, 1)).astype(jnp.int32)
    frozen = tuple(sorted(params.items()))
    return _ring_step(frozen, q, k, v, qs, ks)


def _ring_in_specs(axis, args):
    p = PartitionSpec(None, None, axis, None)   # q/k/v sharded on seq
    return (p, p, p)


def _ring_out_specs(axis):
    return PartitionSpec(None, None, axis, None)


ring_flash = define_op(
    "ring_flash",
    builder=ring_flash_fwd_builder,
    ref=ring_step_ref,
    derive_defines=_ring_defines,
    pre=_ring_pre,
    public_outputs=1,                        # lse is merge/backward-only
    defaults=dict(causal=True, window=None, sm_scale=None, prefix_len=0,
                  block_q=128, block_kv=128, ring_steps=1,
                  mesh_axis="model"),
    array_params=("q_start", "k_start"),     # dynamic absolute offsets
    ref_params=("q_start", "k_start", "causal", "window", "sm_scale",
                "prefix_len"),
    tune_ref=_ring_tune_ref,
    sweep=dict(block_q=[64, 128, 256, 512], block_kv=[64, 128, 256, 512]),
    example=_ring_example,
    shard=OpShard(
        mesh_axis="model", collective="ppermute",
        in_specs=_ring_in_specs, out_specs=_ring_out_specs,
        rotate=(1, 2),                       # k, v hop around the ring
        extent_param="ring_steps",           # defines/tune key track shards
        step=_ring_shard_step, merge=ring_merge,
        done=lambda acc: acc[0]),            # public result: o
    doc="""One ring step of sequence-parallel flash attention: q against a kv
    chunk at dynamic absolute offsets (``q_start``/``k_start``). Call with
    ``mesh=`` to run the full shard_map ring (k/v rotating by ppermute,
    partials merged exactly); ``ring_flash_attention`` wraps both modes.""",
)


# ---------------------------------------------------------------------------
# public wrapper: mesh ring or local (single-process) ring
# ---------------------------------------------------------------------------

def ring_flash_attention(q, k, v, *, mesh=None, mesh_axis="model",
                         ring_steps=None, causal=True, window=None,
                         sm_scale=None, prefix_len=0, block_q=128,
                         block_kv=128, backend="auto", interpret=None):
    """Sequence-parallel ring flash attention, differentiable in both modes.

    ``mesh=`` runs the declared shard_map schedule: q/k/v arrive sharded
    along their sequence axis over ``mesh_axis``, kv chunks rotate around the
    ring, and the backward retraces the ring in reverse (dk/dv cotangents
    ride the transposed ppermute home). Without a mesh, the SAME per-step
    kernel + exact merge runs over ``ring_steps`` locally-split kv chunks —
    the single-device form of the schedule, bit-comparable against
    ``flash_attention`` and against the mesh run.

    Queries are aligned to the end of the global kv stream (the
    ``flash_attention`` convention), so equal global lengths give plain
    causal self-attention."""
    params = dict(causal=causal, window=window, sm_scale=sm_scale,
                  prefix_len=prefix_len, block_q=block_q, block_kv=block_kv,
                  mesh_axis=mesh_axis, backend=backend, interpret=interpret)
    if mesh is not None:
        if ring_steps is not None and ring_steps != int(mesh.shape[mesh_axis]):
            raise ValueError(
                f"ring_flash_attention: ring_steps={ring_steps} contradicts "
                f"mesh axis {mesh_axis!r} of size {mesh.shape[mesh_axis]}")
        return ring_flash(q, k, v, mesh=mesh, **params)
    n = 1 if ring_steps is None else int(ring_steps)
    sq, skv = q.shape[2], k.shape[2]
    if n < 1 or skv % n:
        raise ValueError(
            f"ring_flash_attention: ring_steps={n} does not divide the kv "
            f"length {skv}")
    chunk = skv // n
    base = skv - sq
    frozen = tuple(sorted(dict(params, ring_steps=n).items()))
    qs = jnp.full((1, 1), base, jnp.int32)
    acc = None
    for t in range(n):
        kc = lax.slice_in_dim(k, t * chunk, (t + 1) * chunk, axis=2)
        vc = lax.slice_in_dim(v, t * chunk, (t + 1) * chunk, axis=2)
        ks = jnp.full((1, 1), t * chunk, jnp.int32)
        part = _ring_step(frozen, q, kc, vc, qs, ks)
        acc = part if acc is None else ring_merge(acc, part)
    return acc[0]
