from .ops import (decode_attention, flash_attention, flash_attention_fwd,
                  flash_decode, flash_decode_paged, paged_decode_attention)
from .ref import (decode_ref, mha_chunked, mha_ref, paged_decode_ref,
                  rolling_slot_pos)
from .ring import ring_flash, ring_flash_attention, ring_merge, ring_step_ref

__all__ = ["flash_attention", "flash_attention_fwd", "flash_decode",
           "decode_attention", "flash_decode_paged", "paged_decode_attention",
           "mha_ref", "mha_chunked", "decode_ref", "paged_decode_ref",
           "rolling_slot_pos", "ring_flash", "ring_flash_attention",
           "ring_merge", "ring_step_ref"]
