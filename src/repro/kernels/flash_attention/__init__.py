from .ops import decode_attention, flash_attention
from .ref import decode_ref, mha_chunked, mha_ref

__all__ = ["flash_attention", "decode_attention", "mha_ref", "mha_chunked",
           "decode_ref"]
