"""Chunked selective-scan in the unified kernel language.

TPU adaptation: the GPU selective-scan kernel parallelizes over threads within
a warp; here channels (d_inner) are the vector lanes and time is walked
sequentially in VMEM-resident chunks, with the (d_block, N) state carried in
VMEM scratch across the chunk grid (trailing *reduce* axis). exp/softplus
fusion and the B-outer-product happen in-register — nothing (Bt, L, Dm, N)-
shaped ever touches HBM, which is the entire point of the kernel.

The per-chunk ``y`` writes are a *streamed* output (``Tile(stream=True)``):
each grid cell writes its own chunk block, so the kernel — formerly a
bespoke Pallas call — is now one source expanding to jnp/loops/pallas. The
host path lives in the ``define_op`` declaration in ``ops.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Scratch, Spec, Tile

__all__ = ["ssm_scan_builder"]


def ssm_scan_builder(D):
    """x, delta: (bt, L, dm); A: (dm, n); B, C: (bt, L, n); Dskip: (1, dm);
    h0: (bt, dm, n) -> y: (bt, L, dm) streamed per chunk, hT: (bt, dm, n).

    Grid (bt, dm/d_block, L/chunk) — chunk is the sequential reduce axis so
    the state scratch carries across time; d-blocks are independent."""
    bt, L, dm, n = D.bt, D.L, D.dm, D.n
    chunk, dblk = D.chunk, D.d_block
    dtype = jnp.dtype(D.dtype)

    def body(ctx, x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
             y_ref, hT_ref):
        h_scr, = ctx.scratch

        @ctx.when(ctx.is_first)
        def _init():
            h_scr[...] = h0_ref[0]

        A = a_ref[...]                      # (dblk, n)
        Dskip = d_ref[...]                  # (1, dblk)
        x = x_ref[0]                        # (chunk, dblk)
        dt = dt_ref[0]                      # (chunk, dblk)
        Bm = b_ref[0]                       # (chunk, n)
        Cm = c_ref[0]                       # (chunk, n)

        def step(t, carry):
            h, ys = carry
            dt_t = dt[t][:, None].astype(jnp.float32)          # (dblk, 1)
            x_t = x[t][:, None].astype(jnp.float32)
            dA = jnp.exp(dt_t * A)                             # (dblk, n)
            dBx = dt_t * Bm[t][None, :] * x_t                  # (dblk, n)
            h = dA * h + dBx
            y_t = (h * Cm[t][None, :]).sum(axis=1) + \
                Dskip[0] * x[t].astype(jnp.float32)
            ys = jax.lax.dynamic_update_slice(ys, y_t[None, :], (t, 0))
            return h, ys

        h0 = h_scr[...]
        ys0 = jnp.zeros((chunk, dblk), jnp.float32)
        hT, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
        h_scr[...] = hT
        y_ref[0] = ys.astype(y_ref.dtype)   # streamed: this chunk's block

        @ctx.when(ctx.is_last)
        def _fin():
            hT_ref[0] = h_scr[...]

    return Spec(
        "ssm_scan",
        grid=(bt, dm // dblk, L // chunk),
        reduce_axes=(2,),
        scratch=[Scratch((dblk, n), jnp.float32)],
        inputs=[
            Tile("x", (bt, L, dm), dtype, block=(1, chunk, dblk),
                 index=lambda b, di, ci: (b, ci, di)),
            Tile("delta", (bt, L, dm), dtype, block=(1, chunk, dblk),
                 index=lambda b, di, ci: (b, ci, di)),
            Tile("A", (dm, n), jnp.float32, block=(dblk, n),
                 index=lambda b, di, ci: (di, 0)),
            Tile("B", (bt, L, n), dtype, block=(1, chunk, n),
                 index=lambda b, di, ci: (b, ci, 0)),
            Tile("C", (bt, L, n), dtype, block=(1, chunk, n),
                 index=lambda b, di, ci: (b, ci, 0)),
            Tile("Dskip", (1, dm), jnp.float32, block=(1, dblk),
                 index=lambda b, di, ci: (0, di)),
            Tile("h0", (bt, dm, n), jnp.float32, block=(1, dblk, n),
                 index=lambda b, di, ci: (b, di, 0)),
        ],
        outputs=[
            Tile("y", (bt, L, dm), dtype, block=(1, chunk, dblk),
                 index=lambda b, di, ci: (b, ci, di), stream=True),
            Tile("hT", (bt, dm, n), jnp.float32, block=(1, dblk, n),
                 index=lambda b, di, ci: (b, di, 0)),
        ],
        body=body)
