"""Chunked selective-scan as a Pallas TPU kernel.

TPU adaptation: the GPU selective-scan kernel parallelizes over threads within
a warp; here channels (d_inner) are the vector lanes and time is walked
sequentially in VMEM-resident chunks, with the (d_block, N) state carried in
VMEM scratch across the chunk grid (innermost axis). exp/softplus fusion and
the B-outer-product happen in-register — nothing (Bt, L, Dm, N)-shaped ever
touches HBM, which is the entire point of the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssm_scan_pallas"]


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                 y_ref, hT_ref, h_scr, *, chunk, nchunks, d_block, n_state):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    A = a_ref[...]                      # (d_block, N)
    Dskip = d_ref[...]                  # (1, d_block)
    x = x_ref[0]                        # (chunk, d_block)
    dt = dt_ref[0]                      # (chunk, d_block)
    Bm = b_ref[0]                       # (chunk, N)
    Cm = c_ref[0]                       # (chunk, N)

    def step(t, carry):
        h, ys = carry
        dt_t = dt[t][:, None].astype(jnp.float32)          # (d_block, 1)
        x_t = x[t][:, None].astype(jnp.float32)
        dA = jnp.exp(dt_t * A)                             # (d_block, N)
        dBx = dt_t * Bm[t][None, :] * x_t                  # (d_block, N)
        h = dA * h + dBx
        y_t = (h * Cm[t][None, :]).sum(axis=1) + Dskip[0] * x[t].astype(jnp.float32)
        ys = jax.lax.dynamic_update_slice(ys, y_t[None, :], (t, 0))
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, d_block), jnp.float32)
    hT, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scr[...] = hT
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ci == nchunks - 1)
    def _fin():
        hT_ref[0] = h_scr[...]


def ssm_scan_pallas(x, delta, A, B, C, D, *, h0=None, chunk=64, d_block=None,
                    interpret=True):
    """Fused selective scan. Shapes as in ref.selective_scan_ref.

    Grid: (batch, Dm/d_block, L/chunk) — chunk innermost so the state scratch
    carries across time; d-blocks are independent.
    """
    bt, L, dm = x.shape
    n = A.shape[1]
    d_block = d_block or min(dm, 512)
    chunk = min(chunk, L)
    assert dm % d_block == 0 and L % chunk == 0, (dm, d_block, L, chunk)
    nchunks = L // chunk
    if h0 is None:
        h0 = jnp.zeros((bt, dm, n), jnp.float32)
    D2 = D.reshape(1, dm)

    kernel = functools.partial(_scan_kernel, chunk=chunk, nchunks=nchunks,
                               d_block=d_block, n_state=n)
    y, hT = pl.pallas_call(
        kernel,
        grid=(bt, dm // d_block, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, di, ci: (b, ci, di)),  # x
            pl.BlockSpec((1, chunk, d_block), lambda b, di, ci: (b, ci, di)),  # delta
            pl.BlockSpec((d_block, n), lambda b, di, ci: (di, 0)),             # A
            pl.BlockSpec((1, chunk, n), lambda b, di, ci: (b, ci, 0)),         # B
            pl.BlockSpec((1, chunk, n), lambda b, di, ci: (b, ci, 0)),         # C
            pl.BlockSpec((1, d_block), lambda b, di, ci: (0, di)),             # D
            pl.BlockSpec((1, d_block, n), lambda b, di, ci: (b, di, 0)),       # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, di, ci: (b, ci, di)),  # y
            pl.BlockSpec((1, d_block, n), lambda b, di, ci: (b, di, 0)),       # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, L, dm), x.dtype),
            jax.ShapeDtypeStruct((bt, dm, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(x, delta, A, B, C, D2, h0)
    return y, hT
