from .ops import ssm_scan
from .ref import selective_scan_assoc, selective_scan_ref

__all__ = ["ssm_scan", "selective_scan_ref", "selective_scan_assoc"]
