from .kernel import ssm_scan_builder
from .ops import ssm_scan, ssm_scan_pallas
from .ref import selective_scan_assoc, selective_scan_ref

__all__ = ["ssm_scan", "ssm_scan_builder", "ssm_scan_pallas",
           "selective_scan_ref", "selective_scan_assoc"]
