"""Pure-jnp oracle for the selective state-space scan (mamba1 core)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["selective_scan_ref", "selective_scan_assoc"]


def selective_scan_ref(x, delta, A, B, C, D, *, h0=None):
    """Sequential-scan oracle.

    x, delta: (Bt, L, Dm); A: (Dm, N); B, C: (Bt, L, N); D: (Dm,)
    h_t = exp(delta_t * A) * h_{t-1} + delta_t * B_t * x_t
    y_t = C_t . h_t + D * x_t
    Returns y (Bt, L, Dm) and final state h (Bt, Dm, N).
    """
    bt, L, dm = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bt, dm, n), jnp.float32)

    dA = jnp.exp(delta[..., None].astype(jnp.float32) * A)          # (Bt,L,Dm,N)
    dBx = (delta[..., None] * B[:, :, None, :] * x[..., None]).astype(jnp.float32)

    def step(h, inputs):
        dA_t, dBx_t = inputs
        h = dA_t * h + dBx_t
        return h, h

    hT, hs = jax.lax.scan(step, h0, (dA.transpose(1, 0, 2, 3),
                                     dBx.transpose(1, 0, 2, 3)))
    hs = hs.transpose(1, 0, 2, 3)                                    # (Bt,L,Dm,N)
    y = jnp.einsum("bldn,bln->bld", hs, C.astype(jnp.float32)) + D * x
    return y.astype(x.dtype), hT


def selective_scan_assoc(x, delta, A, B, C, D, *, h0=None):
    """Parallel associative-scan form (what the jnp model path uses).

    Same math via the linear-recurrence combine ((a1,b1)*(a2,b2) = (a1a2, a2b1+b2)).
    """
    bt, L, dm = x.shape
    n = A.shape[1]
    dA = jnp.exp(delta[..., None].astype(jnp.float32) * A)
    dBx = (delta[..., None] * B[:, :, None, :] * x[..., None]).astype(jnp.float32)
    if h0 is not None:
        # fold h0 into the first step: h1 = dA_1 h0 + dBx_1
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bldn,bln->bld", hs, C.astype(jnp.float32)) + D * x
    return y.astype(x.dtype), hs[:, -1]
