"""Public selective-scan op: pallas forward, associative-scan VJP."""

from __future__ import annotations

import jax

from .kernel import ssm_scan_pallas
from .ref import selective_scan_assoc, selective_scan_ref

__all__ = ["ssm_scan"]


@jax.custom_vjp
def _scan(x, delta, A, B, C, D):
    y, _ = ssm_scan_pallas(x, delta, A, B, C, D)
    return y


def _scan_fwd(x, delta, A, B, C, D):
    return _scan(x, delta, A, B, C, D), (x, delta, A, B, C, D)


def _scan_bwd(res, g):
    x, delta, A, B, C, D = res
    _, vjp = jax.vjp(lambda *a: selective_scan_assoc(*a)[0], x, delta, A, B, C, D)
    return vjp(g)


_scan.defvjp(_scan_fwd, _scan_bwd)


def ssm_scan(x, delta, A, B, C, D):
    """Differentiable fused selective scan; see ref.selective_scan_ref."""
    return _scan(x, delta, A, B, C, D)
