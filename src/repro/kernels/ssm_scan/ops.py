"""Public selective-scan op — a ``define_op`` declaration.

Forward: the unified-language chunked kernel (streamed per-chunk ``y``,
state carried in scratch across the chunk reduce axis). Backward: oracle
VJP through the associative-scan reference (what the jnp model path uses).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import OpVJP, define_op, fit_block
from .kernel import ssm_scan_builder
from .ref import selective_scan_assoc, selective_scan_ref

__all__ = ["ssm_scan", "ssm_scan_pallas"]


def _pre(args, params):
    # read-only on params (.get, never .pop): pre hooks must not eat keys
    # from a dict a caller may reuse across calls
    x, delta, A, B, C, D = args
    bt, L, dm = x.shape
    n = A.shape[1]
    h0 = params.get("h0")
    if h0 is None:
        h0 = jnp.zeros((bt, dm, n), jnp.float32)
    return x, delta, A, B, C, D.reshape(1, dm), h0


def _defines(args, params):
    x, delta, A, B, C, D2, h0 = args
    bt, L, dm = x.shape
    n = A.shape[1]
    want_chunk = params["chunk"]
    want_dblk = params["d_block"] or min(dm, 512)
    chunk = fit_block(want_chunk, L)
    d_block = fit_block(want_dblk, dm)
    ncells = bt * (dm // d_block) * (L // chunk)
    degraded = chunk < min(want_chunk, L) or d_block < min(want_dblk, dm)
    if degraded and ncells > 1 << 16:
        # prime/awkward dims collapsed the blocks; the grid would make Spec
        # validation and the expansions pathologically slow — fail loudly
        raise ValueError(
            f"ssm_scan: (L={L}, dm={dm}) degraded blocks to (chunk={chunk}, "
            f"d_block={d_block}) = {ncells} grid cells; pad the operands or "
            "pass chunk/d_block that divide the shapes")
    return dict(bt=bt, L=L, dm=dm, n=n, chunk=chunk, d_block=d_block,
                dtype=jnp.dtype(x.dtype).name)


def _ref(x, delta, A, B, C, D):
    return selective_scan_assoc(x, delta, A, B, C, D)[0]


def _bwd(params, res, g):
    import jax

    _, vjp = jax.vjp(lambda *a: selective_scan_assoc(*a)[0], *res)
    return vjp(g)


def _tune_ref(args, params):
    x, delta, A, B, C, D2, h0 = args
    return selective_scan_ref(x, delta, A, B, C, D2[0], h0=h0)  # (y, hT)


def _example(rng):
    import numpy as np

    bt, L, dm, n = 1, 64, 16, 4
    x = rng.randn(bt, L, dm).astype("float32")
    delta = (np.log1p(np.exp(rng.randn(bt, L, dm))) * 0.1).astype("float32")
    A = -(np.abs(rng.randn(dm, n)) + 0.1).astype("float32")
    B = rng.randn(bt, L, n).astype("float32")
    C = rng.randn(bt, L, n).astype("float32")
    D = rng.randn(dm).astype("float32")
    return (x, delta, A, B, C, D), dict(chunk=16)


ssm_scan = define_op(
    "ssm_scan",
    builder=ssm_scan_builder,
    ref=_ref,
    derive_defines=_defines,
    pre=_pre,
    vjp=OpVJP(bwd=_bwd),
    public_outputs=1,                       # hT is residual/serving-only
    defaults=dict(chunk=64, d_block=None),
    array_params=("h0",),
    tune_ref=_tune_ref,
    sweep=dict(chunk=[16, 32, 64, 128], d_block=[128, 256, 512]),
    example=_example,
    doc="""Differentiable fused selective scan; see ref.selective_scan_ref.
    x, delta: (Bt, L, Dm); A: (Dm, N); B, C: (Bt, L, N); D: (Dm,) -> y.""",
)


def ssm_scan_pallas(x, delta, A, B, C, D, *, h0=None, chunk=64, d_block=None,
                    interpret=None, backend="pallas"):
    """Functional entry point returning (y, hT) — shapes as in
    ref.selective_scan_ref; historic name kept for state-carry composition."""
    return ssm_scan.raw(x, delta, A, B, C, D, h0=h0, chunk=chunk,
                        d_block=d_block, backend=backend, interpret=interpret)
