from .kernel import rmsnorm_builder
from .ops import rmsnorm, rmsnorm_pallas, rmsnorm_unified
from .ref import rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_builder", "rmsnorm_pallas", "rmsnorm_ref",
           "rmsnorm_unified"]
