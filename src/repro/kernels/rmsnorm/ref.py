"""Pure-jnp oracle for RMSNorm."""

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_ref"]


def rmsnorm_ref(x, w, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)
