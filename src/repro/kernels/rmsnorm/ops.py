"""Public RMSNorm op: pallas forward, oracle VJP."""

from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm_pallas
from .ref import rmsnorm_ref

__all__ = ["rmsnorm"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms(x, w, eps):
    return rmsnorm_pallas(x, w, eps=eps)


def _rms_fwd(x, w, eps):
    return _rms(x, w, eps), (x, w)


def _rms_bwd(eps, res, g):
    x, w = res
    _, vjp = jax.vjp(lambda x_, w_: rmsnorm_ref(x_, w_, eps=eps), x, w)
    return vjp(g)


_rms.defvjp(_rms_fwd, _rms_bwd)


def rmsnorm(x, w, *, eps=1e-6):
    return _rms(x, w, eps)
