"""Public RMSNorm op — a single ``define_op`` declaration (oracle VJP)."""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core import define_op, fit_block, oracle_vjp
from .kernel import rmsnorm_builder
from .ref import rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_unified", "rmsnorm_pallas"]


def _early(args, params):
    x, w = args
    if x.size == 0:
        return jnp.asarray(x)  # empty input: nothing to normalize
    return None


def _pre(args, params):
    x, w = args
    d = x.shape[-1]
    return x.reshape(math.prod(x.shape[:-1]), d), w


def _defines(args, params):
    x2, w = args
    rows, d = x2.shape
    return dict(rows=rows, d=d,
                block_rows=fit_block(params["block_rows"], rows),
                eps=float(params["eps"]),
                dtype=jnp.dtype(x2.dtype).name,
                wdtype=jnp.dtype(w.dtype).name)


def _post(outs, args, params):
    return outs[0].reshape(args[0].shape)


def _example(rng):
    x = rng.randn(3, 20, 64).astype("float32")
    w = rng.randn(64).astype("float32")
    return (x, w), dict(block_rows=16)


rmsnorm = define_op(
    "rmsnorm",
    builder=rmsnorm_builder,
    ref=rmsnorm_ref,
    derive_defines=_defines,
    early=_early,
    pre=_pre,
    post=_post,
    vjp=oracle_vjp(rmsnorm_ref, params=("eps",)),
    defaults=dict(eps=1e-6, block_rows=256),
    ref_params=("eps",),
    sweep=dict(block_rows=[32, 64, 128, 256, 512]),
    example=_example,
    doc="""x: (..., D); w: (D,). Normalizes the last axis on any backend.

    Differentiable (oracle VJP through ``rmsnorm_ref``); the forward is the
    unified-language kernel on the selected backend.""",
)


# -- backward-compatible names ------------------------------------------------

def rmsnorm_unified(x, w, *, eps=1e-6, block_rows=256, backend="pallas",
                    interpret=None):
    """Thin alias over the op (historic name for the unified expansion)."""
    return rmsnorm(x, w, eps=eps, block_rows=block_rows, backend=backend,
                   interpret=interpret)


def rmsnorm_pallas(x, w, *, eps=1e-6, block_rows=256, interpret=True):
    """Backward-compatible name for the pallas expansion (interpret honored)."""
    return rmsnorm(x, w, eps=eps, block_rows=block_rows, backend="pallas",
                   interpret=interpret)
