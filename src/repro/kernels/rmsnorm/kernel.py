"""Fused RMSNorm Pallas kernel (row blocks resident in VMEM)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_pallas"]


def _rms_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = (x * x).mean(axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...]).astype(o_ref.dtype)


def rmsnorm_pallas(x, w, *, eps=1e-6, block_rows=256, interpret=True):
    """x: (..., D); w: (D,). Normalizes the last axis."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
