"""Fused RMSNorm expressed in the unified kernel language.

One builder expands to all three backends (``jnp`` / ``loops`` / ``pallas``);
the former bespoke Pallas call site is gone. Rows stay resident in VMEM per
grid cell, so the sum-of-squares reduction is within-tile (no reduce axis
needed — contrast ``repro.kernels.matmul``, which carries scratch across a
sequential reduce axis). The host path (backend pick, block fitting, build
cache, VJP) lives in the ``define_op`` declaration in ``ops.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Spec, Tile

__all__ = ["rmsnorm_builder"]


def rmsnorm_builder(D):
    def body(ctx, x, w, o):
        xf = x[...].astype(jnp.float32)
        var = (xf * xf).mean(axis=-1, keepdims=True)
        o[...] = (xf * jax.lax.rsqrt(var + D.eps) * w[...]).astype(o.dtype)

    rows, d, br = D.rows, D.d, D.block_rows
    dtype, wdtype = jnp.dtype(D.dtype), jnp.dtype(D.wdtype)
    return Spec(
        "rmsnorm", grid=(rows // br,),
        inputs=[Tile("x", (rows, d), dtype, block=(br, d), index=lambda i: (i, 0)),
                Tile("w", (d,), wdtype)],           # whole-array tile
        outputs=[Tile("o", (rows, d), dtype, block=(br, d), index=lambda i: (i, 0))],
        body=body)
