"""Fused RMSNorm expressed in the unified kernel language.

One builder expands to all three backends (``jnp`` / ``loops`` / ``pallas``);
the former bespoke ``pl.pallas_call`` is gone. Rows stay resident in VMEM per
grid cell, so the sum-of-squares reduction is within-tile (no reduce axis
needed — contrast ``repro.kernels.matmul``, which carries scratch across a
sequential reduce axis).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import Spec, Tile, default_device, fit_block

__all__ = ["rmsnorm_builder", "rmsnorm_unified", "rmsnorm_pallas"]


def rmsnorm_builder(D):
    def body(ctx, x, w, o):
        xf = x[...].astype(jnp.float32)
        var = (xf * xf).mean(axis=-1, keepdims=True)
        o[...] = (xf * jax.lax.rsqrt(var + D.eps) * w[...]).astype(o.dtype)

    rows, d, br = D.rows, D.d, D.block_rows
    dtype, wdtype = jnp.dtype(D.dtype), jnp.dtype(D.wdtype)
    return Spec(
        "rmsnorm", grid=(rows // br,),
        inputs=[Tile("x", (rows, d), dtype, block=(br, d), index=lambda i: (i, 0)),
                Tile("w", (d,), wdtype)],           # whole-array tile
        outputs=[Tile("o", (rows, d), dtype, block=(br, d), index=lambda i: (i, 0))],
        body=body)


def rmsnorm_unified(x, w, *, eps=1e-6, block_rows=256, backend="pallas",
                    interpret=None):
    """x: (..., D); w: (D,). Normalizes the last axis on any backend.

    ``interpret=None`` lets the Device pick (Pallas interpret mode off-TPU);
    pass an explicit bool to force it."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = math.prod(orig_shape[:-1])
    if rows == 0 or d == 0:
        return jnp.asarray(x)  # empty input: nothing to normalize
    x2 = x.reshape(rows, d)
    block_rows = fit_block(block_rows, rows)
    kernel = default_device(backend, interpret).build_kernel(rmsnorm_builder, dict(
        rows=rows, d=d, block_rows=block_rows, eps=float(eps),
        dtype=jnp.dtype(x.dtype).name, wdtype=jnp.dtype(w.dtype).name))
    (out,) = kernel.run(x2, w)
    return out.reshape(orig_shape)


def rmsnorm_pallas(x, w, *, eps=1e-6, block_rows=256, interpret=True):
    """Backward-compatible name for the pallas expansion (interpret honored)."""
    return rmsnorm_unified(x, w, eps=eps, block_rows=block_rows,
                           backend="pallas", interpret=interpret)
