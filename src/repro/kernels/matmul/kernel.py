"""Blocked matmul in the unified kernel language — the reduce-axis showcase.

The K dimension is a sequential reduce axis: grid cells ``(i, j, kk)`` with
the same ``(i, j)`` are visited in ``kk`` order and share one f32 VMEM scratch
accumulator (``ctx.scratch``), initialized under ``ctx.when(ctx.is_first)``
and flushed to the output block under ``ctx.when(ctx.is_last)`` — the same
init/accumulate/flush protocol flash-attention hand-rolls for its m/l/acc
state, now expressible in one portable kernel source.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import Scratch, Spec, Tile

__all__ = ["matmul_builder"]


def matmul_builder(D):
    def body(ctx, a, b, c):
        acc, = ctx.scratch

        @ctx.when(ctx.is_first)
        def _init():
            # zeros from shape/dtype, not zeros_like(acc[...]): first-visit
            # scratch contents are undefined, so the init must not read them
            acc[...] = jnp.zeros(acc.shape, acc.dtype)

        acc[...] += jnp.dot(a[...], b[...], preferred_element_type=jnp.float32)

        @ctx.when(ctx.is_last)
        def _flush():
            c[...] = acc[...].astype(c.dtype)

    M, K, N = D.M, D.K, D.N
    bm, bk, bn = D.bm, D.bk, D.bn
    dtype = jnp.dtype(D.dtype)
    out_dtype = jnp.dtype(getattr(D, "out_dtype", D.dtype))
    return Spec(
        "matmul", grid=(M // bm, N // bn, K // bk),
        reduce_axes=(2,),
        scratch=[Scratch((bm, bn), jnp.float32)],
        inputs=[Tile("a", (M, K), dtype, block=(bm, bk), index=lambda i, j, kk: (i, kk)),
                Tile("b", (K, N), dtype, block=(bk, bn), index=lambda i, j, kk: (kk, j))],
        outputs=[Tile("c", (M, N), out_dtype, block=(bm, bn), index=lambda i, j, kk: (i, j))],
        body=body)
