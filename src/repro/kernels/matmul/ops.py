"""Public blocked-matmul op over the unified kernel language."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import default_device, fit_block
from .kernel import matmul_builder

__all__ = ["matmul"]


def matmul(a, b, *, block_m=128, block_n=128, block_k=128, backend="pallas",
           out_dtype=None):
    """a: (M, K) @ b: (K, N) with f32 accumulation across a reduce axis."""
    (m, k), (k2, n) = a.shape, b.shape
    if k != k2:
        raise ValueError(f"matmul: inner dims disagree ({k} vs {k2})")
    if a.dtype != b.dtype:
        raise ValueError(f"matmul: dtypes disagree ({a.dtype} vs {b.dtype})")
    if m == 0 or n == 0 or k == 0:  # nothing to tile; K==0 contracts to zeros
        return jnp.zeros((m, n), jnp.dtype(out_dtype or a.dtype))
    bm, bk, bn = fit_block(block_m, m), fit_block(block_k, k), fit_block(block_n, n)
    ncells = (m // bm) * (n // bn) * (k // bk)
    degraded = (bm < min(block_m, m) or bk < min(block_k, k)
                or bn < min(block_n, n))
    if degraded and ncells > 1 << 16:
        # fit_block shrank a block to honor divisibility (prime/awkward dims)
        # and the resulting grid makes Spec validation and the expansions
        # pathologically slow — fail loudly instead of silently crawling.
        # Cleanly-dividing blocks on big shapes are legitimate and pass.
        raise ValueError(
            f"matmul: {m}x{k}x{n} degraded the requested blocks to "
            f"({bm},{bk},{bn}) = {ncells} grid cells; pad the operands or "
            "pass block sizes that divide the shapes")
    defines = dict(
        M=m, K=k, N=n, bm=bm, bk=bk, bn=bn,
        dtype=jnp.dtype(a.dtype).name,
        out_dtype=jnp.dtype(out_dtype or a.dtype).name)
    kernel = default_device(backend).build_kernel(matmul_builder, defines)
    (out,) = kernel.run(a, b)
    return out
