"""Public blocked-matmul op — a single ``define_op`` declaration."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import define_op, fit_block
from .kernel import matmul_builder
from .ref import matmul_ref

__all__ = ["matmul"]


def _early(args, params):
    a, b = args
    (m, k), (k2, n) = a.shape, b.shape
    if k != k2:
        raise ValueError(f"matmul: inner dims disagree ({k} vs {k2})")
    if a.dtype != b.dtype:
        raise ValueError(f"matmul: dtypes disagree ({a.dtype} vs {b.dtype})")
    if m == 0 or n == 0 or k == 0:  # nothing to tile; K==0 contracts to zeros
        return jnp.zeros((m, n), jnp.dtype(params["out_dtype"] or a.dtype))
    return None


def _defines(args, params):
    a, b = args
    (m, k), (_, n) = a.shape, b.shape
    block_m, block_n, block_k = (params["block_m"], params["block_n"],
                                 params["block_k"])
    bm, bk, bn = fit_block(block_m, m), fit_block(block_k, k), fit_block(block_n, n)
    ncells = (m // bm) * (n // bn) * (k // bk)
    degraded = (bm < min(block_m, m) or bk < min(block_k, k)
                or bn < min(block_n, n))
    if degraded and ncells > 1 << 16:
        # fit_block shrank a block to honor divisibility (prime/awkward dims)
        # and the resulting grid makes Spec validation and the expansions
        # pathologically slow — fail loudly instead of silently crawling.
        # Cleanly-dividing blocks on big shapes are legitimate and pass.
        raise ValueError(
            f"matmul: {m}x{k}x{n} degraded the requested blocks to "
            f"({bm},{bk},{bn}) = {ncells} grid cells; pad the operands or "
            "pass block sizes that divide the shapes")
    return dict(
        M=m, K=k, N=n, bm=bm, bk=bk, bn=bn,
        dtype=jnp.dtype(a.dtype).name,
        out_dtype=jnp.dtype(params["out_dtype"] or a.dtype).name)


def _example(rng):
    a = rng.randn(48, 64).astype("float32")
    b = rng.randn(64, 32).astype("float32")
    return (a, b), dict(block_m=16, block_n=16, block_k=32)


matmul = define_op(
    "matmul",
    builder=matmul_builder,
    ref=matmul_ref,
    derive_defines=_defines,
    early=_early,
    defaults=dict(block_m=128, block_n=128, block_k=128, out_dtype=None),
    ref_params=("out_dtype",),
    sweep=dict(bm=[32, 64, 128, 256], bn=[32, 64, 128, 256],
               bk=[32, 64, 128, 256]),
    example=_example,
    doc="""a: (M, K) @ b: (K, N) with f32 accumulation across a reduce axis.

    One kernel source (``matmul_builder``) expands to jnp/loops/pallas; the
    host path (backend pick, block fitting, build cache, tuning) is owned by
    ``define_op``.""",
)
