"""Pure-jnp oracle for the blocked matmul."""

import jax.numpy as jnp

__all__ = ["matmul_ref"]


def matmul_ref(a, b, *, out_dtype=None):
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)
