from .kernel import matmul_builder
from .ops import matmul
from .ref import matmul_ref

__all__ = ["matmul", "matmul_builder", "matmul_ref"]
