"""Kernels for the LM hot-spots.

Each kernel ships with ``kernel.py`` (the unified-language builder),
``ops.py`` (a single ``define_op`` declaration — the front-end owns backend
selection, defines derivation, kernel caching, VJP wiring and autotuning)
and ``ref.py`` (pure-jnp oracle), validated against the oracle across
backends and shape/dtype sweeps. ``matmul``, ``rmsnorm``, ``ssm_scan`` and
the flash-attention FORWARD are written once in the unified kernel language
(``repro.core.lang``) and expand to every backend; flash-attention's
backward and single-token decode remain hand-tiled ``pl.pallas_call``
kernels (ROADMAP: port next).
"""

from . import flash_attention, matmul, rmsnorm, ssm_scan  # noqa: F401
