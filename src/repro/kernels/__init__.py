"""Kernels for the LM hot-spots.

Each kernel ships with ``kernel.py`` (the unified-language builder),
``ops.py`` (``define_op`` declarations — the front-end owns backend
selection, defines derivation, kernel caching, VJP wiring and autotuning)
and ``ref.py`` (pure-jnp oracle), validated against the oracle across
backends and shape/dtype sweeps. EVERY kernel — ``matmul``, ``rmsnorm``,
``ssm_scan``, the full flash-attention family (forward, fused backward,
single-token decode) and the fused LM head (``lm_head``: matmul + online-
softmax row stats at multiple reduce granularities) — is written once in
the unified kernel language
(``repro.core.lang``) and expands to every backend; ``scripts/ci.sh`` fails
on any bespoke Pallas call site under this package.
"""

from . import apps, flash_attention, lm_head, matmul, rmsnorm, ssm_scan  # noqa: F401
