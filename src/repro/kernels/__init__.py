"""Hand-tiled Pallas TPU kernels for the LM hot-spots.

Each kernel ships with ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jitted wrapper + custom VJP) and ``ref.py`` (pure-jnp oracle),
validated against the oracle in interpret mode across shape/dtype sweeps.
"""

from . import flash_attention, rmsnorm, ssm_scan  # noqa: F401
