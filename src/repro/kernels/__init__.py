"""Kernels for the LM hot-spots.

Each kernel ships with ``kernel.py``, ``ops.py`` (jitted wrapper + custom
VJP where needed) and ``ref.py`` (pure-jnp oracle), validated against the
oracle in interpret mode across shape/dtype sweeps. ``flash_attention`` and
``ssm_scan`` are hand-tiled ``pl.pallas_call`` kernels; ``rmsnorm`` and
``matmul`` are written once in the unified kernel language
(``repro.core.lang``) and expand to every backend.
"""

from . import flash_attention, matmul, rmsnorm, ssm_scan  # noqa: F401
