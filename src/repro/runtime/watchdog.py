"""Straggler detection: rolling step-time statistics with a sigma threshold.

At multi-pod scale a straggling host shows up as a slow all-reduce on every
peer; the watchdog flags steps slower than mean + k*sigma (and absolute
deadlines) so the launcher can checkpoint + evict/restart. On this container
it is exercised by tests with synthetic timings.
"""

from __future__ import annotations

import collections
import math
import time

__all__ = ["StepWatchdog"]


class StepWatchdog:
    def __init__(self, *, window: int = 50, sigma: float = 4.0,
                 absolute_deadline_s: float | None = None,
                 min_samples: int = 10, on_straggler=None):
        self.window = window
        self.sigma = sigma
        self.deadline = absolute_deadline_s
        self.min_samples = min_samples
        self.times = collections.deque(maxlen=window)
        self.flagged: list[tuple[int, float]] = []
        self.on_straggler = on_straggler
        self._t0 = None
        self._step = 0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - self._t0
        self.observe(self._step, dt)
        self._step += 1
        return dt

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        flagged = False
        if self.deadline is not None and dt > self.deadline:
            flagged = True
        if len(self.times) >= self.min_samples:
            mean = sum(self.times) / len(self.times)
            var = sum((t - mean) ** 2 for t in self.times) / len(self.times)
            if dt > mean + self.sigma * math.sqrt(var) and dt > 1.5 * mean:
                flagged = True
        self.times.append(dt)
        if flagged:
            self.flagged.append((step, dt))
            if self.on_straggler:
                self.on_straggler(step, dt)
        return flagged

    @property
    def mean(self) -> float:
        return sum(self.times) / len(self.times) if self.times else 0.0
