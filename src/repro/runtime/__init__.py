from .elastic import choose_mesh_shape, reshard  # noqa: F401
from .failures import ChaosError, FailureInjector  # noqa: F401
from .watchdog import StepWatchdog  # noqa: F401
