"""Failure injection for recovery testing (simulated node loss)."""

from __future__ import annotations

__all__ = ["ChaosError", "FailureInjector"]


class ChaosError(RuntimeError):
    """Injected failure (stands in for a lost host / preempted slice)."""


class FailureInjector:
    def __init__(self, fail_at_steps=(), fail_once: bool = True):
        self.fail_at = set(fail_at_steps)
        self.fail_once = fail_once
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            if self.fail_once and step in self.fired:
                return
            self.fired.add(step)
            raise ChaosError(f"injected failure at step {step}")
