"""Elastic scaling: recompute the mesh for the surviving device set and
reshard live state (params/opt) or a checkpoint onto it.

Policy: keep the model axis (TP must match weight partitioning divisors) and
shrink/grow the data axis to the largest size that fits the surviving
devices — DP degree is the elastic dimension, which is how production
systems (and our launcher) handle slice loss without re-tuning layouts.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

__all__ = ["choose_mesh_shape", "reshard"]


def choose_mesh_shape(n_devices: int, *, model: int = 16,
                      pod: int | None = None) -> tuple:
    """Largest (pod?, data, model) grid with fixed model axis."""
    assert n_devices >= model, (n_devices, model)
    if pod:
        data = n_devices // (pod * model)
        assert data >= 1
        return (pod, data, model)
    data = n_devices // model
    return (data, model)


def reshard(tree, specs, new_mesh: Mesh):
    """Place every leaf of ``tree`` onto ``new_mesh`` under ``specs``."""
    def place(leaf, spec):
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))
    return jax.tree.map(place, tree, specs,
                        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
