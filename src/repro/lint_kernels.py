"""Registry-wide kernel lint — run the static analyzer over every op.

Sweeps every registered ``define_op`` across its example shapes and its
declared autotune sweep (the same candidate space ``op.tune`` explores), and
analyzes every buildable candidate spec: grid invariants, scratch liveness,
output coverage, dimension-semantics consistency (see
:mod:`repro.core.analyze`). Ops whose families build extra kernels outside
the registry (flash-attention's delta/bwd, the LM head's fused-CE backward)
have those builders linted too, against the same defines.

  PYTHONPATH=src python -m repro.lint_kernels            # verdict table
  PYTHONPATH=src python -m repro.lint_kernels --strict   # any finding fails
  PYTHONPATH=src python -m repro.lint_kernels --json artifacts/analyze.json
  PYTHONPATH=src python -m repro.lint_kernels --cost     # + static cost table

``--cost`` additionally runs the static cost model (VMEM footprint vs. the
``$REPRO_VMEM_BUDGET`` budget, HBM bytes moved, FLOPs, arithmetic intensity)
on every op's default config — its findings (``VMEM_OVERFLOW``,
``FOOTPRINT_NEAR_LIMIT``, ``REDUNDANT_FETCH``) join the lint verdict — and
previews which autotune sweep candidates the cost model would prune.
``--cost-json PATH`` writes the table machine-readably (the CI ``analyze``
stage's ``artifacts/cost.json``).

Exit status: 0 when clean; 1 on any error-severity finding (any finding at
all under ``--strict`` — what the CI ``analyze`` stage runs).
"""

from __future__ import annotations

import argparse
import itertools
import json
import os

import numpy as np

__all__ = ["cost_op", "lint_op", "main"]


def _aux_builders(op_name: str) -> list:
    """Kernel builders a family builds directly (no registry entry of their
    own) — linted with the op's defines, which are a superset of theirs."""
    if op_name == "flash_attention":
        from repro.kernels.flash_attention.kernel import (
            flash_bwd_builder, flash_delta_builder)
        return [("flash_attention/delta", flash_delta_builder),
                ("flash_attention/bwd", flash_bwd_builder)]
    if op_name == "lm_head_ce":
        from repro.kernels.lm_head.kernel import lm_head_bwd_builder
        return [("lm_head_ce/bwd", lm_head_bwd_builder)]
    if op_name == "ring_flash":
        from repro.kernels.flash_attention.kernel import (
            flash_delta_builder, ring_flash_bwd_builder)
        return [("ring_flash/delta", flash_delta_builder),
                ("ring_flash/bwd", ring_flash_bwd_builder)]
    return []


def _candidates(op, defines: dict):
    """The derived defines first, then every autotune sweep combination over
    them — the exact candidate space a tuning run would build."""
    yield dict(defines)
    names = sorted(op.sweep)
    for combo in itertools.product(*(op.sweep[n] for n in names)):
        yield dict(defines, **dict(zip(names, combo)))


def lint_op(op, rng=None) -> dict:
    """Analyze one op across its example-shaped candidate sweep.

    Returns ``{"checked", "skipped", "findings"}`` where findings are unique
    dicts (code/spec/subject/message/severity). Invalid tilings (candidates
    ``op.tune`` would skip) count as skipped, not findings."""
    from repro.core import analyze_spec
    from repro.core.analyze import AnalysisError
    from repro.core.lang import defines_namespace

    rng = rng or np.random.RandomState(0)
    args, params = op.example(rng)
    _, _, params = op._resolve(params)
    run_args, defines, _ = op._prepare(tuple(args), params)

    builders = [(op.name, op.builder)] + _aux_builders(op.name)
    checked = skipped = 0
    findings: dict[tuple, dict] = {}

    def add(fs):
        for f in fs:
            key = (f.code, f.spec, f.subject, f.message)
            findings[key] = dict(code=f.code, spec=f.spec, subject=f.subject,
                                 severity=f.severity, message=f.message)

    for cand in _candidates(op, defines):
        D = defines_namespace(cand)
        for _label, builder in builders:
            try:
                spec = builder(D)
            except AnalysisError as e:
                add(e.findings)
                continue
            except (ValueError, AssertionError):
                skipped += 1  # invalid tiling for these shapes: tune skips it
                continue
            report = analyze_spec(spec, D)
            add(report.findings)
            checked += 1

    return {"checked": checked, "skipped": skipped,
            "findings": list(findings.values())}


def _cost_dict(rep) -> dict:
    return dict(
        spec=rep.spec, grid=list(rep.grid), cells=rep.cells,
        vmem_bytes=rep.vmem_bytes, vmem_budget=rep.vmem_budget,
        vmem_frac=round(rep.vmem_frac, 4), bytes_in=rep.bytes_in,
        bytes_out=rep.bytes_out, hbm_bytes=rep.hbm_bytes, flops=rep.flops,
        intensity=(None if rep.intensity is None
                   else round(rep.intensity, 4)),
        comm_bytes=rep.comm_bytes, comm_detail=dict(rep.comm_detail),
        findings=[dict(code=f.code, spec=f.spec, subject=f.subject,
                       severity=f.severity, message=f.message)
                  for f in rep.findings])


def cost_op(op, rng=None) -> dict:
    """Static cost model over one op's default (derived) config: a
    bytes/FLOPs/footprint report per kernel the family builds, plus a
    preview of which autotune sweep candidates the model would prune."""
    from repro.core import estimate_cost, prune_candidates
    from repro.core.lang import defines_namespace

    rng = rng or np.random.RandomState(0)
    args, params = op.example(rng)
    _, _, params = op._resolve(params)
    _, defines, _ = op._prepare(tuple(args), params)

    kernels = []
    for label, builder in [(op.name, op.builder)] + _aux_builders(op.name):
        try:
            spec = builder(defines_namespace(defines))
        except (ValueError, AssertionError):
            continue  # default config not buildable for an aux kernel
        kernels.append(dict(_cost_dict(
            estimate_cost(spec, defines_namespace(defines))), kernel=label))

    kept, pruned = prune_candidates(op.builder, defines, dict(op.sweep))
    return {"kernels": kernels, "sweep_kept": len(kept),
            "sweep_pruned": [
                {"overrides": {k: c[k] for k in sorted(op.sweep)},
                 "reason": r} for c, r in pruned]}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--op", default=None,
                    help="lint ONE op (default: the whole registry)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on ANY finding, coverage warnings included")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write machine-readable findings to PATH")
    ap.add_argument("--cost", action="store_true",
                    help="also run the static cost model: per-op "
                         "bytes/FLOPs/footprint table + sweep prune preview; "
                         "its findings join the verdict")
    ap.add_argument("--cost-json", default=None, metavar="PATH",
                    help="write the cost table to PATH (implies --cost)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.cost_json:
        args.cost = True

    import repro.kernels  # noqa: F401 — registers the op families
    from repro.core import registered_ops

    ops = registered_ops()
    if args.op is not None:
        if args.op not in ops:
            ap.error(f"unknown op {args.op!r}; known: {sorted(ops)}")
        ops = {args.op: ops[args.op]}

    results = {}
    costs = {}
    for name in sorted(ops):
        results[name] = lint_op(ops[name], np.random.RandomState(args.seed))
        if args.cost:
            costs[name] = cost_op(ops[name], np.random.RandomState(args.seed))
            # cost findings on the DEFAULT config join the lint verdict
            seen = {(f["code"], f["spec"], f["subject"], f["message"])
                    for f in results[name]["findings"]}
            for k in costs[name]["kernels"]:
                for f in k["findings"]:
                    key = (f["code"], f["spec"], f["subject"], f["message"])
                    if key not in seen:
                        seen.add(key)
                        results[name]["findings"].append(f)

    n_err = sum(1 for r in results.values() for f in r["findings"]
                if f["severity"] == "error")
    n_all = sum(len(r["findings"]) for r in results.values())
    ok = (n_all == 0) if args.strict else (n_err == 0)

    w = max(len(n) for n in results) if results else 2
    print(f"{'op':<{w}}  {'checked':>7}  {'skipped':>7}  {'findings':>8}  verdict")
    for name, r in results.items():
        nf = len(r["findings"])
        bad = any(f["severity"] == "error" for f in r["findings"]) or \
            (args.strict and nf)
        verdict = "FAIL" if bad else ("WARN" if nf else "OK")
        print(f"{name:<{w}}  {r['checked']:>7}  {r['skipped']:>7}  "
              f"{nf:>8}  {verdict}")
    for name, r in results.items():
        for f in r["findings"]:
            print(f"  {name}: [{f['code']}] {f['message']}")

    if args.cost:
        print()
        kw = max((len(k["kernel"]) for c in costs.values()
                  for k in c["kernels"]), default=6)
        print(f"{'kernel':<{kw}}  {'vmem B':>10}  {'%bud':>5}  "
              f"{'hbm B':>12}  {'flops':>14}  {'flop/B':>7}  "
              f"{'comm B':>10}  pruned")
        for name, c in costs.items():
            for i, k in enumerate(c["kernels"]):
                fl = "?" if k["flops"] is None else f"{k['flops']:,}"
                ai = "?" if k["intensity"] is None else f"{k['intensity']:.2f}"
                cm = "-" if not k.get("comm_bytes") else f"{k['comm_bytes']:,}"
                npruned = (f"{len(c['sweep_pruned'])}/"
                           f"{len(c['sweep_pruned']) + c['sweep_kept']}"
                           if i == 0 else "")
                print(f"{k['kernel']:<{kw}}  {k['vmem_bytes']:>10,}  "
                      f"{k['vmem_frac']:>5.0%}  {k['hbm_bytes']:>12,}  "
                      f"{fl:>14}  {ai:>7}  {cm:>10}  {npruned}")
        for name, c in costs.items():
            for p in c["sweep_pruned"]:
                print(f"  {name}: {p['overrides']} -> {p['reason']}")

    if args.cost_json:
        payload = {"schema": 1, "ops": costs}
        d = os.path.dirname(args.cost_json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.cost_json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        print(f"[lint] wrote {args.cost_json}")

    if args.json:
        payload = {"schema": 1, "strict": bool(args.strict), "ok": ok,
                   "ops": results}
        if args.cost:
            payload["cost"] = costs
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        print(f"[lint] wrote {args.json}")

    print(f"[lint] {len(results)} ops, {n_all} findings "
          f"({n_err} errors){' — STRICT' if args.strict else ''}: "
          f"{'ok' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
