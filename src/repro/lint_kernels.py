"""Registry-wide kernel lint — run the static analyzer over every op.

Sweeps every registered ``define_op`` across its example shapes and its
declared autotune sweep (the same candidate space ``op.tune`` explores), and
analyzes every buildable candidate spec: grid invariants, scratch liveness,
output coverage, dimension-semantics consistency (see
:mod:`repro.core.analyze`). Ops whose families build extra kernels outside
the registry (flash-attention's delta/bwd, the LM head's fused-CE backward)
have those builders linted too, against the same defines.

  PYTHONPATH=src python -m repro.lint_kernels            # verdict table
  PYTHONPATH=src python -m repro.lint_kernels --strict   # any finding fails
  PYTHONPATH=src python -m repro.lint_kernels --json artifacts/analyze.json

Exit status: 0 when clean; 1 on any error-severity finding (any finding at
all under ``--strict`` — what the CI ``analyze`` stage runs).
"""

from __future__ import annotations

import argparse
import itertools
import json
import os

import numpy as np

__all__ = ["lint_op", "main"]


def _aux_builders(op_name: str) -> list:
    """Kernel builders a family builds directly (no registry entry of their
    own) — linted with the op's defines, which are a superset of theirs."""
    if op_name == "flash_attention":
        from repro.kernels.flash_attention.kernel import (
            flash_bwd_builder, flash_delta_builder)
        return [("flash_attention/delta", flash_delta_builder),
                ("flash_attention/bwd", flash_bwd_builder)]
    if op_name == "lm_head_ce":
        from repro.kernels.lm_head.kernel import lm_head_bwd_builder
        return [("lm_head_ce/bwd", lm_head_bwd_builder)]
    return []


def _candidates(op, defines: dict):
    """The derived defines first, then every autotune sweep combination over
    them — the exact candidate space a tuning run would build."""
    yield dict(defines)
    names = sorted(op.sweep)
    for combo in itertools.product(*(op.sweep[n] for n in names)):
        yield dict(defines, **dict(zip(names, combo)))


def lint_op(op, rng=None) -> dict:
    """Analyze one op across its example-shaped candidate sweep.

    Returns ``{"checked", "skipped", "findings"}`` where findings are unique
    dicts (code/spec/subject/message/severity). Invalid tilings (candidates
    ``op.tune`` would skip) count as skipped, not findings."""
    from repro.core import analyze_spec
    from repro.core.analyze import AnalysisError
    from repro.core.lang import defines_namespace

    rng = rng or np.random.RandomState(0)
    args, params = op.example(rng)
    _, _, params = op._resolve(params)
    run_args, defines, _ = op._prepare(tuple(args), params)

    builders = [(op.name, op.builder)] + _aux_builders(op.name)
    checked = skipped = 0
    findings: dict[tuple, dict] = {}

    def add(fs):
        for f in fs:
            key = (f.code, f.spec, f.subject, f.message)
            findings[key] = dict(code=f.code, spec=f.spec, subject=f.subject,
                                 severity=f.severity, message=f.message)

    for cand in _candidates(op, defines):
        D = defines_namespace(cand)
        for _label, builder in builders:
            try:
                spec = builder(D)
            except AnalysisError as e:
                add(e.findings)
                continue
            except (ValueError, AssertionError):
                skipped += 1  # invalid tiling for these shapes: tune skips it
                continue
            report = analyze_spec(spec, D)
            add(report.findings)
            checked += 1

    return {"checked": checked, "skipped": skipped,
            "findings": list(findings.values())}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--op", default=None,
                    help="lint ONE op (default: the whole registry)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on ANY finding, coverage warnings included")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write machine-readable findings to PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import repro.kernels  # noqa: F401 — registers the op families
    from repro.core import registered_ops

    ops = registered_ops()
    if args.op is not None:
        if args.op not in ops:
            ap.error(f"unknown op {args.op!r}; known: {sorted(ops)}")
        ops = {args.op: ops[args.op]}

    results = {}
    for name in sorted(ops):
        results[name] = lint_op(ops[name], np.random.RandomState(args.seed))

    n_err = sum(1 for r in results.values() for f in r["findings"]
                if f["severity"] == "error")
    n_all = sum(len(r["findings"]) for r in results.values())
    ok = (n_all == 0) if args.strict else (n_err == 0)

    w = max(len(n) for n in results) if results else 2
    print(f"{'op':<{w}}  {'checked':>7}  {'skipped':>7}  {'findings':>8}  verdict")
    for name, r in results.items():
        nf = len(r["findings"])
        bad = any(f["severity"] == "error" for f in r["findings"]) or \
            (args.strict and nf)
        verdict = "FAIL" if bad else ("WARN" if nf else "OK")
        print(f"{name:<{w}}  {r['checked']:>7}  {r['skipped']:>7}  "
              f"{nf:>8}  {verdict}")
    for name, r in results.items():
        for f in r["findings"]:
            print(f"  {name}: [{f['code']}] {f['message']}")

    if args.json:
        payload = {"schema": 1, "strict": bool(args.strict), "ok": ok,
                   "ops": results}
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        print(f"[lint] wrote {args.json}")

    print(f"[lint] {len(results)} ops, {n_all} findings "
          f"({n_err} errors){' — STRICT' if args.strict else ''}: "
          f"{'ok' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
