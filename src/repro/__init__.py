"""repro — OCCA (2014) rebuilt as a production JAX/TPU framework.

Layers:
  repro.core      the paper: unified kernel language + define_op host API +
                  persistent autotuner (op registry in repro.core.op)
  repro.apps      paper §4 numerical methods (FD / SEM / DG-SWE)
  repro.kernels   define_op declarations over the unified language (matmul,
                  rmsnorm, ssm_scan, flash attention fwd) + bespoke Pallas
                  bwd/decode kernels
  repro.layers    attention/MLP/MoE/mamba blocks
  repro.models    unified LM over the assigned architecture pool
  repro.configs   architecture configs + input-shape grid
  repro.parallel  sharding rules, step builders, pipeline parallelism
  repro.data/optim/checkpoint/runtime   training substrate
  repro.launch    mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
