"""The unified kernel language (paper §3, adapted for TPU).

One kernel source — a ``body(ctx, *tiles)`` function over VMEM-sized tiles
plus a :class:`Spec` describing its grid/block structure — expands to three
backends, mirroring the paper's macro expansion to OpenMP/OpenCL/CUDA:

  ``loops``   serial ``lax.fori_loop`` over the grid   (the OpenMP expansion)
  ``jnp``     whole-grid vectorized expansion          (portable reference / oracle)
  ``pallas``  ``pl.pallas_call`` + BlockSpec           (the TPU/"CUDA" expansion)

Keyword mapping (paper appendix tables → this module):

  occaOuterFor / occaOuterId   grid / ``ctx.outer_id(d)``
  occaInnerFor / occaInnerId   vector lanes of the tile / ``ctx.lane_ids(n)``
  occaShared (+ manual cache)  ``ctx.cache(ref)`` — tile load into VMEM
  occaBarrier(...)             ``ctx.barrier()`` — a no-op: a TPU block executes
                               as ONE sequenced program, which is exactly the
                               paper's OpenMP "inner loops run serially" model
  occaPrivate(Array)           ``ctx.private(x)`` — per-tile values (registers)
  occaCPU/occaGPU/occaOpenMP…  ``ctx.backend`` / ``ctx.is_pallas`` etc.
  occaKernelInfoArg            the ``ctx`` argument itself
  addDefine / buildKernel      ``Device.build_kernel(builder, defines=...)``

Restrictions (asserted): block shapes must divide the full array shape, and
every output block is visited exactly once (no grid-carried accumulation —
hand-written Pallas kernels in ``repro.kernels`` cover that pattern).
"""

from __future__ import annotations

import dataclasses
import math
from types import SimpleNamespace
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

__all__ = [
    "Tile",
    "Spec",
    "Ctx",
    "TileRef",
    "cdiv",
    "defines_namespace",
    "expand",
    "BACKENDS",
]

BACKENDS = ("jnp", "loops", "pallas")


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def defines_namespace(defines: dict | None) -> SimpleNamespace:
    return SimpleNamespace(**(defines or {}))


@dataclasses.dataclass(frozen=True)
class Tile:
    """One kernel argument: full array shape + its per-grid-cell block.

    ``block=None`` means the whole array is visible to every grid cell (the
    "global memory" view, e.g. for stencil halos). ``index`` maps grid ids to
    *block* indices (Pallas convention); ``None`` selects the canonical
    identity map (requires ``len(grid) == ndim``) or the constant-zero map for
    whole-array tiles.
    """

    name: str
    shape: tuple[int, ...]
    dtype: object
    block: tuple[int, ...] | None = None
    index: Callable[..., tuple] | None = None

    def resolved_block(self) -> tuple[int, ...]:
        blk = tuple(self.shape) if self.block is None else tuple(self.block)
        if len(blk) != len(self.shape):
            raise ValueError(
                f"tile {self.name!r}: block rank {len(blk)} != array rank {len(self.shape)}")
        for s, b in zip(self.shape, blk):
            if s % b != 0:
                raise ValueError(
                    f"tile {self.name!r}: block {blk} does not divide shape {self.shape}")
        return blk

    def resolved_index(self, grid: tuple[int, ...]) -> Callable[..., tuple]:
        if self.index is not None:
            return self.index
        blk = self.resolved_block()
        if blk == tuple(self.shape):  # whole-array tile
            ndim = len(self.shape)
            return lambda *gids: (0,) * ndim
        if len(grid) != len(self.shape):
            raise ValueError(
                f"tile {self.name!r}: no index map and grid rank {len(grid)} != "
                f"array rank {len(self.shape)}; pass index= explicitly")
        return lambda *gids: gids


@dataclasses.dataclass
class Spec:
    """A built kernel: grid + tiles + body. Produced by a builder(D) call."""

    name: str
    grid: tuple[int, ...]
    inputs: list[Tile]
    outputs: list[Tile]
    body: Callable

    def __post_init__(self):
        self.grid = tuple(int(g) for g in self.grid)
        if not self.grid:
            raise ValueError("grid must be non-empty")
        names = [t.name for t in self.inputs + self.outputs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tile names in kernel {self.name!r}")
        # Every output block must be visited exactly once.
        for t in self.outputs:
            blk = t.resolved_block()
            idx = t.resolved_index(self.grid)
            seen = set()
            for cell in np.ndindex(*self.grid):
                bi = tuple(int(i) for i in idx(*cell))
                if bi in seen:
                    raise ValueError(
                        f"output tile {t.name!r} block {bi} visited more than once; "
                        "grid-carried accumulation is not supported by the language "
                        "(write a hand-tiled kernel in repro.kernels instead)")
                seen.add(bi)
            nblocks = math.prod(s // b for s, b in zip(t.shape, blk))
            if len(seen) != nblocks:
                raise ValueError(
                    f"output tile {t.name!r}: {len(seen)} blocks visited but "
                    f"{nblocks} exist; kernel would leave garbage")


class TileRef:
    """Functional ref shim exposing the same read/write surface as a Pallas Ref."""

    __slots__ = ("_value",)

    def __init__(self, value):
        self._value = value

    def __getitem__(self, idx):
        return self._value[idx]

    def __setitem__(self, idx, val):
        if idx is Ellipsis or idx == slice(None):
            self._value = jnp.broadcast_to(val, self._value.shape).astype(self._value.dtype)
        else:
            self._value = self._value.at[idx].set(val)

    @property
    def value(self):
        return self._value

    @property
    def shape(self):
        return self._value.shape

    @property
    def dtype(self):
        return self._value.dtype


class Ctx:
    """occaKernelInfoArg analogue: grid ids/dims, defines, backend flags."""

    def __init__(self, backend: str, defines: SimpleNamespace,
                 gids: Sequence, grid: tuple[int, ...]):
        self.backend = backend
        self.D = defines
        self._gids = tuple(gids)
        self.grid = grid

    # --- occaOuterId / occaOuterDim ---------------------------------------
    def outer_id(self, d: int):
        return self._gids[d]

    def outer_dim(self, d: int) -> int:
        return self.grid[d]

    # --- occaInnerId: lanes of the vectorized tile ------------------------
    def lane_ids(self, n: int):
        return jnp.arange(n)

    # --- occaBarrier: no-op (sequential block execution; see module doc) --
    def barrier(self, *_fence):
        return None

    # --- occaShared manual caching: load a tile into VMEM ------------------
    def cache(self, ref):
        return ref[...]

    # --- occaPrivate ------------------------------------------------------
    def private(self, value):
        return value

    # --- occaCPU / occaGPU / occaOpenMP / occaOpenCL / occaCUDA ------------
    @property
    def is_pallas(self) -> bool:
        return self.backend == "pallas"

    @property
    def is_jnp(self) -> bool:
        return self.backend == "jnp"

    @property
    def is_loops(self) -> bool:
        return self.backend == "loops"


# ---------------------------------------------------------------------------
# Backend expansions
# ---------------------------------------------------------------------------

def _slice_tile(tile: Tile, arr, gids, grid):
    blk = tile.resolved_block()
    if blk == tuple(tile.shape):
        return TileRef(arr)  # whole-array view: no copy, no vmap blow-up
    bidx = tile.resolved_index(grid)(*gids)
    starts = [i * b for i, b in zip(bidx, blk)]
    return TileRef(lax.dynamic_slice(arr, starts, blk))


def _static_starts(tile: Tile, grid) -> np.ndarray:
    """Evaluate the index map for every grid cell at trace time."""
    blk = tile.resolved_block()
    idx = tile.resolved_index(grid)
    starts = [
        [int(i) * b for i, b in zip(idx(*cell), blk)]
        for cell in np.ndindex(*grid)
    ]
    return np.asarray(starts, dtype=np.int32)


def _is_canonical(tile: Tile, grid) -> bool:
    """True if the index map is the identity over the grid (fast reshape path)."""
    blk = tile.resolved_block()
    if len(grid) != len(tile.shape):
        return False
    if any(g * b != s for g, b, s in zip(grid, blk, tile.shape)):
        return False
    for cell in np.ndindex(*grid):
        if tuple(int(i) for i in tile.resolved_index(grid)(*cell)) != cell:
            return False
    return True


def _expand_jnp(spec: Spec, defines: SimpleNamespace):
    grid = spec.grid
    ncells = math.prod(grid)

    def fn(*in_arrays):
        def cell(flat_idx):
            gids = jnp.unravel_index(flat_idx, grid)
            ins = [_slice_tile(t, a, gids, grid) for t, a in zip(spec.inputs, in_arrays)]
            outs = [TileRef(jnp.zeros(t.resolved_block(), t.dtype)) for t in spec.outputs]
            ctx = Ctx("jnp", defines, gids, grid)
            spec.body(ctx, *ins, *outs)
            return tuple(o.value for o in outs)

        blocks = jax.vmap(cell)(jnp.arange(ncells))  # tuple of (ncells, *blk)
        results = []
        for t, stack in zip(spec.outputs, blocks):
            blk = t.resolved_block()
            if _is_canonical(t, grid):
                # (g0..gk, b0..bk) -> interleave -> full shape
                x = stack.reshape(grid + blk)
                perm = []
                for d in range(len(grid)):
                    perm += [d, len(grid) + d]
                x = x.transpose(perm)
                results.append(x.reshape(t.shape))
            else:
                starts = jnp.asarray(_static_starts(t, grid))
                out0 = jnp.zeros(t.shape, t.dtype)

                def write(j, acc, stack=stack, starts=starts):
                    st = [starts[j, k] for k in range(starts.shape[1])]
                    return lax.dynamic_update_slice(acc, stack[j], st)

                results.append(lax.fori_loop(0, ncells, write, out0))
        return tuple(results)

    return fn


def _expand_loops(spec: Spec, defines: SimpleNamespace):
    grid = spec.grid
    ncells = math.prod(grid)

    def fn(*in_arrays):
        outs0 = tuple(jnp.zeros(t.shape, t.dtype) for t in spec.outputs)

        def step(flat_idx, accs):
            gids = jnp.unravel_index(flat_idx, grid)
            ins = [_slice_tile(t, a, gids, grid) for t, a in zip(spec.inputs, in_arrays)]
            outs = [TileRef(jnp.zeros(t.resolved_block(), t.dtype)) for t in spec.outputs]
            ctx = Ctx("loops", defines, gids, grid)
            spec.body(ctx, *ins, *outs)
            new = []
            for t, o, acc in zip(spec.outputs, outs, accs):
                blk = t.resolved_block()
                bidx = t.resolved_index(grid)(*gids)
                starts = [i * b for i, b in zip(bidx, blk)]
                new.append(lax.dynamic_update_slice(acc, o.value, starts))
            return tuple(new)

        return lax.fori_loop(0, ncells, step, outs0)

    return fn


def _expand_pallas(spec: Spec, defines: SimpleNamespace, interpret: bool):
    grid = spec.grid

    def body_adapter(*refs):
        gids = tuple(pl.program_id(d) for d in range(len(grid)))
        ctx = Ctx("pallas", defines, gids, grid)
        spec.body(ctx, *refs)

    def mk_block(t: Tile):
        return pl.BlockSpec(t.resolved_block(), t.resolved_index(grid))

    call = pl.pallas_call(
        body_adapter,
        grid=grid,
        in_specs=[mk_block(t) for t in spec.inputs],
        out_specs=[mk_block(t) for t in spec.outputs],
        out_shape=[jax.ShapeDtypeStruct(t.shape, t.dtype) for t in spec.outputs],
        interpret=interpret,
    )

    def fn(*in_arrays):
        return tuple(call(*in_arrays))

    return fn


def expand(spec: Spec, defines: SimpleNamespace, backend: str, *, interpret: bool = True):
    """Expand one kernel Spec for a backend (the run-time 'macro expansion')."""
    if backend == "jnp":
        return _expand_jnp(spec, defines)
    if backend == "loops":
        return _expand_loops(spec, defines)
    if backend == "pallas":
        return _expand_pallas(spec, defines, interpret)
    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
