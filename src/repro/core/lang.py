"""The unified kernel language (paper §3, adapted for TPU).

One kernel source — a ``body(ctx, *tiles)`` function over VMEM-sized tiles
plus a :class:`Spec` describing its grid/block structure — expands to three
backends, mirroring the paper's macro expansion to OpenMP/OpenCL/CUDA:

  ``loops``   serial ``lax.fori_loop`` over the grid   (the OpenMP expansion)
  ``jnp``     whole-grid vectorized expansion          (portable reference / oracle)
  ``pallas``  ``pl.pallas_call`` + BlockSpec           (the TPU/"CUDA" expansion)

Keyword mapping (paper appendix tables → this module):

  occaOuterFor / occaOuterId   grid / ``ctx.outer_id(d)``
  occaInnerFor / occaInnerId   vector lanes of the tile / ``ctx.lane_ids(n)``
  occaShared (+ manual cache)  ``ctx.cache(ref)`` — tile load into VMEM
  occaShared (accumulators)    ``ctx.scratch`` — VMEM scratch declared via
                               ``Spec(scratch=[Scratch(shape, dtype)])``; the
                               refs persist across sequential reduce steps
  sequential inner loop        reduce axes — ``Spec(reduce_axes=...)`` marks
                               trailing grid axes as *sequential*: blocks
                               mapped to the same output index along those
                               axes are visited in order (occa's "outer loop
                               over work-groups, inner loop carrying state")
  occaBarrier(...)             ``ctx.barrier()`` — a no-op: a TPU block executes
                               as ONE sequenced program, which is exactly the
                               paper's OpenMP "inner loops run serially" model
  guarded occaOuterFor body    ``ctx.cell_when(pred)`` — masked/predicated grid
  (if(...) around the block)   cells: skip a whole block's work when ``pred``
                               (a function of grid ids + defines) is false.
                               Expands to ``pl.when`` on pallas and to
                               ``lax.cond`` over the tracked refs on jnp/loops
  loop scheduling pragmas      dimension_semantics — the pallas expansion marks
  (omp parallel for / CUDA     outer grid axes ``"parallel"`` and reduce axes
  blockIdx independence)       ``"arbitrary"`` so real-TPU grids pipeline
  streamed outputs             ``Tile(..., stream=True)`` — an *output* whose
  (writes inside the           index map may depend on reduce ids: every grid
  sequential inner loop)       cell writes its own block exactly once (e.g. the
                               per-chunk ``y`` of a chunked scan)
  per-output reduce            ``Tile(..., reduce=(axes,))`` — an *output* that
  granularity (outputs         accumulates over a SUBSET of the kernel's reduce
  accumulated at different     axes; its index map may depend on the remaining
  levels of the sequential     reduce axes (e.g. flash-bwd's fused pass: ``dq``
  loop nest)                   accumulates over k-blocks while ``dk``/``dv``
                               accumulate over q-blocks in ONE grid).
                               ``stream=True`` is sugar for ``reduce=()``;
                               the default (``reduce=None``) accumulates over
                               every reduce axis. Blocks keep their contents
                               across their accumulated visits — initialize
                               under ``ctx.reduce_first(d)`` and read-modify-
                               write (first-visit contents are undefined on a
                               real TPU, zero-filled on jnp/loops/interpret).
                               Real-TPU caveat: when an ACCUMULATED axis is
                               outer to a slot axis (flash-bwd's dk/dv), the
                               block's revisits are non-consecutive and rely
                               on the compiled pipeline writing back and
                               refetching the output window between them —
                               guaranteed on jnp/loops/interpret, flagged for
                               real-TPU validation in ROADMAP before compiled
                               use (consecutive revisits — the accumulated
                               axis innermost, as in dq or matmul — are the
                               long-validated safe pattern everywhere)
  dynamic input tiles          run-time data read by bodies and predicates
  (run-time kernel args /      without recompiling: a WHOLE-ARRAY input tile
  indirection arrays — the     (``block=None``) is visible to every grid cell
  unstructured-mesh pattern)   (flash-decode's ``(1, 1)`` ``kv_len`` scalar,
                               read by a ``cell_when`` block skip), while a
                               BLOCKED input tile streams per-cell data-
                               dependent state (flash-decode's ``(1, skv)``
                               ``slot_pos`` map — a rotated cache's
                               slot->position indirection, blocked along the
                               kv axis exactly like k/v). Input index maps
                               are bounds-checked over the whole grid at
                               build time; the jnp expansion hoists inputs
                               whose index map ignores the reduce ids out of
                               the sequential reduce loop (one slice per
                               outer cell instead of one per reduce step)
  tile-indexed index maps      ``Tile(index_tile=("table", axis))`` — the
  (indirection DRIVING the     block index along ``axis`` is READ AT RUN TIME
  fetch itself: vLLM's         from another i32 input tile (the "table") for
  PagedAttention block         the current grid cell, instead of computed by
  table)                       the static index map (whose value at ``axis``
                               is an ignored placeholder; return 0 there).
                               The table must be an integer input tile with
                               an all-ones block — its block index IS the
                               element it contributes — and the looked-up
                               value is clamped to the valid block range.
                               jnp/loops read the table element and
                               dynamic-slice; pallas lowers the table to a
                               scalar-prefetch operand
                               (``pltpu.PrefetchScalarGridSpec``) whose ref
                               the wrapped index maps read. The analyzer
                               bounds-checks the declaration (BOUNDS_TABLE)
                               and the cost model prices the gather as one
                               fetch per visiting cell (no consecutive-reuse
                               credit: the indices are dynamic)
  occaPrivate(Array)           ``ctx.private(x)`` — per-tile values (registers)
  occaCPU/occaGPU/occaOpenMP…  ``ctx.backend`` / ``ctx.is_pallas`` etc.
  occaKernelInfoArg            the ``ctx`` argument itself
  addDefine / buildKernel      ``Device.build_kernel(builder, defines=...)``

Reduction protocol (mirrors ``kernels/flash_attention``'s hand-rolled m/l/acc
pattern): reduce axes must be the *trailing* grid axes (innermost = sequential
on TPU). Scratch contents are undefined before the first reduce step — bodies
initialize under ``ctx.when(ctx.is_first)``, accumulate every step, and flush
outputs under ``ctx.when(ctx.is_last)`` (unconditional output writes are also
fine: the last visit wins on every backend). Output refs keep their contents
across the reduce visits of a block, so scratch-free accumulation directly
into an output block works too — but like scratch, an output block's
first-visit contents are undefined on a real TPU (zero-filled only on the
jnp/loops/interpret expansions), so read-modify-write bodies must initialize
the block under ``ctx.when(ctx.is_first)`` as well.

Restrictions (asserted): block shapes must divide the full array shape; an
output's index map must not depend on the reduce axes it ACCUMULATES over
(all of them by default; the declared subset with ``Tile(reduce=...)``; none
with ``stream=True``) — it may depend on the rest; and distinct
(outer x non-accumulated-reduce) cells must write distinct blocks, covering
every block exactly once (exactly once overall when the kernel has no reduce
axes).
"""

from __future__ import annotations

import dataclasses
import math
from types import SimpleNamespace
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "Tile",
    "Scratch",
    "ShardAxis",
    "Spec",
    "Ctx",
    "TileRef",
    "cdiv",
    "defines_namespace",
    "expand",
    "BACKENDS",
]

BACKENDS = ("jnp", "loops", "pallas")


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def defines_namespace(defines: dict | None) -> SimpleNamespace:
    return SimpleNamespace(**(defines or {}))


@dataclasses.dataclass(frozen=True)
class Tile:
    """One kernel argument: full array shape + its per-grid-cell block.

    ``block=None`` means the whole array is visible to every grid cell (the
    "global memory" view). ``index`` maps grid ids to *block* indices (Pallas
    convention); ``None`` selects the canonical identity map (requires
    ``len(grid) == ndim``) or the constant-zero map for whole-array tiles.

    ``halo=(r0, r1, ...)`` (INPUT tiles only, requires ``block=``) fetches
    each block with a per-axis halo: the body sees a
    ``(b0 + 2*r0, b1 + 2*r1, ...)`` window centered on the block, with the
    out-of-block fringe taken periodically (``wrap=True``, the default) or
    edge-clamped (``wrap=False``) — the stencil pattern, without caching the
    whole field per grid cell. The index map is unchanged (it still returns
    un-haloed block indices); interior element ``(i, j)`` of the block lives
    at ``window[r0 + i, r1 + j]``.
    """

    name: str
    shape: tuple[int, ...]
    dtype: object
    block: tuple[int, ...] | None = None
    index: Callable[..., tuple] | None = None
    # Output tiles only: a *streamed* output's index map may depend on reduce
    # ids — each grid cell (outer x reduce) writes a distinct block exactly
    # once, instead of accumulating into one block across the reduce space.
    stream: bool = False
    # Output tiles only: the subset of the Spec's reduce axes (grid-axis
    # numbers) this output ACCUMULATES over. None (default) = all reduce
    # axes; () = none (same as stream=True). The index map may depend on the
    # reduce axes NOT in this set — per-output reduce granularity.
    reduce: tuple[int, ...] | None = None
    # Input tiles only: per-axis halo radii; the fetched window is the block
    # plus r elements on each side along every axis (see class docstring).
    halo: tuple[int, ...] | None = None
    # Halo boundary rule: periodic wrap (True) or edge clamp (False).
    wrap: bool = True
    # Input tiles only: ("table", axis) — the block index along ``axis`` is
    # read at run time from the named i32 input tile's element for the
    # current grid cell (the PagedAttention block-table idiom). The static
    # index map's value at ``axis`` is an ignored placeholder; the table
    # tile must have an all-ones block. The looked-up index is clamped to
    # the block grid. Validated by the analyzer (BOUNDS_TABLE).
    index_tile: tuple[str, int] | None = None

    def resolved_block(self) -> tuple[int, ...]:
        blk = tuple(self.shape) if self.block is None else tuple(self.block)
        if len(blk) != len(self.shape):
            raise ValueError(
                f"tile {self.name!r}: block rank {len(blk)} != array rank {len(self.shape)}")
        for s, b in zip(self.shape, blk):
            if s % b != 0:
                raise ValueError(
                    f"tile {self.name!r}: block {blk} does not divide shape {self.shape}")
        return blk

    def resolved_halo(self) -> tuple[int, ...]:
        """Validated per-axis halo radii ((0,)*ndim when no halo)."""
        if self.halo is None:
            return (0,) * len(self.shape)
        halo = tuple(int(r) for r in self.halo)
        if len(halo) != len(self.shape):
            raise ValueError(
                f"tile {self.name!r}: halo rank {len(halo)} != array rank "
                f"{len(self.shape)}")
        if any(r < 0 for r in halo):
            raise ValueError(f"tile {self.name!r}: negative halo radius {halo}")
        if self.block is None and any(halo):
            raise ValueError(
                f"tile {self.name!r}: halo= requires a blocked tile (block=); "
                "a whole-array tile already sees every element")
        return halo

    def body_block(self) -> tuple[int, ...]:
        """The block shape the BODY sees: the resolved block grown by the
        halo fringe (identical to ``resolved_block()`` for halo-free tiles).
        This is also the per-cell VMEM-resident shape the cost model prices."""
        return tuple(b + 2 * r
                     for b, r in zip(self.resolved_block(),
                                     self.resolved_halo()))

    def resolved_index(self, grid: tuple[int, ...]) -> Callable[..., tuple]:
        if self.index is not None:
            return self.index
        blk = self.resolved_block()
        if blk == tuple(self.shape):  # whole-array tile
            ndim = len(self.shape)
            return lambda *gids: (0,) * ndim
        if len(grid) != len(self.shape):
            raise ValueError(
                f"tile {self.name!r}: no index map and grid rank {len(grid)} != "
                f"array rank {len(self.shape)}; pass index= explicitly")
        return lambda *gids: gids


@dataclasses.dataclass(frozen=True)
class Scratch:
    """A VMEM scratch buffer (occaShared accumulator analogue).

    Scratch refs are handed to the body via ``ctx.scratch`` and persist across
    the sequential visits of a reduce iteration-space (Pallas: real
    ``pltpu.VMEM`` scratch; jnp/loops: carried accumulators)."""

    shape: tuple[int, ...]
    dtype: object = jnp.float32

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))


SHARD_COLLECTIVES = (None, "ppermute", "psum", "psum_scatter")


@dataclasses.dataclass(frozen=True)
class ShardAxis:
    """A grid reduce axis that lives ACROSS devices on a named mesh axis.

    The spec's grid stays the per-shard (local) grid; ``extent`` says how many
    shards the bound reduce axis spans, and ``collective`` declares how the
    per-shard partials meet:

      ``"ppermute"``      ring schedule — the ``rotate`` input tiles hop to the
                          next shard after each ring step (ring attention's
                          k/v), so every shard eventually reduces over the full
                          axis. Outputs that do NOT accumulate over the bound
                          axis (it is one of their slot axes) write per-chunk
                          blocks owned by a *different* shard each step and
                          must be declared in ``sharded_outputs`` (their
                          cotangents/partials ride the ring home).
      ``"psum"``          every shard reduces its local slice, partials meet in
                          an all-reduce (the sharded-matmul pattern).
      ``"psum_scatter"``  as psum, but each shard keeps only its slice of the
                          result.
      ``None``            declared distribution with no collective — only legal
                          when nothing crosses shards (the analyzer rejects
                          accumulating outputs with COLLECTIVE_UNDECLARED).

    Structural validation happens in ``Spec.__post_init__``; the semantic
    cross-shard checks (write races over the mesh-extended grid, undeclared
    collectives) live in ``core.analyze.check_shard_binding`` and fail the
    build with stable finding codes (RACE_MESH_WRITE, COLLECTIVE_UNDECLARED).
    """

    mesh_axis: str
    axis: int
    extent: int = 1
    collective: str | None = "ppermute"
    rotate: tuple[str, ...] = ()
    sharded_outputs: tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "axis", int(self.axis))
        object.__setattr__(self, "extent", int(self.extent))
        object.__setattr__(self, "rotate", tuple(self.rotate))
        object.__setattr__(self, "sharded_outputs",
                           tuple(self.sharded_outputs))
        if not self.mesh_axis or not isinstance(self.mesh_axis, str):
            raise ValueError("ShardAxis.mesh_axis must be a mesh axis name")
        if self.extent < 1:
            raise ValueError(f"ShardAxis.extent must be >= 1, got {self.extent}")
        if self.collective not in SHARD_COLLECTIVES:
            raise ValueError(
                f"ShardAxis.collective {self.collective!r} unknown "
                f"(one of {SHARD_COLLECTIVES})")


@dataclasses.dataclass
class Spec:
    """A built kernel: grid + tiles + body. Produced by a builder(D) call.

    ``reduce_axes`` marks trailing grid axes as sequential reduction axes;
    ``scratch`` declares VMEM accumulators that persist across the reduce
    steps (see module docstring for the protocol)."""

    name: str
    grid: tuple[int, ...]
    inputs: list[Tile]
    outputs: list[Tile]
    body: Callable
    reduce_axes: tuple[int, ...] = ()
    scratch: list[Scratch] = dataclasses.field(default_factory=list)
    # Per-axis pallas pipelining override ("parallel" | "arbitrary" per grid
    # axis). None derives the safe default: outer axes parallel, reduce axes
    # arbitrary. The analyzer rejects a "parallel" reduce axis that carries
    # scratch or an output accumulation (SEMANTICS_PARALLEL_CARRIED).
    dimension_semantics: tuple[str, ...] | None = None
    # Declared mesh binding: one reduce axis distributed across devices with
    # a named collective (see ShardAxis). The grid stays per-shard; the
    # analyzer extends its race/coverage/cost reasoning over
    # extent-many shards when the binding is active (extent > 1).
    shard: ShardAxis | None = None

    def __post_init__(self):
        self.grid = tuple(int(g) for g in self.grid)
        if not self.grid:
            raise ValueError("grid must be non-empty")
        names = [t.name for t in self.inputs + self.outputs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tile names in kernel {self.name!r}")

        self.reduce_axes = tuple(sorted(int(a) for a in self.reduce_axes))
        if len(set(self.reduce_axes)) != len(self.reduce_axes):
            raise ValueError(f"duplicate reduce axes {self.reduce_axes}")
        k = len(self.grid) - len(self.reduce_axes)
        if self.reduce_axes and self.reduce_axes != tuple(range(k, len(self.grid))):
            raise ValueError(
                f"reduce_axes {self.reduce_axes} must be the trailing grid axes "
                f"(grid rank {len(self.grid)}): sequential axes are innermost on TPU")
        self.scratch = list(self.scratch)
        for s in self.scratch:
            if not isinstance(s, Scratch):
                raise TypeError(f"scratch entries must be lang.Scratch, got {type(s)}")

        if self.dimension_semantics is not None:
            sem = tuple(self.dimension_semantics)
            if len(sem) != len(self.grid):
                raise ValueError(
                    f"dimension_semantics has {len(sem)} entries for a rank-"
                    f"{len(self.grid)} grid")
            bad = [s for s in sem if s not in ("parallel", "arbitrary")]
            if bad:
                raise ValueError(
                    f"dimension_semantics entries must be 'parallel' or "
                    f"'arbitrary', got {bad}")
            self.dimension_semantics = sem

        for t in self.inputs:
            # stream=/reduce= are OUTPUT declarations (accumulation contracts);
            # on an input they would be silently ignored — reject at build
            # time so a mis-declared kernel fails loudly (surfaced by the
            # first op whose outputs span several reduce granularities)
            if t.stream or t.reduce is not None:
                raise ValueError(
                    f"input tile {t.name!r}: stream=/reduce= are output-only "
                    "declarations (inputs are read at every visit)")
            t.resolved_halo()  # structural halo validation (rank/sign/block)

        for t in self.outputs:
            # a halo is a FETCH pattern; overlapping output windows would race
            if t.halo is not None and any(int(r) for r in t.halo):
                raise ValueError(
                    f"output tile {t.name!r}: halo= is input-only "
                    "(overlapping output windows would write racily)")

        if self.shard is not None:
            # Structural shard-binding checks; the semantic cross-shard pass
            # (races / undeclared collectives over the mesh-extended grid)
            # runs in check_grid_invariants below.
            sh = self.shard
            if not isinstance(sh, ShardAxis):
                raise TypeError(
                    f"Spec.shard must be a lang.ShardAxis, got {type(sh)}")
            if sh.axis not in self.reduce_axes:
                raise ValueError(
                    f"kernel {self.name!r}: shard axis {sh.axis} is not a "
                    f"reduce axis {self.reduce_axes} — only sequential "
                    "(reduce) grid axes can be distributed across the mesh")
            in_names = {t.name for t in self.inputs}
            out_names = {t.name for t in self.outputs}
            unknown = set(sh.rotate) - in_names
            if unknown:
                raise ValueError(
                    f"kernel {self.name!r}: ShardAxis.rotate names unknown "
                    f"input tiles {sorted(unknown)}")
            unknown = set(sh.sharded_outputs) - out_names
            if unknown:
                raise ValueError(
                    f"kernel {self.name!r}: ShardAxis.sharded_outputs names "
                    f"unknown output tiles {sorted(unknown)}")

        # Concrete-grid invariants — non-dividing blocks, out-of-range index
        # maps (inputs AND outputs), parallel-cell write races, accumulated-
        # axis index dependence, unwritten blocks — are enforced at build
        # time: autotune relies on invalid candidates failing inside
        # build_kernel, not at the first (jitted) run. The enumeration lives
        # in core.analyze (the static analyzer's grid pass); it also computes
        # which inputs' block index ignores the reduce ids, so the jnp
        # expansion can hoist those slices out of the sequential reduce loop
        # (e.g. flash-decode's q tile is sliced once per (b, h) cell, not
        # once per kv block).
        from .analyze import AnalysisError, check_grid_invariants

        findings, self._input_reduce_invariant = check_grid_invariants(self)
        if findings:
            raise AnalysisError(findings)

    # -- grid split helpers --------------------------------------------------
    @property
    def outer_grid(self) -> tuple[int, ...]:
        return self.grid[: len(self.grid) - len(self.reduce_axes)]

    @property
    def reduce_grid(self) -> tuple[int, ...]:
        return tuple(self.grid[a] for a in self.reduce_axes)

    def resolved_semantics(self) -> tuple[str, ...]:
        """Per-axis ``dimension_semantics``: the declared tuple, else the
        default — outer axes are embarrassingly parallel (each output block
        is written from exactly one outer cell), reduce axes carry scratch
        state and must stay sequential ("arbitrary")."""
        if self.dimension_semantics is not None:
            return tuple(self.dimension_semantics)
        n_par = len(self.grid) - len(self.reduce_axes)
        return ("parallel",) * n_par + ("arbitrary",) * len(self.reduce_axes)

    def output_reduce_axes(self, t: Tile) -> tuple[int, ...]:
        """The reduce axes this output ACCUMULATES over (sorted grid axes)."""
        if t.reduce is not None:
            r = tuple(sorted(int(a) for a in t.reduce))
            if len(set(r)) != len(r):
                raise ValueError(
                    f"output tile {t.name!r}: duplicate axes in reduce={r}")
            if t.stream and r:
                raise ValueError(
                    f"output tile {t.name!r}: stream=True means reduce=(), "
                    f"got reduce={r}")
            if not set(r) <= set(self.reduce_axes):
                raise ValueError(
                    f"output tile {t.name!r}: reduce={r} is not a subset of "
                    f"the kernel's reduce axes {self.reduce_axes}")
            return r
        return () if t.stream else self.reduce_axes

    def output_slot_axes(self, t: Tile) -> tuple[int, ...]:
        """Reduce axes the output's index map may depend on — they select
        which of the output's blocks ("slot") a reduce step writes."""
        acc = set(self.output_reduce_axes(t))
        return tuple(a for a in self.reduce_axes if a not in acc)

    def slot_index(self, t: Tile) -> Callable[..., tuple]:
        """Output index map over (outer + slot-axis) cells — the accumulated
        reduce ids are pinned to 0 (the map does not depend on them)."""
        full = t.resolved_index(self.grid)
        acc = set(self.output_reduce_axes(t))
        k = len(self.outer_grid)

        def f(*cells):
            og, sg = cells[:k], iter(cells[k:])
            rids = tuple(0 if a in acc else next(sg) for a in self.reduce_axes)
            return full(*og, *rids)

        return f


class TileRef:
    """Functional ref shim exposing the same read/write surface as a Pallas Ref."""

    __slots__ = ("_value",)

    def __init__(self, value):
        self._value = value

    def __getitem__(self, idx):
        return self._value[idx]

    def __setitem__(self, idx, val):
        if idx is Ellipsis or idx == slice(None):
            self._value = jnp.broadcast_to(val, self._value.shape).astype(self._value.dtype)
        else:
            self._value = self._value.at[idx].set(val)

    @property
    def value(self):
        return self._value

    @property
    def shape(self):
        return self._value.shape

    @property
    def dtype(self):
        return self._value.dtype


class Ctx:
    """occaKernelInfoArg analogue: grid ids/dims, defines, backend flags,
    reduce position and scratch refs."""

    def __init__(self, backend: str, defines: SimpleNamespace,
                 gids: Sequence, grid: tuple[int, ...], *,
                 reduce_axes: tuple[int, ...] = (), scratch: Sequence = (),
                 refs: Sequence = ()):
        self.backend = backend
        self.D = defines
        self._gids = tuple(gids)
        self.grid = grid
        self._reduce_axes = tuple(reduce_axes)
        self.scratch = tuple(scratch)
        self._refs = tuple(refs)

    # --- occaOuterId / occaOuterDim ---------------------------------------
    def outer_id(self, d: int):
        return self._gids[d]

    def outer_dim(self, d: int) -> int:
        return self.grid[d]

    # --- reduce (sequential) axes -----------------------------------------
    def reduce_id(self, d: int = 0):
        """Position along the d-th reduce axis (0 .. reduce_dim(d) - 1)."""
        return self._gids[self._reduce_axes[d]]

    def reduce_dim(self, d: int = 0) -> int:
        return self.grid[self._reduce_axes[d]]

    def reduce_first(self, d: int = 0):
        """True on the first step along the d-th reduce axis — the init point
        for state accumulated over THAT axis only (e.g. a ``Tile(reduce=...)``
        output or a scratch reset per outer sweep of a 2-deep reduce nest)."""
        return self._gids[self._reduce_axes[d]] == 0

    def reduce_last(self, d: int = 0):
        """True on the last step along the d-th reduce axis (flush point)."""
        a = self._reduce_axes[d]
        return self._gids[a] == self.grid[a] - 1

    @property
    def is_first(self):
        """True on the first visit of the reduce iteration-space (init point).

        A plain ``True`` for kernels without reduce axes; a traced scalar
        bool otherwise."""
        ids = [self._gids[a] for a in self._reduce_axes]
        if not ids:
            return True
        pred = ids[0] == 0
        for i in ids[1:]:
            pred = pred & (i == 0)
        return pred

    @property
    def is_last(self):
        """True on the last visit of the reduce iteration-space (flush point)."""
        if not self._reduce_axes:
            return True
        pred = None
        for a in self._reduce_axes:
            p = self._gids[a] == self.grid[a] - 1
            pred = p if pred is None else pred & p
        return pred

    def when(self, pred):
        """Run the decorated thunk only when ``pred`` holds (pl.when analogue).

        Under pallas this is ``pl.when``; under jnp/loops the thunk runs
        unconditionally and every tracked ref write is select-masked on
        ``pred`` — semantically identical, fully functional."""
        def deco(fn):
            if isinstance(pred, (bool, np.bool_)):
                if pred:
                    fn()
                return fn
            if self.backend == "pallas":
                pl.when(pred)(fn)
                return fn
            before = [r._value for r in self._refs]
            fn()
            for r, old in zip(self._refs, before):
                r._value = jnp.where(pred, r._value, old)
            return fn
        return deco

    def cell_when(self, pred):
        """Masked grid cell: run the thunk only when ``pred`` holds, skipping
        the WHOLE block's work otherwise (flash-attention's causal block skip).

        ``pred`` must be a scalar bool of grid ids, defines and values already
        loaded from input tiles (e.g. flash-decode's dynamic kv length) —
        never of output/scratch contents. Under pallas
        this is ``pl.when`` (no MXU work issued for skipped cells); under
        jnp/loops the thunk becomes one branch of a ``lax.cond`` over the
        tracked refs (a real skip on the loops expansion; a select under the
        jnp vmap, which is semantically identical)."""
        def deco(fn):
            if isinstance(pred, (bool, np.bool_)):
                if pred:
                    fn()
                return fn
            if self.backend == "pallas":
                pl.when(pred)(fn)
                return fn
            before = tuple(r._value for r in self._refs)

            def _taken(vals):
                for r, v in zip(self._refs, vals):
                    r._value = v
                fn()
                return tuple(r._value for r in self._refs)

            after = lax.cond(pred, _taken, lambda vals: vals, before)
            for r, v in zip(self._refs, after):
                r._value = v
            return fn
        return deco

    # --- occaInnerId: lanes of the vectorized tile ------------------------
    def lane_ids(self, n: int):
        return jnp.arange(n)

    # --- occaBarrier: no-op (sequential block execution; see module doc) --
    def barrier(self, *_fence):
        return None

    # --- occaShared manual caching: load a tile into VMEM ------------------
    def cache(self, ref):
        return ref[...]

    # --- occaPrivate ------------------------------------------------------
    def private(self, value):
        return value

    # --- occaCPU / occaGPU / occaOpenMP / occaOpenCL / occaCUDA ------------
    @property
    def is_pallas(self) -> bool:
        return self.backend == "pallas"

    @property
    def is_jnp(self) -> bool:
        return self.backend == "jnp"

    @property
    def is_loops(self) -> bool:
        return self.backend == "loops"


# ---------------------------------------------------------------------------
# Halo lowering
# ---------------------------------------------------------------------------
#
# A halo tile is lowered to a REGULAR blocked tile over a windowed layout
# before any backend sees it: per block index ``i`` along a haloed axis, the
# window ``[i*b - r, (i+1)*b + r)`` (periodic or edge-clamped) is materialized
# contiguously, so block ``i`` of the lowered array IS the haloed window and
# every backend — including Pallas, whose BlockSpec cannot express
# overlapping fetches — runs the exact same non-overlapping blocked machinery.
# The gather is one static-index ``jnp.take`` per haloed axis on the host
# side of the call; its cost is the halo amplification ``(b + 2r) / b`` the
# static cost model charges for the tile.

def _halo_axis_index(nblocks: int, b: int, r: int, s: int, wrap: bool):
    """Static source indices for one haloed axis's windowed layout."""
    offs = np.arange(-r, b + r)
    idx = (np.arange(nblocks)[:, None] * b + offs[None, :]).reshape(-1)
    return idx % s if wrap else np.clip(idx, 0, s - 1)


def _lower_halo_tile(tile: Tile) -> tuple[Tile, Callable]:
    blk = tile.resolved_block()
    halo = tile.resolved_halo()
    nb = tuple(s // b for s, b in zip(tile.shape, blk))
    wblk = tile.body_block()
    wshape = tuple(n * w for n, w in zip(nb, wblk))
    takes = [(d, jnp.asarray(_halo_axis_index(n, b, r, s, tile.wrap),
                             dtype=jnp.int32))
             for d, (n, b, r, s) in enumerate(zip(nb, blk, halo, tile.shape))
             if r]

    def windowize(arr):
        for d, idx in takes:
            arr = jnp.take(arr, idx, axis=d)
        return arr

    lowered = dataclasses.replace(
        tile, shape=wshape, block=wblk, halo=None)
    return lowered, windowize


def _lower_halos(spec: Spec) -> tuple[Spec, list | None]:
    """(lowered spec, per-input window fns) — (spec, None) when halo-free."""
    if not any(t.halo is not None and any(t.resolved_halo())
               for t in spec.inputs):
        return spec, None
    preps, inputs = [], []
    for t in spec.inputs:
        if t.halo is not None and any(t.resolved_halo()):
            lowered, prep = _lower_halo_tile(t)
        else:
            lowered, prep = t, None
        inputs.append(lowered)
        preps.append(prep)
    lowered = dataclasses.replace(spec, inputs=inputs)
    return lowered, preps


# ---------------------------------------------------------------------------
# Backend expansions
# ---------------------------------------------------------------------------

def _slice_tile(tile: Tile, arr, gids, grid, tables=None):
    blk = tile.resolved_block()
    if tile.index_tile is None and blk == tuple(tile.shape):
        return TileRef(arr)  # whole-array view: no copy, no vmap blow-up
    bidx = list(tile.resolved_index(grid)(*gids))
    if tile.index_tile is not None:
        # the block index along the gathered axis comes from the table
        # tile's element for this cell (the static map's value there is a
        # placeholder); clamped so a corrupt table cannot read out of bounds
        tname, axis = tile.index_tile
        ttile, tarr = tables[tname]
        val = _slice_tile(ttile, tarr, gids, grid).value.reshape(-1)[0]
        nb = tile.shape[axis] // blk[axis]
        bidx[axis] = jnp.clip(val.astype(jnp.int32), 0, nb - 1)
    starts = [i * b for i, b in zip(bidx, blk)]
    return TileRef(lax.dynamic_slice(arr, starts, blk))


def _static_starts(tile: Tile, grid, index_fn) -> np.ndarray:
    """Evaluate an index map for every cell of ``grid`` at trace time."""
    blk = tile.resolved_block()
    starts = [
        [int(i) * b for i, b in zip(index_fn(*cell), blk)]
        for cell in np.ndindex(*grid)
    ]
    return np.asarray(starts, dtype=np.int32)


def _is_canonical(tile: Tile, grid, index_fn) -> bool:
    """True if ``index_fn`` is the identity over ``grid`` (fast reshape path)."""
    blk = tile.resolved_block()
    if len(grid) != len(tile.shape):
        return False
    if any(g * b != s for g, b, s in zip(grid, blk, tile.shape)):
        return False
    for cell in np.ndindex(*grid):
        if tuple(int(i) for i in index_fn(*cell)) != cell:
            return False
    return True


def _run_body(spec: Spec, backend: str, defines, gids, ins, out_vals, scr_vals):
    """One grid-cell body invocation on the functional (jnp/loops) backends.

    Returns the updated (output block values, scratch values)."""
    outs = [TileRef(v) for v in out_vals]
    scr = [TileRef(v) for v in scr_vals]
    ctx = Ctx(backend, defines, gids, spec.grid,
              reduce_axes=spec.reduce_axes, scratch=scr, refs=tuple(outs) + tuple(scr))
    spec.body(ctx, *ins, *outs)
    return tuple(o.value for o in outs), tuple(s.value for s in scr)


def _assemble_blocks(t: Tile, stack, grid_used, index_fn):
    """Scatter a (prod(grid_used), *blk) stack of blocks into the full array."""
    blk = t.resolved_block()
    ngrid = math.prod(grid_used) if grid_used else 1
    if _is_canonical(t, grid_used, index_fn):
        # (g0..gk, b0..bk) -> interleave -> full shape
        x = stack.reshape(tuple(grid_used) + blk)
        perm = []
        for d in range(len(grid_used)):
            perm += [d, len(grid_used) + d]
        x = x.transpose(perm)
        return x.reshape(t.shape)
    starts = jnp.asarray(_static_starts(t, grid_used, index_fn))
    out0 = jnp.zeros(t.shape, t.dtype)

    def write(j, acc):
        st = [starts[j, k] for k in range(starts.shape[1])]
        return lax.dynamic_update_slice(acc, stack[j], st)

    return lax.fori_loop(0, ngrid, write, out0)


def _expand_jnp(spec: Spec, defines: SimpleNamespace):
    grid = spec.grid
    outer_grid = spec.outer_grid
    red_grid = spec.reduce_grid
    nouter = math.prod(outer_grid) if outer_grid else 1
    nred = math.prod(red_grid) if red_grid else 1
    # Per-output slot structure: within one outer cell, an output owns one
    # block per combination of its slot axes (the reduce axes it does NOT
    # accumulate over). Full-accumulate outputs have 1 slot; streamed outputs
    # have nred. Blocks are carried as a (nslots, *blk) stack across the
    # sequential reduce loop — a visited slot keeps its contents, so partial-
    # reduce outputs read-modify-write their block exactly like the resident
    # Pallas block.
    slot_pos = []   # positions (within reduce_axes) of each output's slot axes
    slot_dims = []  # the grid extents of those axes
    for t in spec.outputs:
        axes = spec.output_slot_axes(t)
        slot_pos.append(tuple(spec.reduce_axes.index(a) for a in axes))
        slot_dims.append(tuple(spec.grid[a] for a in axes))

    # inputs whose block index ignores the reduce ids (statically probed at
    # Spec build): slice ONCE per outer cell, not once per reduce step
    hoistable = spec._input_reduce_invariant if red_grid else \
        [False] * len(spec.inputs)
    zero_r = (0,) * len(spec.reduce_axes)

    def fn(*in_arrays):
        tables = {t.name: (t, a) for t, a in zip(spec.inputs, in_arrays)}

        def cell(flat_idx):
            ogids = jnp.unravel_index(flat_idx, outer_grid) if outer_grid else ()
            pinned = [
                _slice_tile(t, a, tuple(ogids) + zero_r, grid, tables).value
                if h else None
                for t, a, h in zip(spec.inputs, in_arrays, hoistable)]
            stk0 = tuple(
                jnp.zeros((math.prod(sd) if sd else 1,) + t.resolved_block(),
                          t.dtype)
                for t, sd in zip(spec.outputs, slot_dims))
            scr0 = tuple(jnp.zeros(s.shape, s.dtype) for s in spec.scratch)

            def step(r, carry):
                stacks, scr_vals = carry
                rgids = jnp.unravel_index(r, red_grid) if red_grid else ()
                gids = tuple(ogids) + tuple(rgids)
                # hoisted inputs get a FRESH TileRef per step: input refs are
                # read-only by contract, but a stray in-body write must not
                # leak across reduce steps
                ins = [TileRef(p) if h else _slice_tile(t, a, gids, grid,
                                                        tables)
                       for t, a, h, p in zip(spec.inputs, in_arrays,
                                             hoistable, pinned)]
                slots, cur = [], []
                for t, stack, pos, sd in zip(spec.outputs, stacks, slot_pos,
                                             slot_dims):
                    s = 0
                    for p, dim in zip(pos, sd):
                        s = s * dim + rgids[p]
                    slots.append(s)
                    blk = t.resolved_block()
                    cur.append(lax.dynamic_slice(
                        stack, (s,) + (0,) * len(blk), (1,) + blk)[0])
                new_out, new_scr = _run_body(spec, "jnp", defines, gids, ins,
                                             tuple(cur), scr_vals)
                new_stacks = tuple(
                    lax.dynamic_update_slice(
                        stack, v[None], (s,) + (0,) * (stack.ndim - 1))
                    for stack, v, s in zip(stacks, new_out, slots))
                return new_stacks, new_scr

            if red_grid:
                stacks, _ = lax.fori_loop(0, nred, step, (stk0, scr0))
            else:
                stacks, _ = step(0, (stk0, scr0))
            return stacks

        blocks = jax.vmap(cell)(jnp.arange(nouter))  # tuple of (nouter, nslots, ...)
        results = []
        for t, stack, sd in zip(spec.outputs, blocks, slot_dims):
            blk = t.resolved_block()
            ns = math.prod(sd) if sd else 1
            # (nouter, nslots, *blk) -> flat C order over (outer + slot axes),
            # the same visit order as np.ndindex over that combined grid
            results.append(_assemble_blocks(
                t, stack.reshape((nouter * ns,) + blk),
                tuple(outer_grid) + sd, spec.slot_index(t)))
        return tuple(results)

    return fn


def _expand_single_cell(spec: Spec, defines: SimpleNamespace, backend: str):
    """Degenerate grid (one cell): run the body once, directly on the full
    arrays — no vmap, no fori_loop, no dynamic slicing. The jnp and loops
    expansions collapse to the same program here, and the removed machinery
    is pure overhead at exactly the shapes where it matters most (a block
    sized to the whole problem, the autotuner's frequent small-shape winner)."""
    grid = spec.grid
    gids = (0,) * len(grid)

    def fn(*in_arrays):
        tables = {t.name: (t, a) for t, a in zip(spec.inputs, in_arrays)}
        ins = [_slice_tile(t, a, gids, grid, tables)
               for t, a in zip(spec.inputs, in_arrays)]
        out0 = tuple(jnp.zeros(t.resolved_block(), t.dtype)
                     for t in spec.outputs)
        scr0 = tuple(jnp.zeros(s.shape, s.dtype) for s in spec.scratch)
        out_vals, _ = _run_body(spec, backend, defines, gids, ins, out0, scr0)
        results = []
        for t, v in zip(spec.outputs, out_vals):
            blk = t.resolved_block()
            if blk == tuple(t.shape):
                results.append(v)
            else:
                bidx = t.resolved_index(grid)(*gids)
                starts = [int(i) * b for i, b in zip(bidx, blk)]
                results.append(lax.dynamic_update_slice(
                    jnp.zeros(t.shape, t.dtype), v, starts))
        return tuple(results)

    return fn


def _expand_loops(spec: Spec, defines: SimpleNamespace):
    grid = spec.grid
    ncells = math.prod(grid)

    def fn(*in_arrays):
        tables = {t.name: (t, a) for t, a in zip(spec.inputs, in_arrays)}
        outs0 = tuple(jnp.zeros(t.shape, t.dtype) for t in spec.outputs)
        scr0 = tuple(jnp.zeros(s.shape, s.dtype) for s in spec.scratch)

        def step(flat_idx, carry):
            accs, scr_vals = carry
            # C-order unravel: trailing (reduce) axes iterate innermost, so
            # scratch carried across steps sees the reduce space sequentially
            # — the same visit order as the Pallas grid.
            gids = jnp.unravel_index(flat_idx, grid)
            ins = [_slice_tile(t, a, gids, grid, tables)
                   for t, a in zip(spec.inputs, in_arrays)]
            # With reduce axes, output refs see the block's CURRENT contents
            # (zeros on first visit): bodies that accumulate directly into an
            # output behave like the jnp carry / resident Pallas block.
            # Without them every block is visited once and the slice would
            # always read zeros — skip it.
            out_blk0, out_starts = [], []
            for t, acc in zip(spec.outputs, accs):
                blk = t.resolved_block()
                bidx = t.resolved_index(grid)(*gids)
                starts = [i * b for i, b in zip(bidx, blk)]
                out_starts.append(starts)
                if spec.reduce_axes:
                    out_blk0.append(lax.dynamic_slice(acc, starts, blk))
                else:
                    out_blk0.append(jnp.zeros(blk, t.dtype))
            out_vals, scr_vals = _run_body(spec, "loops", defines, gids, ins,
                                           tuple(out_blk0), scr_vals)
            new = [lax.dynamic_update_slice(acc, val, starts)
                   for val, acc, starts in zip(out_vals, accs, out_starts)]
            return tuple(new), scr_vals

        outs, _ = lax.fori_loop(0, ncells, step, (outs0, scr0))
        return outs

    return fn


def _expand_pallas(spec: Spec, defines: SimpleNamespace, interpret: bool):
    grid = spec.grid
    ng = len(grid)
    n_in, n_out = len(spec.inputs), len(spec.outputs)
    tiles = {t.name: t for t in spec.inputs}
    # index_tile tables, in first-use order (deduped): each is ALSO a regular
    # input (the body's view is backend-identical), but its array is
    # additionally prepended to the call as a scalar-prefetch operand whose
    # SMEM ref the wrapped index maps read (PrefetchScalarGridSpec appends
    # the scalar refs to every index map's grid ids).
    table_names: list[str] = []
    for t in spec.inputs:
        if t.index_tile is not None and t.index_tile[0] not in table_names:
            table_names.append(t.index_tile[0])
    n_tab = len(table_names)
    table_pos = [next(i for i, t in enumerate(spec.inputs) if t.name == nm)
                 for nm in table_names]

    def body_adapter(*refs):
        refs = refs[n_tab:]  # drop the scalar-prefetch refs: the tables
        gids = tuple(pl.program_id(d) for d in range(ng))  # arrive again as
        scr = refs[n_in + n_out:]                          # regular inputs
        ctx = Ctx("pallas", defines, gids, grid,
                  reduce_axes=spec.reduce_axes, scratch=scr)
        spec.body(ctx, *refs[: n_in + n_out])

    def mk_index(t: Tile):
        base = t.resolved_index(grid)
        if t.index_tile is None:
            if not n_tab:
                return base
            return lambda *a: base(*a[:ng])
        tname, axis = t.index_tile
        tindex = tiles[tname].resolved_index(grid)
        ti = table_names.index(tname)
        nb = t.shape[axis] // t.resolved_block()[axis]

        def gather(*a):
            ids, srefs = a[:ng], a[ng:]
            # all-ones table block: its block index IS the element index
            val = srefs[ti][tuple(tindex(*ids))]
            out = list(base(*ids))
            out[axis] = jnp.clip(val, 0, nb - 1)
            return tuple(out)

        return gather

    def mk_block(t: Tile):
        return pl.BlockSpec(t.resolved_block(), mk_index(t))

    # Real-TPU pipelining: outer axes are embarrassingly parallel (validated:
    # each output block is written from exactly one outer cell), reduce axes
    # carry scratch state and must stay sequential ("arbitrary"). The
    # interpreter ignores compiler params, so only pass them when compiling.
    kwargs = {}
    if not interpret:
        sem = spec.resolved_semantics()
        params_cls = getattr(pltpu, "CompilerParams", None) or \
            getattr(pltpu, "TPUCompilerParams", None)
        if params_cls is not None:
            kwargs["compiler_params"] = params_cls(dimension_semantics=sem)

    in_specs = [mk_block(t) for t in spec.inputs]
    out_specs = [mk_block(t) for t in spec.outputs]
    out_shape = [jax.ShapeDtypeStruct(t.shape, t.dtype) for t in spec.outputs]
    scratch_shapes = [pltpu.VMEM(s.shape, s.dtype) for s in spec.scratch]

    if n_tab:
        call = pl.pallas_call(
            body_adapter,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=n_tab, grid=grid,
                in_specs=in_specs, out_specs=out_specs,
                scratch_shapes=scratch_shapes),
            out_shape=out_shape,
            interpret=interpret,
            **kwargs,
        )

        def fn(*in_arrays):
            tabs = [in_arrays[i] for i in table_pos]
            return tuple(call(*tabs, *in_arrays))

        return fn

    call = pl.pallas_call(
        body_adapter,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        **kwargs,
    )

    def fn(*in_arrays):
        return tuple(call(*in_arrays))

    return fn


def expand(spec: Spec, defines: SimpleNamespace, backend: str, *, interpret: bool = True):
    """Expand one kernel Spec for a backend (the run-time 'macro expansion')."""
    spec, preps = _lower_halos(spec)
    if backend in ("jnp", "loops") and math.prod(spec.grid) == 1:
        inner = _expand_single_cell(spec, defines, backend)
    elif backend == "jnp":
        inner = _expand_jnp(spec, defines)
    elif backend == "loops":
        inner = _expand_loops(spec, defines)
    elif backend == "pallas":
        inner = _expand_pallas(spec, defines, interpret)
    else:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if preps is None:
        return inner

    def fn(*in_arrays):
        return inner(*(a if p is None else p(a)
                       for p, a in zip(preps, in_arrays)))

    return fn
