"""occa::device analogue — run-time backend selection + kernel build cache.

``Device("pallas")`` on a TPU host compiles real Pallas kernels; on this CPU
container it transparently selects ``interpret=True`` (the kernel *language*
is identical — that is the portability contract). ``build_kernel`` performs
the paper's run-time compilation: the builder is invoked with the injected
``defines`` (addDefine analogue), expanded for the device's backend, jitted,
and cached keyed by (builder, defines, backend) — OCCA's kernel cache.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import jax

from . import lang
from .kernel import Kernel
from .memory import Memory

__all__ = ["Device", "BuildStats"]


@dataclasses.dataclass
class BuildStats:
    builds: int = 0
    cache_hits: int = 0


def _freeze(v):
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    return v


class Device:
    """A compute backend with its own kernel build cache."""

    BACKENDS = lang.BACKENDS

    def __init__(self, backend: str = "jnp", *, interpret: bool | None = None):
        if backend not in self.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {self.BACKENDS}")
        self.backend = backend
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        self._cache: dict = {}
        self._lock = threading.Lock()
        self.stats = BuildStats()

    # -- memory ---------------------------------------------------------------
    def malloc(self, array_or_shape, dtype=None) -> Memory:
        import jax.numpy as jnp

        if isinstance(array_or_shape, (tuple, list)) or isinstance(array_or_shape, int):
            shape = (array_or_shape,) if isinstance(array_or_shape, int) else tuple(array_or_shape)
            array = jnp.zeros(shape, dtype or jnp.float32)
        else:
            array = jnp.asarray(array_or_shape)
        return Memory(self, array)

    # -- run-time kernel compilation -------------------------------------------
    def build_kernel(self, builder: Callable, defines: dict | None = None) -> Kernel:
        defines = dict(defines or {})
        key = (
            getattr(builder, "__module__", "?") + "." + getattr(builder, "__qualname__", repr(builder)),
            _freeze(defines),
            self.backend,
            self.interpret,
        )
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self.stats.cache_hits += 1
                return hit

        D = lang.defines_namespace(defines)
        spec = builder(D)
        if not isinstance(spec, lang.Spec):
            raise TypeError(f"builder {builder!r} must return lang.Spec, got {type(spec)}")
        fn = lang.expand(spec, D, self.backend, interpret=self.interpret)
        kern = Kernel(self, spec, jax.jit(fn), defines)

        with self._lock:
            self._cache[key] = kern
            self.stats.builds += 1
        return kern

    def synchronize(self) -> None:
        # jax dispatch is async; nothing to do beyond letting callers
        # block on results (block_until_ready on Memory.data).
        pass

    def __repr__(self):
        return f"Device(backend={self.backend!r}, interpret={self.interpret})"
