"""occa::device analogue — run-time backend selection + kernel build cache.

``Device("pallas")`` on a TPU host compiles real Pallas kernels; on this CPU
container it transparently selects ``interpret=True`` (the kernel *language*
is identical — that is the portability contract). ``build_kernel`` performs
the paper's run-time compilation: the builder is invoked with the injected
``defines`` (addDefine analogue), expanded for the device's backend, jitted,
and cached keyed by (builder *identity*, defines, backend) — OCCA's kernel
cache. Identity matters: two closures produced by the same factory share a
``__qualname__`` but are different kernels, so the cache is keyed on the
function object itself (weakly, where possible) rather than its name.
"""

from __future__ import annotations

import dataclasses
import threading
import weakref
from typing import Callable

import jax

from . import analyze as _analyze
from . import lang
from .kernel import Kernel
from .memory import Memory

__all__ = ["Device", "BuildStats", "default_device", "fit_block"]


@dataclasses.dataclass
class BuildStats:
    builds: int = 0
    cache_hits: int = 0


def _freeze(v):
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    return v


class Device:
    """A compute backend with its own kernel build cache."""

    BACKENDS = lang.BACKENDS

    def __init__(self, backend: str = "jnp", *, interpret: bool | None = None):
        if backend not in self.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {self.BACKENDS}")
        self.backend = backend
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        # id(builder anchor) -> (ref-or-strong-anchor, {key: Kernel}). Keyed by
        # object IDENTITY (never __eq__/__hash__: two equal-but-distinct
        # instances must not share kernels). Weakly-referenced anchors are
        # evicted by a finalizer so caching never pins short-lived closures;
        # non-weakrefable anchors are held strongly (keeping the id valid)
        # with bounded FIFO eviction.
        self._cache: dict = {}
        self._strong_keys: list = []
        self._lock = threading.Lock()
        self.stats = BuildStats()

    # -- memory ---------------------------------------------------------------
    def malloc(self, array_or_shape, dtype=None) -> Memory:
        import jax.numpy as jnp

        if isinstance(array_or_shape, (tuple, list)) or isinstance(array_or_shape, int):
            shape = (array_or_shape,) if isinstance(array_or_shape, int) else tuple(array_or_shape)
            array = jnp.zeros(shape, dtype or jnp.float32)
        else:
            array = jnp.asarray(array_or_shape, dtype)  # dtype=None keeps as-is
        return Memory(self, array)

    _STRONG_CACHE_MAX = 64

    @staticmethod
    def _evict_entry(cache, key, ref):
        ent = cache.get(key)
        if ent is not None and ent[0] is ref:  # don't drop a reused-id entry
            cache.pop(key, None)

    def _builder_cache(self, builder) -> dict:
        """Per-builder kernel sub-cache, keyed on object identity.

        Bound methods are a fresh object per attribute access, so they are
        unwrapped and anchored on the *instance* (with the underlying function
        in the subkey) — ``dev.build_kernel(obj.builder, ...)`` in a loop hits
        the cache. Plain closures recreated per call inherently cannot: hold
        onto the builder object to reuse its cache."""
        anchor, fn = builder, None
        if getattr(builder, "__func__", None) is not None \
                and getattr(builder, "__self__", None) is not None:
            anchor, fn = builder.__self__, builder.__func__
        key = id(anchor)
        ent = self._cache.get(key)
        if ent is not None:
            ref, sub = ent
            live = ref() if isinstance(ref, weakref.ref) else ref
            if live is not anchor:  # stale id reuse: rebuild the entry
                ent = None
        if ent is None:
            sub = {}
            try:
                ref = weakref.ref(anchor)
                self._cache[key] = (ref, sub)
                weakref.finalize(anchor, self._evict_entry, self._cache, key, ref)
            except TypeError:  # anchor not weakref-able: hold it strongly
                self._cache[key] = (anchor, sub)
                self._strong_keys.append(key)
                while len(self._strong_keys) > self._STRONG_CACHE_MAX:
                    # bounded: evict oldest so strong refs can't pile up forever
                    self._cache.pop(self._strong_keys.pop(0), None)
        if fn is None:
            return sub
        per_fn = sub.get(fn)
        if per_fn is None:
            per_fn = sub[fn] = {}
        return per_fn

    # -- run-time kernel compilation -------------------------------------------
    def build_kernel(self, builder: Callable, defines: dict | None = None, *,
                     analyze: str | None = None) -> Kernel:
        defines = dict(defines or {})
        # backend/interpret are set in __init__ but are public attributes: keep
        # them in the key so mutating them can't serve stale kernels.
        key = (_freeze(defines), self.backend, self.interpret)
        with self._lock:
            hit = self._builder_cache(builder).get(key)
            if hit is not None:
                self.stats.cache_hits += 1
                return hit

        D = lang.defines_namespace(defines)
        spec = builder(D)
        if not isinstance(spec, lang.Spec):
            raise TypeError(f"builder {builder!r} must return lang.Spec, got {type(spec)}")
        # the static analyzer gates every cache-miss build (grid invariants
        # already ran in Spec.__post_init__; this adds the body-trace
        # liveness/coverage pass). ``analyze`` overrides the process mode
        # per build ($REPRO_ANALYZE / set_analysis_mode; "off" skips).
        _analyze.check_built_spec(spec, D, mode=analyze)
        fn = lang.expand(spec, D, self.backend, interpret=self.interpret)
        kern = Kernel(self, spec, jax.jit(fn), defines)

        with self._lock:
            self._builder_cache(builder)[key] = kern
            self.stats.builds += 1
        return kern

    def synchronize(self) -> None:
        # jax dispatch is async; nothing to do beyond letting callers
        # block on results (block_until_ready on Memory.data).
        pass

    def __repr__(self):
        return f"Device(backend={self.backend!r}, interpret={self.interpret})"


_DEFAULT_DEVICES: dict = {}
_DEFAULT_DEVICES_LOCK = threading.Lock()


def default_device(backend: str, interpret: bool | None = None) -> Device:
    """Process-wide Device per (backend, interpret), so ops that build kernels
    on the fly (matmul, rmsnorm, …) share one kernel cache instead of one per
    module. ``interpret=None`` lets the Device pick (interpret off-TPU)."""
    with _DEFAULT_DEVICES_LOCK:
        key = (backend, interpret)
        dev = _DEFAULT_DEVICES.get(key)
        if dev is None:
            dev = _DEFAULT_DEVICES[key] = Device(backend, interpret=interpret)
        return dev


def fit_block(block: int, n: int) -> int:
    """Largest divisor of ``n`` that is <= ``block`` (blocks must tile exactly)."""
    if n <= 0:
        raise ValueError(f"fit_block: cannot tile a dimension of size {n}")
    if block <= 0:
        raise ValueError(f"fit_block: block must be positive, got {block}")
    block = min(int(block), int(n))
    while n % block:
        block -= 1
    return block
