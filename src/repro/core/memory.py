"""occa::memory analogue — device memory handles over functional JAX.

OCCA memory is imperative (kernels write into it; ``o_u1.swap(o_u2)`` swaps
handles). JAX arrays are immutable, so a :class:`Memory` owns a *rebindable*
reference to a ``jax.Array``: kernels return fresh arrays and the host API
rebinds the handle — the user-visible semantics (including ``swap``, the
paper's code listing 9) are preserved exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Memory"]


class Memory:
    __slots__ = ("device", "_arr")

    def __init__(self, device, array):
        self.device = device
        self._arr = jnp.asarray(array)

    # -- handle access ------------------------------------------------------
    @property
    def data(self) -> jax.Array:
        return self._arr

    @property
    def shape(self):
        return self._arr.shape

    @property
    def dtype(self):
        return self._arr.dtype

    @property
    def nbytes(self) -> int:
        return self._arr.size * self._arr.dtype.itemsize

    # -- paper listing 9: o_u1.swap(o_u2) ------------------------------------
    def swap(self, other: "Memory") -> None:
        if not isinstance(other, Memory):
            raise TypeError(f"swap: expected Memory, got {type(other).__name__}")
        if other.device is not self.device:
            # handles from different devices silently swapping would mix
            # backends (occa: memory belongs to the device that malloc'd it)
            raise ValueError(
                f"swap: Memory handles belong to different devices "
                f"({self.device!r} vs {other.device!r})")
        self._arr, other._arr = other._arr, self._arr

    # -- host<->device copies -------------------------------------------------
    def to_host(self) -> np.ndarray:
        return np.asarray(self._arr)

    def from_host(self, array) -> None:
        array = jnp.asarray(array)
        if array.shape != self._arr.shape or array.dtype != self._arr.dtype:
            raise ValueError(
                f"from_host: expected {self._arr.shape}/{self._arr.dtype}, "
                f"got {array.shape}/{array.dtype}")
        self._arr = array

    def _rebind(self, array) -> None:
        self._arr = array

    def __repr__(self):
        return f"Memory(shape={self.shape}, dtype={self.dtype}, backend={self.device.backend})"
