"""Kernel autotuning — the paper's `setThreadArray` tuning loop as a
first-class facility: sweep block-size defines for a kernel builder on a
device, time each candidate, cache the winner.

    best = autotune(device, fd2d_builder, base_defines,
                    sweep={"bh": [16, 32, 64, 128]},
                    args=(u1, u2))
    kernel = device.build_kernel(fd2d_builder, best)

Winners persist across processes (OCCA's on-disk kernel cache analogue):
``autotune(..., cache=True)`` stores the best sweep values as JSON under
``$REPRO_CACHE_DIR`` (default ``~/.cache/repro-occa/``), keyed by
(op/builder name, the non-swept defines, backend, device kind, jax version).
A warm cache returns immediately — zero builds, zero timed sweeps. Entries
are stamped with :data:`SCHEMA_VERSION`; corrupt, mismatched or
other-version entries are evicted on load (never crashed on, never silently
reused). :func:`cached_winner` exposes the lookup without the sweep — the
serving warmup path.
"""

from __future__ import annotations

import hashlib
import itertools
import json
import os
import pathlib
import time

import jax

__all__ = ["autotune", "cached_winner", "prune_candidates", "TuneResult",
           "tune_cache_dir", "tune_cache_key", "SCHEMA_VERSION"]

# Bump whenever the meaning of a cache entry changes (payload layout, winner
# semantics, timing protocol). Entries stamped with any other version are
# EVICTED on load — never crashed on, never silently reused.
SCHEMA_VERSION = 2


def tune_cache_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get(
        "REPRO_CACHE_DIR", os.path.expanduser("~/.cache/repro-occa")))


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return jax.default_backend()


def tune_cache_key(name: str, defines: dict, sweep: dict, backend: str,
                   interpret: bool = False) -> tuple:
    """(digest, payload): the persistent-cache identity of one tuning problem.

    Swept keys are excluded from the defines — they are the tuning *output* —
    but the CANDIDATE SETS are part of the identity (a narrower sweep is a
    different tuning problem: its cached winner must not come from values the
    caller excluded). Everything else a winner could depend on (shape/dtype
    defines, backend, interpret mode, device kind, jax version) is in.
    Interpret mode matters: interpreter wall-times are unrelated to compiled
    TPU performance, so a debug sweep must never answer for the compiled
    path."""
    base = {k: v for k, v in sorted(defines.items()) if k not in sweep}
    payload = dict(op=name, defines={k: repr(v) for k, v in base.items()},
                   sweep={k: [repr(v) for v in sweep[k]] for k in sorted(sweep)},
                   backend=backend, interpret=bool(interpret),
                   device_kind=_device_kind(), jax_version=jax.__version__)
    digest = hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()[:24]
    return digest, payload


def _evict(path: pathlib.Path):
    try:
        path.unlink()
    except OSError:
        pass


def _cache_load(digest: str, payload: dict, sweep_names):
    """Load one cache entry, EVICTING anything unusable.

    An entry is stale/mismatched — deleted on sight, treated as a miss — when
    it is corrupt JSON, stamped with a schema version other than
    :data:`SCHEMA_VERSION` (including pre-versioning entries with no stamp),
    its stored tuning-problem payload disagrees with the one that produced
    the digest (hand-edited or colliding file), or its winner no longer
    covers the swept keys. Reusing any of those would either crash the sweep
    consumer or silently answer a different tuning problem."""
    path = tune_cache_dir() / "autotune" / f"{digest}.json"
    try:
        with open(path) as f:
            entry = json.load(f)
    except OSError:
        return None                     # no entry: nothing to evict
    except ValueError:
        _evict(path)                    # corrupt: remove and re-tune
        return None
    if (entry.get("schema") != SCHEMA_VERSION
            or any(entry.get(k) != v for k, v in payload.items())
            or not all(n in entry.get("winner", {}) for n in sweep_names)):
        _evict(path)
        return None
    return entry


def _cache_store(digest: str, payload: dict, winner: dict, best_seconds: float):
    root = tune_cache_dir() / "autotune"
    try:
        root.mkdir(parents=True, exist_ok=True)
        tmp = root / f".{digest}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(dict(payload, schema=SCHEMA_VERSION, winner=winner,
                           best_seconds=best_seconds),
                      f, indent=1, sort_keys=True)
        os.replace(tmp, root / f"{digest}.json")
    except OSError:
        pass  # cache is an optimization; never fail the tune over it


def cached_winner(name: str, defines: dict, sweep: dict, backend: str,
                  interpret: bool = False) -> dict | None:
    """The persisted winner for one tuning problem, or None — a pure lookup
    (no builds, no timings). Stale entries are evicted along the way."""
    names = sorted(sweep)
    digest, payload = tune_cache_key(name, defines, sweep, backend, interpret)
    hit = _cache_load(digest, payload, names)
    if hit is None:
        return None
    return {n: hit["winner"][n] for n in names}


class TuneResult(dict):
    """The winning defines; ``.trials`` holds (defines, seconds) for all
    candidates, ``.best_seconds`` the winning time, ``.skipped`` the
    (defines, reason) pairs rejected before timing — at build time (invalid
    tilings) or by the static cost model (``prune[...]`` reasons, also
    exposed as ``.pruned``) — and ``.cached`` whether the result came from
    the persistent cache (in which case ``.trials`` is empty — nothing was
    re-timed)."""

    def __init__(self, best_defines, trials, skipped=(), best_seconds=None,
                 cached=False):
        super().__init__(best_defines)
        self.trials = list(trials)
        if best_seconds is None:
            timed = [t for _, t in self.trials if t < float("inf")]
            best_seconds = min(timed) if timed else float("nan")
        self.best_seconds = best_seconds
        self.skipped = list(skipped)
        self.cached = cached

    @property
    def pruned(self):
        """(defines, reason) pairs rejected by the static cost model."""
        return [(c, r) for c, r in self.skipped if r.startswith("prune[")]


def prune_candidates(builder, defines: dict, sweep: dict, *, budget=None):
    """Static cost pass over a sweep's candidate space — no kernel is built
    or timed. Returns ``(kept, pruned)`` where ``kept`` is the list of
    candidate defines dicts still worth timing and ``pruned`` is a list of
    ``(candidate, reason)`` pairs, reasons prefixed ``prune[CODE]:``.

    Two rejection rules, both fail-open (a candidate the model cannot
    evaluate is kept for the build loop to judge):

    * ``prune[VMEM_OVERFLOW]`` — the static footprint exceeds the VMEM
      budget; the build would raise the same verdict, so don't pay for it.
    * ``prune[DOMINATED]`` — another candidate that itself fits the budget
      moves no more HBM bytes AND does no more FLOPs, at least one strictly
      less. The static model ranks it at-least-as-fast, so timing the
      dominated candidate buys nothing. VMEM footprint is deliberately NOT
      part of the dominance vector: bigger blocks nearly always trade
      footprint for bytes/FLOPs, and a footprint term would make dominance
      vacuous — the budget check alone polices VMEM.
    """
    from types import SimpleNamespace

    from . import analyze as _analyze

    budget = _analyze.vmem_budget() if budget is None else int(budget)
    names = sorted(sweep)
    cands = []   # (cand, report | None)
    for combo in itertools.product(*(sweep[n] for n in names)):
        cand = dict(defines, **dict(zip(names, combo)))
        try:
            spec = builder(SimpleNamespace(**cand))
            rep = _analyze.estimate_cost(
                spec, SimpleNamespace(**cand), budget=budget)
        except Exception:
            rep = None   # invalid/unmodelable: the build loop decides
        cands.append((cand, rep))

    kept, pruned = [], []
    fitting = [(c, r) for c, r in cands
               if r is not None and r.vmem_bytes <= budget]
    for cand, rep in cands:
        if rep is None:
            kept.append(cand)
            continue
        if rep.vmem_bytes > budget:
            pruned.append((cand, (
                f"prune[VMEM_OVERFLOW]: static footprint {rep.vmem_bytes} B "
                f"> budget {budget} B")))
            continue
        dominator = None
        if rep.flops is not None:
            for other, orep in fitting:
                if other is cand or orep.flops is None:
                    continue
                if (orep.hbm_bytes <= rep.hbm_bytes
                        and orep.flops <= rep.flops
                        and (orep.hbm_bytes < rep.hbm_bytes
                             or orep.flops < rep.flops)):
                    dominator = (other, orep)
                    break
        if dominator is not None:
            other, orep = dominator
            over = {n: other[n] for n in names}
            pruned.append((cand, (
                f"prune[DOMINATED]: {over} moves {orep.hbm_bytes} B vs "
                f"{rep.hbm_bytes} B and does {orep.flops} vs {rep.flops} "
                "FLOPs — statically at-least-as-fast")))
            continue
        kept.append(cand)
    return kept, pruned


def _time_once(kernel, args, *, warmup=1, repeats=3):
    """Returns (best seconds, last output) — callers reuse the output so
    validation doesn't pay an extra kernel execution."""
    out = None
    for _ in range(warmup):
        out = kernel.run(*args)
    if out is not None:  # warmup=0: nothing dispatched yet, nothing to block on
        jax.block_until_ready(out)
    best = float("inf")
    # repeats=0 used to leave best == inf (TuneResult.best_seconds == inf and
    # every candidate ranked equal); always take at least one timed dispatch.
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = kernel.run(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _as_output_tuple(x):
    return tuple(x) if isinstance(x, (tuple, list)) else (x,)


def autotune(device, builder, defines: dict, *, sweep: dict, args,
             warmup: int = 1, repeats: int = 3, validate: bool = True,
             ref=None, cache: bool = False, name: str | None = None,
             prune: bool = True, budget=None):
    """Grid-search ``sweep`` (name -> candidate values) over ``defines``.

    Invalid candidates (non-dividing blocks etc.) are skipped via the
    Spec validation errors. With ``prune=True`` (default) the static cost
    model rejects candidates *before* any build or timing — VMEM-overflow
    and strictly-dominated candidates land in ``.skipped`` with a
    ``prune[...]`` reason (see :func:`prune_candidates`; ``budget``
    overrides the VMEM budget). With ``validate=True`` every candidate's
    output is checked against ``ref`` — an independent oracle, either a
    callable ``ref(*args)`` or precomputed output arrays — when one is
    given; without a ref, candidates are cross-checked against the first
    valid candidate (tuning must not change results — the paper's
    correctness-portability contract — but a bug shared with the first
    candidate self-certifies, so declare a ref whenever one exists).

    ``cache=True`` consults/updates the persistent winner cache under
    ``$REPRO_CACHE_DIR`` before sweeping; ``name`` keys the cache entry
    (defaults to the builder's qualname).
    """
    import numpy as np

    names = sorted(sweep)
    name = name or getattr(builder, "__qualname__", repr(builder))
    if cache:
        digest, payload = tune_cache_key(name, defines, sweep, device.backend,
                                         getattr(device, "interpret", False))
        hit = _cache_load(digest, payload, names)
        if hit is not None:
            winner = {n: hit["winner"][n] for n in names}
            return TuneResult(dict(defines, **winner), trials=[],
                              best_seconds=hit.get("best_seconds", float("nan")),
                              cached=True)

    reference = None
    if validate and ref is not None:
        out = ref(*args) if callable(ref) else ref
        reference = [np.asarray(o) for o in _as_output_tuple(out)]

    skipped = []
    if prune:
        candidates, pruned = prune_candidates(
            builder, defines, sweep, budget=budget)
        skipped.extend(pruned)
        if not candidates and pruned:
            raise ValueError(
                "every sweep candidate was statically pruned:\n"
                + "\n".join(f"  {c}: {r}" for c, r in pruned))
    else:
        candidates = [dict(defines, **dict(zip(names, combo)))
                      for combo in itertools.product(*(sweep[n]
                                                       for n in names))]

    trials = []
    for cand in candidates:
        try:
            kernel = device.build_kernel(builder, cand)
        except (ValueError, AssertionError) as e:
            skipped.append((cand, str(e)))  # invalid tiling for this shape
            continue
        sec, raw = _time_once(kernel, args, warmup=warmup, repeats=repeats)
        if validate and raw is not None:
            out = [np.asarray(o) for o in raw]
            if reference is None:
                reference = out  # no oracle declared: first-candidate fallback
            else:
                for a, b in zip(out, reference):
                    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
        trials.append((cand, sec))
    if not trials:
        raise ValueError("no valid candidate in the sweep")
    best, best_sec = min(trials, key=lambda t: t[1])
    result = TuneResult(best, trials, skipped, best_seconds=best_sec)
    if cache:
        _cache_store(digest, payload, {n: best[n] for n in names}, best_sec)
    return result
