"""Kernel autotuning — the paper's `setThreadArray` tuning loop as a
first-class facility: sweep block-size defines for a kernel builder on a
device, time each candidate, cache the winner.

    best = autotune(device, fd2d_builder, base_defines,
                    sweep={"bh": [16, 32, 64, 128]},
                    args=(u1, u2))
    kernel = device.build_kernel(fd2d_builder, best)
"""

from __future__ import annotations

import itertools
import time

import jax

__all__ = ["autotune", "TuneResult"]


class TuneResult(dict):
    """The winning defines; ``.trials`` holds (defines, seconds) for all
    candidates, ``.best_seconds`` the winning time, ``.skipped`` the
    (defines, reason) pairs rejected at build time (invalid tilings)."""

    def __init__(self, best_defines, trials, skipped=()):
        super().__init__(best_defines)
        self.trials = trials
        self.best_seconds = min(t for _, t in trials)
        self.skipped = list(skipped)


def _time_once(kernel, args, *, warmup=1, repeats=3):
    """Returns (best seconds, last output) — callers reuse the output so
    validation doesn't pay an extra kernel execution."""
    out = None
    for _ in range(warmup):
        out = kernel.run(*args)
    if out is not None:  # warmup=0: nothing dispatched yet, nothing to block on
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = kernel.run(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def autotune(device, builder, defines: dict, *, sweep: dict, args,
             warmup: int = 1, repeats: int = 3, validate: bool = True):
    """Grid-search ``sweep`` (name -> candidate values) over ``defines``.

    Invalid candidates (non-dividing blocks etc.) are skipped via the
    Spec validation errors. With ``validate=True`` every candidate's output
    is checked against the first valid candidate (tuning must not change
    results — the paper's correctness-portability contract).
    """
    import numpy as np

    names = sorted(sweep)
    trials = []
    skipped = []
    reference = None
    for combo in itertools.product(*(sweep[n] for n in names)):
        cand = dict(defines, **dict(zip(names, combo)))
        try:
            kernel = device.build_kernel(builder, cand)
        except (ValueError, AssertionError) as e:
            skipped.append((cand, str(e)))  # invalid tiling for this shape
            continue
        sec, raw = _time_once(kernel, args, warmup=warmup, repeats=repeats)
        if validate and raw is not None:  # raw is None only when warmup=repeats=0
            out = [np.asarray(o) for o in raw]
            if reference is None:
                reference = out
            else:
                for a, b in zip(out, reference):
                    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
        trials.append((cand, sec))
    if not trials:
        raise ValueError("no valid candidate in the sweep")
    best = min(trials, key=lambda t: t[1])[0]
    return TuneResult(best, trials, skipped)
