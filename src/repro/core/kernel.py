"""occa::kernel analogue — a built (backend-expanded, jitted) kernel handle."""

from __future__ import annotations

from .memory import Memory

__all__ = ["Kernel"]


class Kernel:
    """Callable kernel handle.

    Call convention follows the paper's host code (listing 9): positional
    arguments are the kernel's inputs followed by its outputs. Output
    arguments must be :class:`Memory`; their handles are rebound to the fresh
    result arrays (functional under the hood, imperative at the surface).
    """

    def __init__(self, device, spec, compiled, defines: dict):
        self.device = device
        self.spec = spec
        self.defines = dict(defines)
        self._compiled = compiled
        self.n_in = len(spec.inputs)
        self.n_out = len(spec.outputs)

    @property
    def name(self) -> str:
        return self.spec.name

    def __call__(self, *args):
        if len(args) != self.n_in + self.n_out:
            raise TypeError(
                f"kernel {self.name!r} expects {self.n_in} inputs + "
                f"{self.n_out} outputs, got {len(args)} args")
        ins = [a.data if isinstance(a, Memory) else a for a in args[: self.n_in]]
        for slot in args[self.n_in:]:
            if not isinstance(slot, Memory):
                raise TypeError(f"kernel {self.name!r}: output args must be Memory")
            if slot.device is not self.device:
                raise ValueError(
                    f"kernel {self.name!r}: output Memory belongs to "
                    f"{slot.device!r}, not this kernel's {self.device!r}")
        outs = self._compiled(*ins)
        for slot, val in zip(args[self.n_in:], outs):
            slot._rebind(val)
        return outs

    # Functional entry point (used by tests / composition inside jit).
    def run(self, *in_arrays):
        return self._compiled(*in_arrays)

    def lowered_text(self, *in_arrays) -> str:
        # self._compiled is already jitted by Device.build_kernel
        return self._compiled.lower(*in_arrays).as_text()

    def __repr__(self):
        return (f"Kernel({self.name!r}, backend={self.device.backend}, "
                f"defines={self.defines})")
