"""repro.core — the paper's contribution: a unified kernel language + host API.

One kernel source expands at run time to three backends (``jnp``, ``loops``,
``pallas``), selected per :class:`Device` — the OCCA OpenMP/OpenCL/CUDA model
adapted to JAX/TPU. See DESIGN.md §2 for the keyword-by-keyword mapping.
"""

from .lang import (BACKENDS, Ctx, Scratch, ShardAxis, Spec, Tile, TileRef,
                   cdiv, expand)
from .analyze import (ANALYZE_MODES, AnalysisError, AnalysisWarning,
                      CostReport, Finding, Report, analysis_mode,
                      analyze_spec, estimate_cost, estimate_flops,
                      set_analysis_mode, vmem_budget, vmem_footprint)
from .device import Device, BuildStats, default_device, fit_block
from .kernel import Kernel
from .memory import Memory
from .op import (Op, OpShard, OpVJP, define_op, get_op, oracle_vjp,
                 registered_ops)
from .tune import (SCHEMA_VERSION, TuneResult, autotune, cached_winner,
                   prune_candidates, tune_cache_dir, tune_cache_key)

__all__ = [
    "ANALYZE_MODES",
    "AnalysisError",
    "AnalysisWarning",
    "BACKENDS",
    "BuildStats",
    "CostReport",
    "Ctx",
    "Device",
    "Finding",
    "Kernel",
    "Memory",
    "Op",
    "OpShard",
    "OpVJP",
    "Report",
    "SCHEMA_VERSION",
    "Scratch",
    "ShardAxis",
    "Spec",
    "Tile",
    "TileRef",
    "TuneResult",
    "analysis_mode",
    "analyze_spec",
    "autotune",
    "cached_winner",
    "cdiv",
    "default_device",
    "define_op",
    "estimate_cost",
    "estimate_flops",
    "expand",
    "fit_block",
    "get_op",
    "oracle_vjp",
    "prune_candidates",
    "registered_ops",
    "set_analysis_mode",
    "tune_cache_dir",
    "tune_cache_key",
    "vmem_budget",
    "vmem_footprint",
]
