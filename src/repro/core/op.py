"""Declarative op front-end — ``define_op`` (OCCA's host API, unified).

Every public kernel op (matmul, rmsnorm, ssm_scan, flash-attention, ...) is
ONE ``define_op`` declaration: a kernel-language builder, a pure oracle, and a
shape->defines derivation. The front-end owns everything the per-op host
wrappers used to duplicate —

  * backend selection   (``backend="auto"`` -> ``$REPRO_BACKEND`` if set,
                         else pallas; interpret off-TPU, via
                         :func:`repro.core.device.default_device`)
  * defines derivation  (``derive_defines`` with ``fit_block`` + degradation
                         guards, per call, cached by the Device kernel cache)
  * kernel build/cache  (``Device.build_kernel`` — OCCA's runtime compile)
  * custom-VJP wiring   (an :class:`OpVJP` declaration instead of per-op
                         ``jax.custom_vjp`` boilerplate)
  * autotuning          (``op.tune(args)`` sweeps the op's declared knobs,
                         validates against the oracle, persists winners)

and registers the op in a process-wide registry so tooling (tests, benchmark
harnesses, serving) can enumerate every op and its oracle.

    matmul = define_op(
        "matmul", builder=matmul_builder, ref=matmul_ref,
        derive_defines=_defines, sweep={"bm": [...], "bn": [...]}, ...)
    c = matmul(a, b)                      # pallas (interpret off-TPU)
    c = matmul(a, b, backend="loops")     # same kernel source, loops expansion
    best = matmul.tune((a, b))            # sweep, validate vs ref, cache
"""

from __future__ import annotations

import functools
import os
from typing import Callable, Mapping, Sequence

import jax

from . import tune as _tune
from .device import default_device
from .lang import BACKENDS

__all__ = ["Op", "OpShard", "OpVJP", "define_op", "get_op", "oracle_vjp",
           "registered_ops"]

_REGISTRY: dict[str, "Op"] = {}


def registered_ops() -> dict[str, "Op"]:
    """Snapshot of the process-wide op registry (name -> Op)."""
    return dict(_REGISTRY)


def get_op(name: str) -> "Op":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no op named {name!r} registered; known: {sorted(_REGISTRY)}") from None


class OpVJP:
    """Custom-VJP declaration for a :func:`define_op` op.

    ``bwd(params, residuals, cotangent) -> per-primal-arg cotangents`` is the
    only required piece; ``params`` carries the resolved ``backend`` and
    ``interpret`` so a backward built from unified-language kernels runs on
    the same expansion as the forward. ``residuals(outs, args, params)``
    selects what the backward needs (default: the primal args); ``outs`` is
    the FULL kernel output tuple, so residual-only outputs (flash-attention's
    lse) are available even though callers never see them."""

    def __init__(self, bwd: Callable, residuals: Callable | None = None):
        self.bwd = bwd
        self.residuals = residuals or (lambda outs, args, params: args)


def oracle_vjp(ref_fn: Callable, *, params: Sequence[str] = ()) -> OpVJP:
    """An :class:`OpVJP` that differentiates the op's reference oracle.

    The forward runs the kernel; the backward is ``jax.vjp`` through
    ``ref_fn(*primals, **{k: params[k] for k in params})`` — correct whenever
    the kernel and the oracle compute the same function (which the test suite
    asserts), without writing a backward kernel."""

    def bwd(call_params, res, g):
        kw = {k: call_params[k] for k in params if k in call_params}
        _, pullback = jax.vjp(lambda *xs: ref_fn(*xs, **kw), *res)
        return pullback(g)

    return OpVJP(bwd=bwd)


class OpShard:
    """Executable mesh schedule for an op whose spec binds a ShardAxis.

    Where ``lang.ShardAxis`` is the spec-level DECLARATION (validated and
    cost-priced by the analyzer), ``OpShard`` is the op-level SCHEDULE:
    calling the op with ``mesh=`` wraps it in ``shard_map`` over these specs
    and drives the declared collective —

      ``"ppermute"``      a ring: ``step`` runs the per-chunk kernel on the
                          shard's current data, ``merge`` folds its partials
                          into the accumulator, and the ``rotate`` args hop to
                          the next shard between steps (``lax.ppermute``).
                          The whole ring is a static Python loop, so jax
                          autodiff transposes it for free (cotangents of the
                          rotated args ride the inverse ring home).
      ``"psum"`` /        one ``step`` per shard over its local slice, then an
      ``"psum_scatter"``  all-reduce (or reduce-scatter along
                          ``scatter_axis``) of the partials.

    ``in_specs(axis, args)`` / ``out_specs(axis)`` produce the shard_map
    partition specs. ``extent_param`` names an op param to set to the mesh
    axis size (so derived defines — and therefore the spec's ShardAxis extent
    and the tune-cache key — track the shard count). ``step`` defaults to the
    op's public call, which re-resolves ``backend=`` INSIDE shard_map: backend
    resolution is per-shard, not per-mesh.
    """

    def __init__(self, *, mesh_axis: str = "model",
                 collective: str = "ppermute", in_specs: Callable,
                 out_specs: Callable, rotate: Sequence[int] = (),
                 extent_param: str | None = None, scatter_axis: int = 0,
                 step: Callable | None = None, merge: Callable | None = None,
                 done: Callable | None = None):
        if collective not in ("ppermute", "psum", "psum_scatter"):
            raise ValueError(f"OpShard collective {collective!r} unknown")
        if collective == "ppermute" and (not rotate or merge is None):
            raise ValueError(
                "OpShard(collective='ppermute') needs rotate= arg indices "
                "and a merge= hook — a ring with nothing rotating or no way "
                "to fold partials cannot reduce across shards")
        self.mesh_axis = mesh_axis
        self.collective = collective
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.rotate = tuple(int(i) for i in rotate)
        self.extent_param = extent_param
        self.scatter_axis = int(scatter_axis)
        self.step = step or (
            lambda op, args, params, *, t, n, axis: op(*args, **params))
        self.merge = merge
        self.done = done


def _freeze(params: Mapping) -> tuple:
    return tuple(sorted(params.items()))


def _thaw(frozen: tuple) -> dict:
    return dict(frozen)


class Op:
    """A declared op: the public callable returned by :func:`define_op`.

    Hook signatures (all take/return plain tuples + a mutable params dict):

      early(args, params)        -> result or None   shape short-circuits
      pre(args, params)          -> kernel args      host-side arg prep
                                                     (may pop params it eats)
      derive_defines(args, params) -> defines dict   shapes -> addDefine set
      post(outs, args, params)   -> public result    host-side output shaping
                                                     (default: single output
                                                     unwrapped, tuple kept)

    ``args`` for ``post``/``OpVJP`` hooks are the ORIGINAL call args (pre is
    kernel-facing only). ``public_outputs`` exposes just the first n kernel
    outputs (the rest are residual-only, e.g. softmax stats)."""

    def __init__(self, name, builder, ref, derive_defines, *, vjp=None,
                 sweep=None, defaults=None, public_outputs=None,
                 early=None, pre=None, post=None, ref_params=(),
                 tune_ref=None, example=None, doc=None, array_params=(),
                 analyze=None, shard=None):
        self.name = name
        self.builder = builder
        self.ref = ref
        self.derive_defines = derive_defines
        self.vjp = vjp
        self.sweep = dict(sweep or {})
        self.defaults = dict(defaults or {})
        self.array_params = tuple(array_params)
        self.public_outputs = public_outputs
        self.ref_params = tuple(ref_params)
        self.tune_ref = tune_ref
        self.example = example
        # per-op static-analysis strictness override (None = the process
        # mode: $REPRO_ANALYZE / analyze.set_analysis_mode)
        self.analyze = analyze
        # declared mesh schedule (OpShard) behind the mesh= call param
        self.shard = shard
        self._early = early
        self._pre = pre
        self._post = post
        self.__doc__ = doc or (ref.__doc__ if ref is not None else None)
        self.__name__ = name
        if vjp is not None:
            self._core = self._build_vjp_core()

    # -- call plumbing -------------------------------------------------------
    def _resolve(self, kw: Mapping) -> tuple[str, bool | None, dict]:
        unknown = (set(kw) - set(self.defaults) - set(self.array_params)
                   - {"backend", "interpret"})
        if unknown:
            raise TypeError(
                f"op {self.name!r} got unexpected params {sorted(unknown)}; "
                f"known: {sorted(set(self.defaults) | set(self.array_params))} "
                "(+ backend, interpret)")
        params = dict(self.defaults)
        params.update(dict.fromkeys(self.array_params))
        params.update(kw)
        backend = params.pop("backend", "auto")
        interpret = params.pop("interpret", None)
        if backend == "auto":
            # REPRO_BACKEND pins what "auto" means process-wide — the CI
            # backend-matrix re-runs the cross-backend suites under jnp and
            # loops so a pallas-only regression can't hide behind the default
            backend = os.environ.get("REPRO_BACKEND", "pallas")
            if backend not in BACKENDS:
                raise ValueError(
                    f"REPRO_BACKEND={backend!r} is not a backend; expected "
                    f"one of {BACKENDS}")
        return backend, interpret, params

    def _prepare(self, args, params) -> tuple[tuple, dict, dict]:
        """The shared call prologue: pre-hook (may eat params) + shape->defines
        derivation. Returns (kernel args, defines, post-pre params)."""
        params = dict(params)
        if self._pre is not None:
            args = tuple(self._pre(tuple(args), params))
        return tuple(args), self.derive_defines(tuple(args), params), params

    def _run_kernel(self, args, backend, interpret, params) -> tuple:
        """prepare -> build (Device kernel cache) -> run; ALL kernel outputs."""
        args, defines, _ = self._prepare(args, params)
        kern = default_device(backend, interpret).build_kernel(
            self.builder, defines, analyze=self.analyze)
        return kern.run(*args)

    def _publish(self, outs, args, params):
        pub = outs if self.public_outputs is None else outs[: self.public_outputs]
        if self._post is not None:
            return self._post(pub, args, params)
        return pub[0] if len(pub) == 1 else pub

    def _primal(self, args, backend, interpret, params):
        outs = self._run_kernel(args, backend, interpret, params)
        return self._publish(outs, args, params), outs

    def _build_vjp_core(self):
        vjp = self.vjp

        @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
        def core(frozen, *args):
            backend, interpret, params = self._resolve(_thaw(frozen))
            return self._primal(args, backend, interpret, params)[0]

        def core_fwd(frozen, *args):
            backend, interpret, params = self._resolve(_thaw(frozen))
            result, outs = self._primal(args, backend, interpret, params)
            return result, vjp.residuals(outs, args, params)

        def core_bwd(frozen, res, g):
            # the bwd hook sees the resolved backend/interpret so a declared
            # backward KERNEL runs on the same expansion as the forward —
            # grads are backend-portable, not pallas-only
            backend, interpret, params = self._resolve(_thaw(frozen))
            params["interpret"] = interpret
            params["backend"] = backend
            return tuple(vjp.bwd(params, res, g))

        core.defvjp(core_fwd, core_bwd)
        return core

    def _shard_call(self, mesh, args, kw):
        """Run under ``shard_map`` per the declared :class:`OpShard` schedule.

        The collective loop is traced ONCE for all shards (shard_map's SPMD
        contract), so per-shard positions must come from ``lax.axis_index``
        inside the step hook, never from Python. ``check_rep=False``: the ring
        writes sharded outputs through explicit collectives the replication
        checker cannot see."""
        from jax import lax  # deferred: op.py stays import-light
        from jax.experimental.shard_map import shard_map

        sh = self.shard
        if sh is None:
            raise ValueError(
                f"op {self.name!r} declares no mesh schedule (OpShard); "
                "mesh= is not supported here")
        ax = sh.mesh_axis
        if ax not in dict(getattr(mesh, "shape", {})):
            raise ValueError(
                f"op {self.name!r}: mesh has no axis {ax!r} "
                f"(axes: {tuple(getattr(mesh, 'shape', {}))})")
        n = int(mesh.shape[ax])
        params = dict(kw)
        if sh.extent_param:
            params.setdefault(sh.extent_param, n)

        def local(*largs):
            if sh.collective == "ppermute":
                # ring: at step t, shard i holds chunk (i + t) % n of every
                # rotated arg; a backward pass through this loop transposes
                # each ppermute, carrying dk/dv-style cotangents home
                perm = [(j, (j - 1) % n) for j in range(n)]
                cur = list(largs)
                acc = None
                for t in range(n):
                    part = sh.step(self, tuple(cur), dict(params),
                                   t=t, n=n, axis=ax)
                    acc = part if acc is None else sh.merge(acc, part)
                    if t + 1 < n:
                        for i in sh.rotate:
                            cur[i] = lax.ppermute(cur[i], ax, perm)
                return sh.done(acc) if sh.done is not None else acc
            part = sh.step(self, largs, dict(params), t=0, n=n, axis=ax)
            if sh.collective == "psum":
                return jax.tree.map(lambda x: lax.psum(x, ax), part)
            return jax.tree.map(
                lambda x: lax.psum_scatter(
                    x, ax, scatter_dimension=sh.scatter_axis, tiled=True),
                part)

        fn = shard_map(local, mesh=mesh, in_specs=tuple(sh.in_specs(ax, args)),
                       out_specs=sh.out_specs(ax), check_rep=False)
        return fn(*args)

    def __call__(self, *args, **kw):
        mesh = kw.pop("mesh", None)
        if mesh is not None:
            return self._shard_call(mesh, args, kw)
        backend, interpret, params = self._resolve(kw)
        if self._early is not None:
            got = self._early(args, dict(params))
            if got is not None:
                return got
        if self.vjp is not None:
            # array-valued params cannot thread through custom_vjp's static
            # (nondiff) param tuple — reject loudly rather than freeze a
            # tracer or silently drop the value from the backward pass
            live = [n for n in self.array_params if params.get(n) is not None]
            if live:
                raise ValueError(
                    f"op {self.name!r}: params {live} take arrays and are not "
                    "differentiable through the public op; use the functional "
                    f"entry point ({self.name}.raw / its wrapper) instead")
            for n in self.array_params:
                params.pop(n, None)
            return self._core(
                _freeze(dict(params, backend=backend, interpret=interpret)),
                *args)
        return self._primal(args, backend, interpret, params)[0]

    def raw(self, *args, **kw):
        """Run the kernel and return ALL its outputs (no VJP, no post/early):
        the functional entry point for tests and composition."""
        backend, interpret, params = self._resolve(kw)
        return self._run_kernel(args, backend, interpret, params)

    # -- oracle access -------------------------------------------------------
    def reference(self, *args, **kw):
        """The op's oracle at public-call granularity (backend-independent)."""
        _, _, params = self._resolve(kw)
        return self.ref(*args, **{k: params[k] for k in self.ref_params
                                  if k in params})

    # -- autotuning ----------------------------------------------------------
    def tune(self, args, *, sweep=None, cache=True, warmup=1, repeats=3,
             validate=True, prune=True, **kw):
        """Sweep this op's tuning knobs on real args; returns the winning
        defines (a :class:`repro.core.tune.TuneResult`).

        Sweeps are over DEFINES keys (the builder's addDefine surface).
        Candidates validate against the op's oracle — not against each other.
        ``prune=True`` (default) lets the static cost model reject
        VMEM-overflow and strictly-dominated candidates before they are
        built or timed (reasons in ``result.pruned``). Winners persist under
        ``$REPRO_CACHE_DIR`` (``cache=False`` opts out): a warm cache
        performs zero builds and zero timed sweeps."""
        backend, interpret, params = self._resolve(kw)
        run_args, defines, params = self._prepare(args, params)
        sweep = dict(self.sweep if sweep is None else sweep)
        if not sweep:
            raise ValueError(f"op {self.name!r} declares no tuning sweep")
        # lazy: autotune evaluates the oracle only after a cache miss, so a
        # warm cache pays neither sweep timings nor the reference forward
        ref = None
        if validate:
            tref = self.tune_ref
            if tref is not None:
                ref = lambda *a: tref(run_args, params)  # noqa: E731
            elif self.ref is not None:
                kwf = {k: params[k] for k in self.ref_params if k in params}
                ref = lambda *a: self.ref(*a, **kwf)  # noqa: E731
        return _tune.autotune(
            default_device(backend, interpret), self.builder, defines,
            sweep=sweep, args=run_args, warmup=warmup, repeats=repeats,
            validate=validate, ref=ref, cache=cache, name=self.name,
            prune=prune)

    def cached_winner(self, args, *, sweep=None, **kw):
        """The persisted ``op.tune`` winner for these args, or None — a PURE
        cache lookup: no kernel builds, no timed sweeps, no oracle. This is
        how serving warmup adopts tuned block sizes (``$REPRO_CACHE_DIR``)
        instead of hardcoded defaults."""
        backend, interpret, params = self._resolve(kw)
        _, defines, _ = self._prepare(args, params)
        sweep = dict(self.sweep if sweep is None else sweep)
        if not sweep:
            return None
        dev = default_device(backend, interpret)
        return _tune.cached_winner(self.name, defines, sweep, dev.backend,
                                   dev.interpret)

    def __repr__(self):
        return (f"Op({self.name!r}, params={sorted(self.defaults)}, "
                f"sweep={sorted(self.sweep)}, vjp={self.vjp is not None})")


def define_op(name: str, *, builder: Callable, ref: Callable | None,
              derive_defines: Callable, vjp: OpVJP | None = None,
              sweep: Mapping | None = None, defaults: Mapping | None = None,
              public_outputs: int | None = None, early: Callable | None = None,
              pre: Callable | None = None, post: Callable | None = None,
              ref_params: Sequence[str] = (), tune_ref: Callable | None = None,
              example: Callable | None = None, doc: str | None = None,
              array_params: Sequence[str] = (), register: bool = True,
              analyze: str | None = None,
              shard: OpShard | None = None) -> Op:
    """Declare a public op over the unified kernel language; see :class:`Op`.

    ``example(rng) -> (args, params)`` supplies representative inputs so the
    registry-wide portability test can sweep every op across all backends
    against its ``ref`` without op-specific test code. ``array_params`` names
    params that may hold arrays (e.g. a carried state ``h0``): they are legal
    on the functional ``op.raw``/``op.tune`` paths but rejected on the
    differentiable call (arrays cannot be static custom_vjp params)."""
    op = Op(name, builder, ref, derive_defines, vjp=vjp, sweep=sweep,
            defaults=defaults, public_outputs=public_outputs, early=early,
            pre=pre, post=post, ref_params=ref_params, tune_ref=tune_ref,
            example=example, doc=doc, array_params=array_params,
            analyze=analyze, shard=shard)
    if register:
        # silent overwrites are the same collision class the PR-1 kernel-cache
        # fix eliminated: callers holding the first Op would diverge from the
        # registry with no error
        if name in _REGISTRY:
            raise ValueError(
                f"an op named {name!r} is already registered; pick a unique "
                "name or pass register=False to keep it out of the registry")
        _REGISTRY[name] = op
    return op
