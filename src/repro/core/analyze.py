"""Static analysis of unified-language kernel specs.

The language's portability claim — one ``body(ctx, *tiles)`` expands
identically to jnp/loops/pallas — only holds for programs whose semantics do
not depend on what a backend happens to do with memory the contract leaves
undefined. The jnp/loops expansions zero-fill output blocks and scratch, so a
kernel that forgets its ``reduce_first`` init *passes* there and corrupts on a
real TPU, where first-visit contents are garbage. This module is the
machine-checked safety net: a verifier that runs on every kernel build
(mirroring how compiler IR verifiers gate each pass).

Two complementary analyses:

``check_grid_invariants(spec)``
    Concrete-grid enumeration of every tile's index map: bounds
    (``BOUNDS_INDEX``), write races — distinct (outer x slot) cells mapping
    to one output block (``RACE_PARALLEL_WRITE``), index maps depending on
    accumulated reduce axes (``SEMANTICS_ACC_INDEX``), and blocks never
    visited (``COVERAGE_UNWRITTEN``). These are *certain* bugs and raise at
    ``Spec`` construction (``lang.Spec.__post_init__`` delegates here).

``trace_body(spec, defines)`` + ``check_body(spec, events)``
    An abstract interpretation of the kernel body: the body runs once under
    ``jax.eval_shape`` with a recording ``_RecCtx``/``_RecRef`` that logs
    every ref read/write together with the active ``when``/``cell_when``
    predicate context (``is_first``/``reduce_first(d)``/... become symbolic
    tokens; data- or grid-dependent predicates are *opaque* — they may skip).
    From the event log:

      * ``LIVENESS_SCRATCH_UNINIT`` — scratch read with no write that is
        guaranteed on the first reduce visit (missing ``reduce_first`` init).
      * ``COVERAGE_SKIP_NO_INIT`` — an output block whose every write sits
        under a skippable predicate, with no guaranteed first-visit init and
        no guaranteed last-visit flush (the block can be left undefined); or
        an output read before any guaranteed write (read-modify-write into
        undefined first-visit contents).
      * ``SEMANTICS_PARALLEL_CARRIED`` — a ``dimension_semantics`` override
        marks a reduce axis ``"parallel"`` while scratch or an output
        accumulation carries a dependence along it.

Soundness of the "guaranteed init" rule: ``is_first`` implies every
``reduce_first(d)``, so a write whose whole predicate context is drawn from
``{is_first, reduce_first(*)}`` executes on the very first visit of the
reduce space — after which the ref (scratch persists across the whole space;
an accumulated output block across its own visits) is defined forever. For a
block of an output accumulating over axes ``A``, the tags guaranteed on the
*block's* first (resp. last) visit are ``reduce_first(d)`` (resp.
``reduce_last(d)``) for ``d`` in ``A`` — plus ``is_first``/``is_last`` only
when ``A`` is the full reduce space.

Findings carry a stable ``code`` (also embedded ``[CODE]`` in the message);
``AnalysisError`` subclasses ``ValueError`` so the autotuner's
skip-invalid-candidates handling keeps working. Strictness is a process
knob (``$REPRO_ANALYZE`` / :func:`set_analysis_mode`, per-build override via
``Device.build_kernel(..., analyze=...)``):

  ``off``     skip body analysis (grid invariants still guard Spec build)
  ``warn``    report every finding as an :class:`AnalysisWarning`
  ``error``   raise on error findings, warn on coverage ones   (default)
  ``strict``  raise on any finding (what ``repro.lint_kernels --strict`` uses)
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import os
import warnings
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ANALYZE_MODES",
    "AnalysisError",
    "AnalysisWarning",
    "CostReport",
    "DEFAULT_VMEM_BUDGET",
    "Finding",
    "Report",
    "analysis_mode",
    "analyze_spec",
    "check_body",
    "check_built_spec",
    "check_grid_invariants",
    "check_semantics",
    "check_shard_binding",
    "estimate_cost",
    "estimate_flops",
    "set_analysis_mode",
    "trace_body",
    "vmem_budget",
    "vmem_footprint",
]

ANALYZE_MODES = ("off", "warn", "error", "strict")

# finding code -> severity; "error" findings are certain (or near-certain)
# cross-backend divergence, "coverage" findings are may-leave-undefined
# hazards gated by the strictness knob
SEVERITY = {
    "BOUNDS_INDEX": "error",
    "BOUNDS_HALO": "error",
    "BOUNDS_TABLE": "error",
    "BOUNDS_SCRATCH": "error",
    "RACE_PARALLEL_WRITE": "error",
    "SEMANTICS_ACC_INDEX": "error",
    "COVERAGE_UNWRITTEN": "error",
    "LIVENESS_SCRATCH_UNINIT": "error",
    "SEMANTICS_PARALLEL_CARRIED": "error",
    "COVERAGE_SKIP_NO_INIT": "coverage",
    "TRACE_INCOMPLETE": "coverage",
    # -- mesh-extended grid (ShardAxis bindings) --
    "RACE_MESH_WRITE": "error",
    "COLLECTIVE_UNDECLARED": "error",
    # -- static cost model (performance findings) --
    "VMEM_OVERFLOW": "error",
    "FOOTPRINT_NEAR_LIMIT": "coverage",
    "REDUNDANT_FETCH": "coverage",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer verdict: a stable code + the offending spec/ref/message."""

    code: str
    spec: str
    subject: str  # tile/scratch name (or "" for spec-level findings)
    message: str

    @property
    def severity(self) -> str:
        return SEVERITY.get(self.code, "error")

    def __str__(self):
        return f"[{self.code}] kernel {self.spec!r}: {self.message}"


class AnalysisError(ValueError):
    """A rejected kernel spec. Subclasses ValueError on purpose: autotune
    treats build-time ValueErrors as skippable invalid candidates."""

    def __init__(self, findings):
        self.findings = tuple(findings)
        super().__init__("\n".join(str(f) for f in self.findings))


class AnalysisWarning(UserWarning):
    """A non-fatal analyzer finding (coverage class, or warn mode)."""


@dataclasses.dataclass
class Report:
    """All findings for one spec + the dispatch policy per strictness mode."""

    spec: str
    findings: list

    @property
    def errors(self):
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.findings

    def emit(self, mode: str) -> None:
        """Raise/warn per the strictness mode (see module docstring)."""
        if mode not in ANALYZE_MODES:
            raise ValueError(
                f"unknown analyze mode {mode!r}; expected one of {ANALYZE_MODES}")
        if mode == "off" or not self.findings:
            return
        if mode == "strict" and self.findings:
            raise AnalysisError(self.findings)
        if mode == "error" and self.errors:
            raise AnalysisError(self.errors)
        for f in self.findings:
            if mode == "warn" or f.severity != "error":
                warnings.warn(str(f), AnalysisWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Strictness knob
# ---------------------------------------------------------------------------

_MODE_OVERRIDE: str | None = None


def analysis_mode() -> str:
    """The process-wide strictness mode: :func:`set_analysis_mode` override,
    else ``$REPRO_ANALYZE``, else ``"error"``."""
    if _MODE_OVERRIDE is not None:
        return _MODE_OVERRIDE
    mode = os.environ.get("REPRO_ANALYZE", "error")
    if mode not in ANALYZE_MODES:
        raise ValueError(
            f"REPRO_ANALYZE={mode!r} is not an analyze mode; expected one "
            f"of {ANALYZE_MODES}")
    return mode


def set_analysis_mode(mode: str | None) -> str | None:
    """Override the process-wide mode (None restores ``$REPRO_ANALYZE``).
    Returns the previous override so callers can restore it."""
    global _MODE_OVERRIDE
    if mode is not None and mode not in ANALYZE_MODES:
        raise ValueError(
            f"unknown analyze mode {mode!r}; expected one of {ANALYZE_MODES}")
    prev, _MODE_OVERRIDE = _MODE_OVERRIDE, mode
    return prev


# ---------------------------------------------------------------------------
# Concrete-grid invariants (index-map enumeration)
# ---------------------------------------------------------------------------

def _bounds_detail(bi, nb):
    for ax, (i, n) in enumerate(zip(bi, nb)):
        if not 0 <= i < n:
            return f"axis {ax}: block index {i} not in [0, {n})"
    return f"rank {len(bi)} != block-grid rank {len(nb)}"


def _table_findings(spec):
    """Structural validation of every ``Tile(index_tile=...)`` declaration:
    the dynamic block index must come from an integer INPUT tile whose block
    is all-ones (its block index IS the element it contributes), naming a
    real axis of the gathered tile. Run-time values are clamped by the
    expansions, so a well-formed declaration cannot read out of bounds —
    malformed declarations are certain bugs (BOUNDS_TABLE)."""
    findings = []
    in_tiles = {t.name: t for t in spec.inputs}

    def bad(t, msg):
        findings.append(Finding(
            "BOUNDS_TABLE", spec.name, t.name,
            f"tile {t.name!r}: {msg}"))

    for t in spec.outputs:
        if getattr(t, "index_tile", None) is not None:
            bad(t, "index_tile= is input-only (a run-time write destination "
                   "would race undetectably)")
    for t in spec.inputs:
        it = getattr(t, "index_tile", None)
        if it is None:
            continue
        if (not isinstance(it, tuple)) or len(it) != 2:
            bad(t, f"index_tile must be a (table_name, axis) pair, got {it!r}")
            continue
        tname, axis = it
        if t.halo is not None and any(t.resolved_halo()):
            bad(t, "halo= and index_tile= cannot combine (the windowed "
                   "lowering would reorder the gathered axis)")
        if not isinstance(axis, int) or not 0 <= axis < len(t.shape):
            bad(t, f"index_tile axis {axis!r} out of range for rank-"
                   f"{len(t.shape)} tile")
            continue
        table = in_tiles.get(tname)
        if table is None or table is t:
            bad(t, f"index_tile names {tname!r}, which is not another "
                   "input tile of this kernel")
            continue
        if getattr(table, "index_tile", None) is not None:
            bad(t, f"table tile {tname!r} is itself gathered via "
                   "index_tile — tables must have static index maps")
        if not np.issubdtype(np.dtype(table.dtype), np.integer):
            bad(t, f"table tile {tname!r} dtype {table.dtype} is not an "
                   "integer type")
        if any(b != 1 for b in table.resolved_block()):
            bad(t, f"table tile {tname!r} block {table.resolved_block()} "
                   "must be all-ones so its block index selects exactly "
                   "the element the gather reads")
    return findings


def check_grid_invariants(spec):
    """Enumerate every tile's index map over the whole grid.

    Returns ``(findings, input_reduce_invariant)`` — the latter is the
    per-input hoisting mask the jnp expansion needs (computed here so the
    grid is walked exactly once per tile). All findings from this pass are
    errors; ``lang.Spec.__post_init__`` raises on any."""
    findings = []
    k = len(spec.grid) - len(spec.reduce_axes)
    zero_r = (0,) * len(spec.reduce_axes)

    input_reduce_invariant = []
    tab_findings = _table_findings(spec)
    if tab_findings:
        return tab_findings, input_reduce_invariant
    for t in spec.inputs:
        blk = t.resolved_block()
        idx = t.resolved_index(spec.grid)
        nb = tuple(s // bb for s, bb in zip(t.shape, blk))
        gax = None if t.index_tile is None else t.index_tile[1]
        for ax, (r, s) in enumerate(zip(t.resolved_halo(), t.shape)):
            # a radius past the array extent would wrap more than one full
            # period (or clamp a window wider than the data) — certainly a
            # mis-sized stencil, on every backend
            if r > s:
                findings.append(Finding(
                    "BOUNDS_HALO", spec.name, t.name,
                    f"input tile {t.name!r}: halo radius {r} on axis {ax} "
                    f"exceeds the array extent {s} — the fetched window "
                    "would span more than one full period of the data"))
                return findings, input_reduce_invariant
        inv = True
        bi0 = None
        for cell in np.ndindex(*spec.grid):
            bi = tuple(int(i) for i in idx(*cell))
            if gax is not None and len(bi) == len(nb):
                # the static map's value at the gathered axis is an ignored
                # placeholder: the run-time table value is clamped in-range
                # by construction, so only the other axes are bounds-checked
                bi = bi[:gax] + (0,) + bi[gax + 1:]
            if len(bi) != len(nb) or any(
                    not (0 <= i < n) for i, n in zip(bi, nb)):
                findings.append(Finding(
                    "BOUNDS_INDEX", spec.name, t.name,
                    f"input tile {t.name!r}: index map returned block "
                    f"{bi} for grid cell {cell}, outside the {nb} block "
                    f"grid (shape {t.shape}, block {blk}; "
                    f"{_bounds_detail(bi, nb)})"))
                return findings, input_reduce_invariant
            if inv and spec.reduce_axes:
                # C-order walk: each outer group starts at reduce ids 0, so
                # that cell's bi IS the group's reference — one index-map
                # call per cell, not two
                if cell[k:] == zero_r:
                    bi0 = bi
                elif bi != bi0:
                    inv = False
        input_reduce_invariant.append(inv)

    # a gathered tile's block index is only reduce-invariant when its own
    # static map AND the table it reads are — a table indexed by a reduce id
    # (the paged block walk) makes the gather a fresh fetch every step
    name_to_i = {t.name: i for i, t in enumerate(spec.inputs)}
    for i, t in enumerate(spec.inputs):
        if t.index_tile is not None:
            ti = name_to_i[t.index_tile[0]]
            input_reduce_invariant[i] = (
                input_reduce_invariant[i] and input_reduce_invariant[ti])

    for i, s in enumerate(spec.scratch):
        if any(d <= 0 for d in s.shape):
            findings.append(Finding(
                "BOUNDS_SCRATCH", spec.name, f"scratch[{i}]",
                f"scratch[{i}]: shape {s.shape} has a non-positive "
                "dimension"))

    # Per-output reduce granularity: an output accumulates over SOME of the
    # reduce axes (all by default; none when streamed) and its index map may
    # depend only on the REMAINING axes — the accumulate-then-flush contract
    # needs a destination that is stable along exactly the accumulated axes.
    # Distinct (outer x non-accumulated) cells must write distinct blocks,
    # covering every block exactly once.
    for t in spec.outputs:
        blk = t.resolved_block()
        idx = t.resolved_index(spec.grid)
        nb = tuple(s // b for s, b in zip(t.shape, blk))
        nblocks = math.prod(nb)
        slot_axes = spec.output_slot_axes(t)
        kind = "stream output" if t.stream else "output"
        seen: dict[tuple, tuple] = {}
        visited: set[tuple] = set()
        for cell in np.ndindex(*spec.grid):
            bi = tuple(int(i) for i in idx(*cell))
            if len(bi) != len(nb) or any(
                    not (0 <= i < n) for i, n in zip(bi, nb)):
                findings.append(Finding(
                    "BOUNDS_INDEX", spec.name, t.name,
                    f"{kind} tile {t.name!r}: index map returned block "
                    f"{bi} for grid cell {cell}, outside the {nb} block "
                    f"grid (shape {t.shape}, block {blk}; "
                    f"{_bounds_detail(bi, nb)})"))
                return findings, input_reduce_invariant
            key = cell[:k] + tuple(cell[a] for a in slot_axes)
            if key in seen:
                if seen[key] != bi:
                    findings.append(Finding(
                        "SEMANTICS_ACC_INDEX", spec.name, t.name,
                        f"output tile {t.name!r}: index map depends on reduce "
                        f"axes it accumulates over (cell {cell} -> {bi}, "
                        f"expected {seen[key]}); exclude those axes via "
                        "Tile(reduce=...) or stream=True"))
                    return findings, input_reduce_invariant
            else:
                if bi in visited:
                    hint = ("streamed outputs must write a distinct block "
                            "per grid cell" if t.stream else
                            "grid-carried accumulation needs an explicit "
                            "reduce axis (Spec(reduce_axes=...) + "
                            "Tile(reduce=...)) — implicit revisits are "
                            "rejected")
                    findings.append(Finding(
                        "RACE_PARALLEL_WRITE", spec.name, t.name,
                        f"{kind} tile {t.name!r} block {bi} visited more "
                        f"than once by distinct cells; {hint}"))
                    return findings, input_reduce_invariant
                seen[key] = bi
                visited.add(bi)
        if len(seen) != nblocks:
            findings.append(Finding(
                "COVERAGE_UNWRITTEN", spec.name, t.name,
                f"{kind} tile {t.name!r}: {len(seen)} blocks visited but "
                f"{nblocks} exist; kernel would leave garbage"))
            return findings, input_reduce_invariant

    findings.extend(check_shard_binding(spec))
    return findings, input_reduce_invariant


def check_shard_binding(spec):
    """Cross-shard semantics of a ShardAxis binding over the MESH-EXTENDED
    grid: the local grid replicated ``extent`` times along the bound reduce
    axis, one replica per device.

    Two hazards a single-shard walk cannot see:

    * an output that ACCUMULATES over the bound axis holds a per-shard
      partial — without a declared collective the partials never meet and
      every shard silently returns a different wrong answer
      (``COLLECTIVE_UNDECLARED``);
    * an output whose index map SELECTS along the bound axis (a slot axis)
      writes blocks owned by other shards as data rotates — every shard
      writes the same local block coordinates, which is a write race over the
      extended grid unless the output is declared shard-resident
      (``sharded_outputs``), i.e. its partials ride the declared collective
      home (``RACE_MESH_WRITE``).
    """
    sh = getattr(spec, "shard", None)
    if sh is None or sh.extent <= 1:
        return []
    findings = []
    if sh.collective == "ppermute" and not sh.rotate:
        findings.append(Finding(
            "COLLECTIVE_UNDECLARED", spec.name, "",
            f"shard axis {sh.axis} on mesh axis {sh.mesh_axis!r} declares a "
            "ppermute ring but rotates no input tiles — no data ever "
            "crosses shards, so the ring reduces over the same local chunk "
            f"{sh.extent} times"))
    for t in spec.outputs:
        acc = spec.output_reduce_axes(t)
        if sh.axis in acc:
            if sh.collective is None:
                findings.append(Finding(
                    "COLLECTIVE_UNDECLARED", spec.name, t.name,
                    f"output tile {t.name!r} accumulates over shard axis "
                    f"{sh.axis} ({sh.extent} shards on mesh axis "
                    f"{sh.mesh_axis!r}) but the binding declares no "
                    "collective — per-shard partials would never be "
                    "combined"))
        elif sh.axis in spec.output_slot_axes(t):
            if t.name not in sh.sharded_outputs:
                findings.append(Finding(
                    "RACE_MESH_WRITE", spec.name, t.name,
                    f"output tile {t.name!r} selects blocks along shard "
                    f"axis {sh.axis}: all {sh.extent} shards on mesh axis "
                    f"{sh.mesh_axis!r} write the same local block "
                    "coordinates for different chunks of the data — a "
                    "cross-shard write race unless the output is declared "
                    "in ShardAxis.sharded_outputs (partials ride the "
                    "collective back to their owner)"))
    return findings


def check_semantics(spec):
    """``dimension_semantics`` consistency: an axis the pallas pipeline may
    reorder ("parallel") must not carry sequential state along it."""
    sem = getattr(spec, "dimension_semantics", None)
    if not sem:
        return []
    findings = []
    for a, s in enumerate(sem):
        if s != "parallel" or a not in spec.reduce_axes:
            continue
        carried = ["scratch"] if spec.scratch else []
        carried += [f"output {t.name!r}" for t in spec.outputs
                    if a in spec.output_reduce_axes(t)]
        if carried:
            findings.append(Finding(
                "SEMANTICS_PARALLEL_CARRIED", spec.name, f"axis {a}",
                f"dimension_semantics marks reduce axis {a} \"parallel\" "
                f"but {', '.join(carried)} carries a sequential dependence "
                "along it (its reduce_id feeds carried state); declare the "
                "axis \"arbitrary\""))
    return findings


# ---------------------------------------------------------------------------
# Abstract interpretation of the body (recording trace)
# ---------------------------------------------------------------------------

class _Opaque:
    """A predicate the analyzer cannot prove (data/grid-dependent, or any
    boolean algebra over symbolic tokens). Opaque guards may skip."""

    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __and__(self, other):
        return self

    __rand__ = __or__ = __ror__ = __xor__ = __rxor__ = __and__

    def __invert__(self):
        return self

    def __repr__(self):
        return "<opaque predicate>"


_OPAQUE = _Opaque()


class _Pred:
    """A symbolic predicate token: the analyzer knows exactly when it holds
    (``("is_first",)``, ``("reduce_first", d)``, ...). Any algebra over it
    degrades to opaque — conservative, never unsound."""

    __slots__ = ("key",)

    def __init__(self, key):
        self.key = key

    def __and__(self, other):
        return _OPAQUE

    __rand__ = __or__ = __ror__ = __xor__ = __rxor__ = __and__

    def __invert__(self):
        return _OPAQUE

    def __bool__(self):
        raise TypeError(
            f"predicate {self.key} is symbolic under analysis (and traced "
            "at run time): use ctx.when/ctx.cell_when, not Python `if`")

    def __repr__(self):
        return f"<pred {self.key}>"


@dataclasses.dataclass(frozen=True)
class _Event:
    op: str       # "read" | "write"
    kind: str     # "input" | "output" | "scratch"
    name: str
    ctx: tuple    # predicate-context tags active at the access


class _RecRef:
    """Recording TileRef: same read/write surface, logs every access with
    the active predicate context, carries abstract values so the body keeps
    tracing."""

    __slots__ = ("_trace", "kind", "name", "_value")

    def __init__(self, trace, kind, name, value):
        self._trace = trace
        self.kind = kind
        self.name = name
        self._value = value

    def __getitem__(self, idx):
        self._trace.record("read", self)
        return self._value[idx]

    def __setitem__(self, idx, val):
        self._trace.record("write", self)
        if idx is Ellipsis or idx == slice(None):
            self._value = jnp.broadcast_to(
                val, self._value.shape).astype(self._value.dtype)
        else:
            self._value = self._value.at[idx].set(val)

    @property
    def value(self):
        self._trace.record("read", self)
        return self._value

    @property
    def shape(self):
        return self._value.shape

    @property
    def dtype(self):
        return self._value.dtype


class _RecCtx:
    """Recording Ctx: same surface as :class:`lang.Ctx`, but reduce-position
    predicates are symbolic tokens and ``when``/``cell_when`` run their thunk
    unconditionally while pushing the classified predicate onto the context
    stack. Backend flags are all False (bodies are backend-agnostic by
    contract; a backend-branching body traces its generic path)."""

    backend = "analyze"
    is_pallas = is_jnp = is_loops = False

    def __init__(self, trace, spec, defines, gids):
        self._trace = trace
        self._spec = spec
        self.D = defines
        self._gids = tuple(gids)
        self.grid = spec.grid
        self._reduce_axes = tuple(spec.reduce_axes)
        self.scratch = ()

    # --- grid ids ---------------------------------------------------------
    def outer_id(self, d: int):
        return self._gids[d]

    def outer_dim(self, d: int) -> int:
        return self.grid[d]

    def reduce_id(self, d: int = 0):
        return self._gids[self._reduce_axes[d]]

    def reduce_dim(self, d: int = 0) -> int:
        return self.grid[self._reduce_axes[d]]

    # --- reduce-position predicates: symbolic tokens ----------------------
    def reduce_first(self, d: int = 0):
        return _Pred(("reduce_first", int(d)))

    def reduce_last(self, d: int = 0):
        return _Pred(("reduce_last", int(d)))

    @property
    def is_first(self):
        return True if not self._reduce_axes else _Pred(("is_first",))

    @property
    def is_last(self):
        return True if not self._reduce_axes else _Pred(("is_last",))

    # --- predicated execution --------------------------------------------
    def when(self, pred):
        return self._trace.guard(pred, "when")

    def cell_when(self, pred):
        return self._trace.guard(pred, "cell_when")

    # --- the rest of the Ctx surface --------------------------------------
    def lane_ids(self, n: int):
        return jnp.arange(n)

    def barrier(self, *_fence):
        return None

    def cache(self, ref):
        return ref[...]

    def private(self, value):
        return value


class _Trace:
    """The event log + predicate-context stack shared by one body run."""

    def __init__(self):
        self.events: list[_Event] = []
        self._stack: list[tuple] = []
        self._serial = itertools.count()

    def record(self, op, ref):
        self.events.append(
            _Event(op, ref.kind, ref.name, tuple(self._stack)))

    def guard(self, pred, kind):
        """The when/cell_when decorator under analysis: classify the
        predicate, push it, run the thunk unconditionally (every guarded
        path is traced), pop."""
        if isinstance(pred, _Pred):
            tag = pred.key
        elif isinstance(pred, (bool, np.bool_)):
            # a defines-derived compile-time constant: True guards nothing,
            # False statically removes the code (matches the real Ctx)
            tag = None if pred else False
        elif pred is _OPAQUE:
            tag = (kind, next(self._serial))
        else:
            try:  # concrete scalars fold like Python bools...
                tag = None if bool(pred) else False
            except Exception:  # ...tracers (grid/data-dependent) are opaque
                tag = (kind, next(self._serial))

        def deco(fn):
            if tag is False:
                return fn
            if tag is not None:
                self._stack.append(tag)
            try:
                fn()
            finally:
                if tag is not None:
                    self._stack.pop()
            return fn

        return deco


def trace_body(spec, defines=None):
    """Run the kernel body once under ``jax.eval_shape`` with recording
    refs/ctx; returns the ordered read/write event log. No real compute —
    block values are abstract, grid ids are traced i32 scalars (so
    grid-dependent predicates stay opaque rather than folding for one cell)."""
    defines = defines if defines is not None else SimpleNamespace()
    trace = _Trace()
    i32 = jnp.int32

    def run(gids, ins, outs, scr):
        ctx = _RecCtx(trace, spec, defines, gids)
        in_refs = [_RecRef(trace, "input", t.name, v)
                   for t, v in zip(spec.inputs, ins)]
        out_refs = [_RecRef(trace, "output", t.name, v)
                    for t, v in zip(spec.outputs, outs)]
        ctx.scratch = tuple(
            _RecRef(trace, "scratch", f"scratch[{i}]", v)
            for i, v in enumerate(scr))
        spec.body(ctx, *in_refs, *out_refs)
        return ()

    jax.eval_shape(
        run,
        [jax.ShapeDtypeStruct((), i32) for _ in spec.grid],
        [jax.ShapeDtypeStruct(t.body_block(), t.dtype)
         for t in spec.inputs],
        [jax.ShapeDtypeStruct(t.resolved_block(), t.dtype)
         for t in spec.outputs],
        [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in spec.scratch],
    )
    return trace.events


def _guaranteed(ctx_tags, allowed) -> bool:
    """True if an access under these tags is guaranteed to execute whenever
    every predicate in ``allowed`` holds (i.e. every guard is provable)."""
    return all(tag in allowed for tag in ctx_tags)


def _first_last_sets(spec, t):
    """The predicate tags guaranteed to hold on an output block's first and
    last visit (see module docstring)."""
    acc = set(spec.output_reduce_axes(t))
    n_red = len(spec.reduce_axes)
    first = {("reduce_first", d) for d, a in enumerate(spec.reduce_axes)
             if a in acc}
    last = {("reduce_last", d) for d, a in enumerate(spec.reduce_axes)
            if a in acc}
    if n_red == 0 or acc == set(spec.reduce_axes):
        first.add(("is_first",))
        last.add(("is_last",))
    return first, last


_SCRATCH_FIRST_BASE = frozenset([("is_first",)])


def check_body(spec, events):
    """Liveness/coverage verdicts from one body trace (see module docstring)."""
    findings = []
    n_red = len(spec.reduce_axes)
    scratch_first = set(_SCRATCH_FIRST_BASE) | {
        ("reduce_first", d) for d in range(n_red)}

    def read_before_init(name, firstset, code, what):
        """Walk the ref's events in order: a read is safe once a write
        guaranteed on the first visit has happened, or when an earlier write
        dominates it within the same guarded region (its context tags are a
        subset of the read's)."""
        init = False
        prior_writes: list[frozenset] = []
        for ev in events:
            if ev.name != name:
                continue
            if ev.op == "write":
                if _guaranteed(ev.ctx, firstset):
                    init = True
                prior_writes.append(frozenset(ev.ctx))
            elif not init:
                rc = set(ev.ctx)
                if any(w <= rc for w in prior_writes):
                    continue
                findings.append(Finding(code, spec.name, name, what(ev)))
                return

    for i, _s in enumerate(spec.scratch):
        name = f"scratch[{i}]"
        read_before_init(
            name, scratch_first, "LIVENESS_SCRATCH_UNINIT",
            lambda ev, name=name: (
                f"{name} is read (context {list(ev.ctx) or 'unconditional'}) "
                "before any write guaranteed on the first reduce visit; "
                "first-visit scratch contents are undefined on a real TPU — "
                "initialize under ctx.when(ctx.is_first) / ctx.reduce_first"))

    for t in spec.outputs:
        firstset, lastset = _first_last_sets(spec, t)
        evs = [ev for ev in events if ev.kind == "output" and ev.name == t.name]
        if not evs:
            continue  # never touched: the grid walk already flags UNWRITTEN
        writes = [ev for ev in evs if ev.op == "write"]
        has_init = any(_guaranteed(ev.ctx, firstset) for ev in writes)
        has_flush = any(_guaranteed(ev.ctx, lastset) for ev in writes)
        if writes and not (has_init or has_flush):
            ctxs = sorted({str(list(ev.ctx)) for ev in writes})
            findings.append(Finding(
                "COVERAGE_SKIP_NO_INIT", spec.name, t.name,
                f"output tile {t.name!r} is only written under skippable "
                f"predicates ({', '.join(ctxs)}): a block whose guards all "
                "skip is left undefined on a real TPU (zero-filled only on "
                "jnp/loops). Add a guaranteed init (ctx.is_first / "
                "ctx.reduce_first) or flush (ctx.is_last / ctx.reduce_last)"))
        read_before_init(
            t.name, firstset, "COVERAGE_SKIP_NO_INIT",
            lambda ev, t=t: (
                f"output tile {t.name!r} is read (context "
                f"{list(ev.ctx) or 'unconditional'}) before any write "
                "guaranteed on its block's first visit; first-visit output "
                "contents are undefined on a real TPU — initialize under "
                "ctx.reduce_first of an accumulated axis"))

    return findings


# ---------------------------------------------------------------------------
# Static cost model: VMEM footprint, bytes moved, FLOPs
# ---------------------------------------------------------------------------

#: Per-core VMEM working-set budget (bytes). TPU cores have ~16 MB of VMEM;
#: override with ``$REPRO_VMEM_BUDGET`` (plain bytes or a K/M/G suffix).
DEFAULT_VMEM_BUDGET = 16 * 2**20

#: Fraction of the budget above which FOOTPRINT_NEAR_LIMIT warns.
NEAR_LIMIT_FRAC = 0.8

#: Grid sizes past this are not walked cell-by-cell; bytes fall back to the
#: every-visit-fetches upper bound and REDUNDANT_FETCH detection is skipped.
WALK_CELL_LIMIT = 1 << 20


def vmem_budget() -> int:
    """The configured VMEM budget: ``$REPRO_VMEM_BUDGET`` (bytes, or with a
    K/M/G suffix, e.g. ``128M``), else :data:`DEFAULT_VMEM_BUDGET`."""
    raw = os.environ.get("REPRO_VMEM_BUDGET", "").strip()
    if not raw:
        return DEFAULT_VMEM_BUDGET
    mult = {"K": 2**10, "M": 2**20, "G": 2**30}.get(raw[-1].upper(), 1)
    digits = raw[:-1] if mult != 1 else raw
    try:
        val = int(digits) * mult
    except ValueError:
        raise ValueError(
            f"REPRO_VMEM_BUDGET={raw!r} is not a byte count (use plain "
            "bytes or a K/M/G suffix, e.g. 16M)") from None
    if val <= 0:
        raise ValueError(f"REPRO_VMEM_BUDGET={raw!r} must be positive")
    return val


def _itemsize(dtype) -> int:
    return int(jnp.dtype(dtype).itemsize)


def vmem_footprint(spec) -> tuple[int, dict]:
    """Per-grid-cell resident VMEM bytes: every tile's block (double-buffered
    when the pipeline streams new blocks under it — i.e. the grid has more
    than one cell and the tile is blocked rather than whole-array) plus
    scratch. Cheap (no grid walk): safe to run on every kernel build."""
    ncells = math.prod(spec.grid) if spec.grid else 1
    detail = {}
    for t in list(spec.inputs) + list(spec.outputs):
        blk = t.resolved_block()
        # the body sees the block grown by any halo fringe — that window is
        # what actually sits in VMEM per cell
        nbytes = math.prod(t.body_block()) * _itemsize(t.dtype)
        mult = 1 if (ncells == 1 or blk == tuple(t.shape)) else 2
        detail[t.name] = nbytes * mult
    for i, s in enumerate(spec.scratch):
        detail[f"scratch[{i}]"] = math.prod(s.shape) * _itemsize(s.dtype)
    return sum(detail.values()), detail


def _footprint_findings(spec, *, budget=None):
    """VMEM_OVERFLOW / FOOTPRINT_NEAR_LIMIT findings for one spec."""
    budget = vmem_budget() if budget is None else int(budget)
    total, detail = vmem_footprint(spec)
    top = ", ".join(f"{k}={v}" for k, v in sorted(
        detail.items(), key=lambda kv: -kv[1])[:4])
    if total > budget:
        return [Finding(
            "VMEM_OVERFLOW", spec.name, "",
            f"static VMEM footprint {total} B exceeds the budget {budget} B "
            f"(largest blocks: {top}); shrink tile blocks or raise "
            "$REPRO_VMEM_BUDGET")]
    if total > NEAR_LIMIT_FRAC * budget:
        return [Finding(
            "FOOTPRINT_NEAR_LIMIT", spec.name, "",
            f"static VMEM footprint {total} B is above "
            f"{int(NEAR_LIMIT_FRAC * 100)}% of the budget {budget} B "
            f"(largest blocks: {top})")]
    return []


def _runs(seq) -> int:
    """Number of maximal runs of equal consecutive elements."""
    it = iter(seq)
    try:
        prev = next(it)
    except StopIteration:
        return 0
    n = 1
    for x in it:
        if x != prev:
            n += 1
            prev = x
    return n


def _sweep_refetches(sweep) -> bool:
    """True if one outer cell's ordered reduce sweep ``[(rcell, bi), ...]``
    re-fetches a block it already held, *excluding* inherent re-reads caused
    by an interleaved independent axis (blocked-GEMM reuse). Axis ``p`` is
    *dependent* for this tile if two sweep entries differing only at ``p``
    map to different blocks; entries are grouped by the non-dependent axes'
    ids, and a group whose ordered block sequence has more runs than distinct
    blocks thrashed a block it will fetch again."""
    if len(sweep) < 2:
        return False
    nred = len(sweep[0][0])
    dep = set()
    for p in range(nred):
        seen = {}
        for rcell, bi in sweep:
            key = rcell[:p] + rcell[p + 1:]
            if key in seen:
                if seen[key] != bi:
                    dep.add(p)
                    break
            else:
                seen[key] = bi
    groups = {}
    for rcell, bi in sweep:
        gkey = tuple(v for q, v in enumerate(rcell) if q not in dep)
        groups.setdefault(gkey, []).append(bi)
    return any(_runs(seq) > len(set(seq)) for seq in groups.values())


def _walk_costs(spec):
    """One C-order walk of the concrete grid (the Pallas iteration order):
    per-tile block-fetch runs -> HBM bytes moved, plus REDUNDANT_FETCH
    detection on inputs whose reduce sweep re-fetches a block it already
    held. Pallas elides the copy when the block index repeats consecutively,
    so bytes = runs x block bytes; accumulated output blocks revisited
    non-consecutively are also read back (read-modify-write)."""
    grid = tuple(spec.grid)
    reduce_axes = tuple(spec.reduce_axes)
    outer_axes = [d for d in range(len(grid)) if d not in reduce_axes]
    findings = []
    bytes_in = 0
    bytes_out = 0

    cells = list(np.ndindex(*grid)) if grid else [()]
    cells = [tuple(int(g) for g in c) for c in cells]

    for t in spec.inputs:
        idx = t.resolved_index(grid)
        # halo tiles fetch the overlapped window, not the bare block: the
        # amplification (b + 2r) / b per axis is real HBM traffic
        blk_bytes = math.prod(t.body_block()) * _itemsize(t.dtype)
        if t.index_tile is not None:
            # the gathered block index is run-time data: no consecutive-
            # index elision credit can be proven, so every visiting cell is
            # charged a fetch (the price of the indirection), and the
            # REDUNDANT_FETCH heuristic — which reasons over the STATIC
            # walk — is skipped
            bytes_in += len(cells) * blk_bytes
            continue
        walk = [tuple(idx(*c)) for c in cells]
        bytes_in += _runs(walk) * blk_bytes
        if reduce_axes and len(cells) > 1:
            sweeps = {}
            for c, bi in zip(cells, walk):
                ocell = tuple(c[d] for d in outer_axes)
                rcell = tuple(c[a] for a in reduce_axes)
                sweeps.setdefault(ocell, []).append((rcell, bi))
            if any(_sweep_refetches(sw) for sw in sweeps.values()):
                findings.append(Finding(
                    "REDUNDANT_FETCH", spec.name, t.name,
                    f"input tile {t.name!r}: the reduce sweep re-fetches a "
                    "block it already held — the index map revisits a block "
                    "after moving off it. Reorder the reduce walk or hoist "
                    "the tile (a reduce-invariant map is hoisted "
                    "automatically on jnp)"))

    for t in spec.outputs:
        idx = t.resolved_index(grid)
        blk_bytes = math.prod(t.resolved_block()) * _itemsize(t.dtype)
        walk = [tuple(idx(*c)) for c in cells]
        runs = _runs(walk)
        bytes_out += runs * blk_bytes
        if spec.output_reduce_axes(t):
            # revisiting an accumulated block after moving off it re-reads it
            bytes_in += max(0, runs - len(set(walk))) * blk_bytes

    return bytes_in, bytes_out, findings


# -- FLOPs from an abstract body trace --------------------------------------

_ELEMENTWISE_PRIMS = frozenset([
    "add", "add_any", "sub", "mul", "div", "rem", "max", "min", "pow",
    "integer_pow", "neg", "abs", "sign", "exp", "exp2", "expm1", "log",
    "log1p", "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "square",
    "erf", "erfc", "sin", "cos", "tan", "atan2", "floor", "ceil", "round",
    "nextafter", "clamp",
])

_REDUCE_PRIMS = frozenset([
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "cumsum", "cummax", "cummin", "cumprod", "cumlogsumexp",
])

_CALL_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _is_float(aval) -> bool:
    return jnp.issubdtype(aval.dtype, jnp.floating) or \
        jnp.issubdtype(aval.dtype, jnp.complexfloating)


def _jaxpr_flops(jaxpr) -> int:
    """Floating-point operation count of one jaxpr. Deliberately simple:
    2*prod(out)*contraction for dot_general, 1/output element for
    elementwise, 1/input element for reductions, 0 for data movement.
    ``cond`` counts its widest branch, ``scan`` its body x length, ``while``
    its body once (a lower bound — trip counts are dynamic)."""
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            (lc, _rc), _batch = eqn.params["dimension_numbers"]
            lhs = eqn.invars[0].aval
            out = eqn.outvars[0].aval
            contract = math.prod(lhs.shape[d] for d in lc) if lc else 1
            total += 2 * math.prod(out.shape) * contract
        elif name == "cond":
            total += max((_jaxpr_flops(b.jaxpr)
                          for b in eqn.params["branches"]), default=0)
        elif name == "scan":
            total += int(eqn.params["length"]) * \
                _jaxpr_flops(eqn.params["jaxpr"].jaxpr)
        elif name == "while":
            total += _jaxpr_flops(eqn.params["body_jaxpr"].jaxpr)
        elif name in _ELEMENTWISE_PRIMS:
            out = eqn.outvars[0].aval
            if _is_float(out):
                total += math.prod(out.shape)
        elif name in _REDUCE_PRIMS:
            operand = eqn.invars[0].aval
            if _is_float(operand):
                total += math.prod(operand.shape)
        else:
            for key in _CALL_PARAM_KEYS:
                inner = eqn.params.get(key) if eqn.params else None
                if inner is not None:
                    total += _jaxpr_flops(getattr(inner, "jaxpr", inner))
                    break
    return total


class _CostTrace:
    """A :class:`_Trace` stand-in for FLOPs counting: guarded regions get
    *stable* ids — the path of per-nesting-level guard indices — so the same
    region keeps its id across repeated body runs with different enabled
    sets. ``enabled=None`` (discovery) runs every symbolic region; else only
    regions whose path is in the set run. ``record`` is a no-op (the jaxpr
    itself is the artifact)."""

    def __init__(self, enabled=None):
        self._enabled = enabled
        self._counters = [itertools.count()]
        self._path: tuple = ()
        self._stack: list = []          # _RecCtx compatibility
        self.regions: list[tuple[tuple, tuple]] = []   # (path, tag)

    def record(self, op, ref):
        pass

    def _enter(self, path):
        self._path = path
        self._counters.append(itertools.count())

    def _leave(self):
        self._counters.pop()
        self._path = self._path[:-1]

    def guard(self, pred, kind):
        idx = next(self._counters[-1])
        path = self._path + (idx,)
        if isinstance(pred, _Pred):
            tag = pred.key
        elif isinstance(pred, (bool, np.bool_)):
            tag = None if pred else False
        elif pred is _OPAQUE:
            tag = ("opaque",)
        else:
            try:
                tag = None if bool(pred) else False
            except Exception:
                tag = ("opaque",)

        def deco(fn):
            if tag is False:
                return fn
            if tag is None:  # unconditional: run, but keep nested ids stable
                self._enter(path)
                try:
                    fn()
                finally:
                    self._leave()
                return fn
            self.regions.append((path, tag))
            if self._enabled is None or path in self._enabled:
                self._enter(path)
                try:
                    fn()
                finally:
                    self._leave()
            return fn

        return deco


def _region_weight(spec, tag) -> float:
    """Fraction of grid cells a guarded region executes on. Symbolic
    first/last predicates hit one cell of their reduce space; opaque
    (data-dependent) guards count fully — a conservative upper bound."""
    red = tuple(spec.reduce_grid)
    if tag == ("is_first",) or tag == ("is_last",):
        return 1.0 / max(1, math.prod(red))
    if isinstance(tag, tuple) and len(tag) == 2 and \
            tag[0] in ("reduce_first", "reduce_last"):
        return 1.0 / max(1, red[tag[1]])
    return 1.0


def estimate_flops(spec, defines=None):
    """Static per-kernel FLOPs from the abstract body trace: the body is
    staged with :class:`_CostTrace` under ``jax.make_jaxpr`` once per
    (ancestor-closed) enabled-region set; each guarded region's marginal
    FLOPs are weighted by how often its predicate holds over the grid.
    Returns None when the body cannot be staged."""
    defines = defines if defines is not None else SimpleNamespace()
    i32 = jnp.int32
    gargs = [jax.ShapeDtypeStruct((), i32) for _ in spec.grid]
    iargs = [jax.ShapeDtypeStruct(t.body_block(), t.dtype)
             for t in spec.inputs]
    oargs = [jax.ShapeDtypeStruct(t.resolved_block(), t.dtype)
             for t in spec.outputs]
    sargs = [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in spec.scratch]

    def staged(trace):
        def run(gids, ins, outs, scr):
            ctx = _RecCtx(trace, spec, defines, gids)
            in_refs = [_RecRef(trace, "input", t.name, v)
                       for t, v in zip(spec.inputs, ins)]
            out_refs = [_RecRef(trace, "output", t.name, v)
                        for t, v in zip(spec.outputs, outs)]
            ctx.scratch = tuple(
                _RecRef(trace, "scratch", f"scratch[{i}]", v)
                for i, v in enumerate(scr))
            spec.body(ctx, *in_refs, *out_refs)
            return ()
        return run

    try:
        discovery = _CostTrace(None)
        jax.make_jaxpr(staged(discovery))(gargs, iargs, oargs, sargs)
        regions = discovery.regions

        memo: dict[frozenset, int] = {}

        def flops_with(enabled: frozenset) -> int:
            if enabled not in memo:
                trace = _CostTrace(enabled)
                jaxpr = jax.make_jaxpr(staged(trace))(
                    gargs, iargs, oargs, sargs)
                memo[enabled] = _jaxpr_flops(jaxpr.jaxpr)
            return memo[enabled]

        per_cell = float(flops_with(frozenset()))
        for path, tag in regions:
            ancestors = frozenset(
                p for p, _t in regions
                if len(p) < len(path) and p == path[:len(p)])
            marginal = flops_with(ancestors | {path}) - flops_with(ancestors)
            weight = _region_weight(spec, tag)
            for p, t in regions:
                if len(p) < len(path) and p == path[:len(p)]:
                    weight *= _region_weight(spec, t)
            per_cell += weight * max(0, marginal)
        ncells = math.prod(spec.grid) if spec.grid else 1
        return int(round(ncells * per_cell))
    except Exception:
        return None


@dataclasses.dataclass
class CostReport:
    """Static roofline terms for one built spec."""

    spec: str
    grid: tuple
    cells: int
    vmem_bytes: int
    vmem_detail: dict
    vmem_budget: int
    bytes_in: int
    bytes_out: int
    flops: int | None
    findings: list
    # Interconnect traffic of the declared ShardAxis binding: bytes each
    # shard puts on the wire across the whole schedule (all ring steps /
    # the full allreduce), per tile in comm_detail. 0 when the spec has no
    # active mesh binding.
    comm_bytes: int = 0
    comm_detail: dict = dataclasses.field(default_factory=dict)

    @property
    def hbm_bytes(self) -> int:
        return self.bytes_in + self.bytes_out

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / self.vmem_budget if self.vmem_budget else 0.0

    @property
    def intensity(self) -> float | None:
        """Arithmetic intensity (FLOPs / HBM byte) — the roofline x-axis."""
        if self.flops is None or not self.hbm_bytes:
            return None
        return self.flops / self.hbm_bytes

    def __str__(self):
        fl = "?" if self.flops is None else f"{self.flops:,}"
        ai = self.intensity
        return (f"{self.spec}: vmem {self.vmem_bytes:,} B "
                f"({self.vmem_frac:.0%} of budget), hbm {self.hbm_bytes:,} B "
                f"(in {self.bytes_in:,} / out {self.bytes_out:,}), "
                f"flops {fl}"
                + (f", intensity {ai:.2f} flop/B" if ai is not None else "")
                + (f", comm {self.comm_bytes:,} B/shard"
                   if self.comm_bytes else ""))


def estimate_cost(spec, defines=None, *, budget=None,
                  walk: bool = True, flops: bool = True) -> CostReport:
    """The static cost model for one built spec: VMEM footprint vs. budget,
    HBM bytes moved over the concrete grid walk, and FLOPs from the abstract
    body trace. ``walk=False``/``flops=False`` skip the expensive passes
    (footprint alone is cheap enough for every build)."""
    budget = vmem_budget() if budget is None else int(budget)
    vmem, detail = vmem_footprint(spec)
    findings = _footprint_findings(spec, budget=budget)
    ncells = math.prod(spec.grid) if spec.grid else 1
    if walk and ncells <= WALK_CELL_LIMIT:
        bytes_in, bytes_out, fetch_findings = _walk_costs(spec)
        findings += fetch_findings
    else:
        # upper bound: every visit fetches its block, every output visit
        # writes it back (no consecutive-index elision credit) — EXCEPT
        # whole-array input tiles, which are grid-invariant (one resident
        # copy, a constant index map) and fetched exactly once. A gathered
        # (index_tile) block is run-time-indexed and always per-visit.
        bytes_in = sum(
            (1 if (t.resolved_block() == tuple(t.shape)
                   and t.index_tile is None) else ncells)
            * math.prod(t.body_block()) * _itemsize(t.dtype)
            for t in spec.inputs)
        bytes_out = sum(
            ncells * math.prod(t.resolved_block()) * _itemsize(t.dtype)
            for t in spec.outputs)
    fl = estimate_flops(spec, defines) if flops else None
    comm, comm_detail = _comm_costs(spec)
    return CostReport(
        spec=spec.name, grid=tuple(spec.grid), cells=ncells,
        vmem_bytes=vmem, vmem_detail=detail, vmem_budget=budget,
        bytes_in=int(bytes_in), bytes_out=int(bytes_out), flops=fl,
        findings=findings, comm_bytes=comm, comm_detail=comm_detail)


def _comm_costs(spec):
    """Per-shard interconnect bytes of the declared ShardAxis binding over
    the whole schedule. Tile shapes in a mesh-bound spec are already the
    per-shard (local) shapes, so each term is local-array bytes times the
    hop count of the declared collective:

      ppermute       every rotated input hops extent-1 times (one hop per
                     ring step after the first); sharded outputs' partials
                     ride the same ring home — another extent-1 hops each
      psum           ring allreduce: 2*(n-1)/n of the array per shard
      psum_scatter   reduce-scatter half of the above: (n-1)/n
    """
    sh = getattr(spec, "shard", None)
    if sh is None or sh.extent <= 1:
        return 0, {}
    n = sh.extent
    detail: dict[str, int] = {}
    tiles = {t.name: t for t in spec.inputs + spec.outputs}
    if sh.collective == "ppermute":
        for name in (*sh.rotate, *sh.sharded_outputs):
            t = tiles[name]
            b = (n - 1) * math.prod(t.shape) * _itemsize(t.dtype)
            detail[name] = detail.get(name, 0) + b
    elif sh.collective in ("psum", "psum_scatter"):
        hops = 2 * (n - 1) / n if sh.collective == "psum" else (n - 1) / n
        for t in spec.outputs:
            if sh.axis in spec.output_reduce_axes(t):
                b = math.prod(t.shape) * _itemsize(t.dtype)
                detail[t.name] = int(round(hops * b))
    return sum(detail.values()), detail


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def analyze_spec(spec, defines=None, *, body=True, footprint=True) -> Report:
    """Full analysis of one built Spec: grid invariants + semantics
    consistency + (``footprint=True``) VMEM budget accounting +
    (``body=True``) the recording body trace."""
    findings, _ = check_grid_invariants(spec)
    findings = list(findings)
    findings += check_semantics(spec)
    if footprint:
        findings += _footprint_findings(spec)
    if body and not findings:
        try:
            events = trace_body(spec, defines)
        except Exception as e:  # an exotic body the recorder cannot trace
            findings.append(Finding(
                "TRACE_INCOMPLETE", spec.name, "",
                f"body trace failed ({type(e).__name__}: {e}); liveness/"
                "coverage analysis skipped for this kernel"))
        else:
            findings += check_body(spec, events)
    return Report(spec.name, findings)


def check_built_spec(spec, defines=None, *, mode: str | None = None) -> Report:
    """The kernel-build hook (``Device.build_kernel``): analyze + dispatch
    per the strictness mode. Grid invariants already raised at Spec
    construction, so this pass contributes the body/semantics verdicts."""
    mode = analysis_mode() if mode is None else mode
    if mode == "off":
        return Report(spec.name, [])
    findings = list(check_semantics(spec))
    findings += _footprint_findings(spec)
    try:
        events = trace_body(spec, defines)
    except Exception as e:
        findings.append(Finding(
            "TRACE_INCOMPLETE", spec.name, "",
            f"body trace failed ({type(e).__name__}: {e}); liveness/"
            "coverage analysis skipped for this kernel"))
    else:
        findings += check_body(spec, events)
    report = Report(spec.name, findings)
    report.emit(mode)
    return report
