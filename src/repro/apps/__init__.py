"""The paper's three numerical applications (§4), each written once in the
unified kernel language and runnable on every backend."""

from . import dg_swe, fd2d, numerics, sem  # noqa: F401
