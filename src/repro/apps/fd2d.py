"""Paper §4.1 — 2D finite-difference acoustic wave equation, in the unified
kernel language (one source, three backends).

u_tt = u_xx + u_yy on the periodic square [-1,1]^2; leapfrog in time with an
order-2r central stencil in space. Mirrors the paper's code listings 8-9
(kernel + host code with ``addDefine``/``buildKernel``/``swap``).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Device, Spec, Tile
from .numerics import fd_second_derivative_weights

__all__ = ["fd2d_builder", "FDWave", "reference_step", "fd_flops_per_step"]


def fd2d_builder(D):
    """Kernel builder (the paper's fd2d.occa). Defines: w,h,bh,bw,r,dt,dx,
    weights,dtype.

    Each work-group (grid cell) owns a ``(bh, bw)`` block of the field and
    reads it through a halo tile: the language fetches the block plus its
    r-point periodic fringe on every side — the paper's manual "shared
    memory" caching pattern, without ever touching the field outside the
    ``(bh + 2r, bw + 2r)`` window."""
    weights = tuple(D.weights)
    inv_dx2 = 1.0 / (D.dx * D.dx)
    dt2 = D.dt * D.dt
    dtype = jnp.dtype(D.dtype)
    r, bh, bw, w, h = D.r, D.bh, D.bw, D.w, D.h

    def body(ctx, u1, u2, u3):
        win = ctx.cache(u1)                  # (bh+2r, bw+2r) haloed window
        ctx.barrier()                        # halo cached ("shared")
        inner = win[r:r + bh, r:r + bw]
        lap = jnp.zeros((bh, bw), jnp.float32)
        for k in range(-r, r + 1):           # unrolled radius loop
            wk = weights[k + r]
            lap = lap + wk * win[r + k:r + k + bh, r:r + bw]    # vertical
            lap = lap + wk * win[r:r + bh, r + k:r + k + bw]    # horizontal
        lap = lap * inv_dx2
        u3[...] = (2.0 * inner - u2[...] + dt2 * lap).astype(dtype)

    return Spec(
        "fd2d",
        grid=(h // bh, w // bw),
        inputs=[
            Tile("u1", (h, w), dtype, block=(bh, bw), halo=(r, r), wrap=True),
            Tile("u2", (h, w), dtype, block=(bh, bw)),
        ],
        outputs=[Tile("u3", (h, w), dtype, block=(bh, bw))],
        body=body,
    )


def reference_step(u1, u2, weights, dx, dt):
    """Pure-jnp oracle for one leapfrog step (independent of the kernel lang)."""
    lap = jnp.zeros_like(u1)
    r = (len(weights) - 1) // 2
    for k in range(-r, r + 1):
        wk = weights[k + r]
        lap = lap + wk * (jnp.roll(u1, -k, axis=0) + jnp.roll(u1, -k, axis=1))
    lap = lap / (dx * dx)
    return 2.0 * u1 - u2 + dt * dt * lap


def fd_flops_per_step(w: int, h: int, r: int) -> int:
    # per node: (2r+1) * (2 rolls * 1 mul + 2 add) ~= 4*(2r+1) + 5 update ops
    return w * h * (4 * (2 * r + 1) + 5)


class FDWave:
    """Host driver mirroring the paper's listing 9.

    Block sizes flow through the registered ``fd2d`` op: ``block=None``
    (default) adopts the persisted autotune winner for this shape/backend
    when one exists (``repro.tune_cli --apps`` writes it), falling back to
    the op's declared defaults. An explicit ``block=(bh, bw)`` pins the
    tile (0 means "full extent" along that axis)."""

    def __init__(self, *, model: str = "jnp", width: int = 128, height: int = 128,
                 radius: int = 1, cfl: float = 0.5, dtype="float32",
                 block: tuple[int, int] | None = None):
        self.device = Device(model)
        self.w, self.h, self.r = width, height, radius
        self.dx = 2.0 / width
        self.dt = cfl * self.dx / np.sqrt(2.0)
        self.dtype = np.dtype(dtype)
        self.block = block
        self.current_time = 0.0
        self.weights = tuple(float(x) for x in fd_second_derivative_weights(radius))
        self._setup_solver()

    # paper: setupSolver()
    def _setup_solver(self):
        w, h = self.w, self.h
        x = np.linspace(-1, 1, w, endpoint=False)
        y = np.linspace(-1, 1, h, endpoint=False)
        X, Y = np.meshgrid(x, y)
        # standing wave initial condition: u = cos(pi x) cos(pi y) cos(omega t)
        self.omega = np.pi * np.sqrt(2.0)
        u0 = (np.cos(np.pi * X) * np.cos(np.pi * Y)).astype(self.dtype)
        # second initial slice at t = -dt (exact): cos(omega * -dt) factor
        um1 = (u0 * np.cos(self.omega * self.dt)).astype(self.dtype)

        self.o_u1 = self.device.malloc(u0)    # u at t_n
        self.o_u2 = self.device.malloc(um1)   # u at t_{n-1}
        self.o_u3 = self.device.malloc(np.zeros_like(u0))

        # defines via the registered op (shared fit_block derivation + the
        # persisted-autotune winner for this shape/backend, when present)
        from repro.kernels.apps import fd2d as fd2d_op  # late: avoid cycle
        params = dict(weights=self.weights, dx=float(self.dx),
                      dt=float(self.dt))
        if self.block is None:
            shapes = (jax.ShapeDtypeStruct((h, w), self.dtype),) * 2
            params.update(fd2d_op.cached_winner(
                shapes, backend=self.device.backend,
                interpret=self.device.interpret, **params) or {})
        else:
            params.update(bh=self.block[0] or h, bw=self.block[1] or w)
        defines = fd2d_op.derive_defines(
            (u0, um1), {**fd2d_op.defaults, **params})
        self.fd2d = self.device.build_kernel(fd2d_builder, defines)

    # paper: timestep()
    def timestep(self):
        self.current_time += self.dt
        self.fd2d(self.o_u1, self.o_u2, self.o_u3)
        # Rotate solutions (paper's swap chain): u1 <- u_{n+1}, u2 <- u_n
        self.o_u2.swap(self.o_u3)
        self.o_u1.swap(self.o_u2)

    def run(self, nsteps: int):
        for _ in range(nsteps):
            self.timestep()
        self.o_u1.data.block_until_ready()
        return self

    @property
    def solution(self) -> np.ndarray:
        return self.o_u1.to_host()  # u at current_time (after rotation)

    def analytic(self) -> np.ndarray:
        x = np.linspace(-1, 1, self.w, endpoint=False)
        y = np.linspace(-1, 1, self.h, endpoint=False)
        X, Y = np.meshgrid(x, y)
        return (np.cos(np.pi * X) * np.cos(np.pi * Y)
                * np.cos(self.omega * self.current_time)).astype(self.dtype)
