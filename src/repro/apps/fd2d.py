"""Paper §4.1 — 2D finite-difference acoustic wave equation, in the unified
kernel language (one source, three backends).

u_tt = u_xx + u_yy on the periodic square [-1,1]^2; leapfrog in time with an
order-2r central stencil in space. Mirrors the paper's code listings 8-9
(kernel + host code with ``addDefine``/``buildKernel``/``swap``).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Device, Spec, Tile
from .numerics import fd_second_derivative_weights

__all__ = ["fd2d_builder", "FDWave", "reference_step", "fd_flops_per_step"]


def fd2d_builder(D):
    """Kernel builder (the paper's fd2d.occa). Defines: w,h,bh,r,dt,dx,weights,dtype.

    Each work-group (grid cell) owns a row stripe and caches its stripe plus
    the r-row periodic halo into "shared memory" (VMEM), exactly the paper's
    manual-caching pattern — per-cell work is proportional to the stripe."""
    weights = tuple(D.weights)
    inv_dx2 = 1.0 / (D.dx * D.dx)
    dt2 = D.dt * D.dt
    dtype = jnp.dtype(D.dtype)
    r, bh, w, h = D.r, D.bh, D.w, D.h

    def body(ctx, u1, u2, u3):
        bi = ctx.outer_id(0)
        U = ctx.cache(u1)                                # whole field (HBM view)
        # stripe + halo rows [bi*bh - r, bi*bh + bh + r) with periodic wrap:
        rolled = jnp.roll(U, r, axis=0)
        padded = jnp.concatenate([rolled, rolled[:2 * r]], axis=0)
        win = jax.lax.dynamic_slice(padded, (bi * bh, 0), (bh + 2 * r, w))
        ctx.barrier()                                    # halo cached ("shared")
        inner = win[r:r + bh]
        lap = jnp.zeros((bh, w), jnp.float32)
        for k in range(-r, r + 1):                       # unrolled radius loop
            wk = weights[k + r]
            lap = lap + wk * win[r + k:r + k + bh]                  # vertical
            lap = lap + wk * jnp.roll(inner, -k, axis=1)            # horizontal
        lap = lap * inv_dx2
        u3[...] = (2.0 * inner - u2[...] + dt2 * lap).astype(dtype)

    return Spec(
        "fd2d",
        grid=(D.h // bh,),
        inputs=[
            Tile("u1", (h, w), dtype),                           # whole-array (halo)
            Tile("u2", (h, w), dtype, block=(bh, w), index=lambda i: (i, 0)),
        ],
        outputs=[Tile("u3", (h, w), dtype, block=(bh, w),
                      index=lambda i: (i, 0))],
        body=body,
    )


def reference_step(u1, u2, weights, dx, dt):
    """Pure-jnp oracle for one leapfrog step (independent of the kernel lang)."""
    lap = jnp.zeros_like(u1)
    r = (len(weights) - 1) // 2
    for k in range(-r, r + 1):
        wk = weights[k + r]
        lap = lap + wk * (jnp.roll(u1, -k, axis=0) + jnp.roll(u1, -k, axis=1))
    lap = lap / (dx * dx)
    return 2.0 * u1 - u2 + dt * dt * lap


def fd_flops_per_step(w: int, h: int, r: int) -> int:
    # per node: (2r+1) * (2 rolls * 1 mul + 2 add) ~= 4*(2r+1) + 5 update ops
    return w * h * (4 * (2 * r + 1) + 5)


class FDWave:
    """Host driver mirroring the paper's listing 9."""

    def __init__(self, *, model: str = "jnp", width: int = 128, height: int = 128,
                 radius: int = 1, cfl: float = 0.5, dtype="float32",
                 block: tuple[int, int] = (32, 0)):
        self.device = Device(model)
        self.w, self.h, self.r = width, height, radius
        self.dx = 2.0 / width
        self.dt = cfl * self.dx / np.sqrt(2.0)
        self.dtype = np.dtype(dtype)
        self.block = block
        self.current_time = 0.0
        self.weights = tuple(float(x) for x in fd_second_derivative_weights(radius))
        self._setup_solver()

    # paper: setupSolver()
    def _setup_solver(self):
        w, h = self.w, self.h
        x = np.linspace(-1, 1, w, endpoint=False)
        y = np.linspace(-1, 1, h, endpoint=False)
        X, Y = np.meshgrid(x, y)
        # standing wave initial condition: u = cos(pi x) cos(pi y) cos(omega t)
        self.omega = np.pi * np.sqrt(2.0)
        u0 = (np.cos(np.pi * X) * np.cos(np.pi * Y)).astype(self.dtype)
        # second initial slice at t = -dt (exact): cos(omega * -dt) factor
        um1 = (u0 * np.cos(self.omega * self.dt)).astype(self.dtype)

        self.o_u1 = self.device.malloc(u0)    # u at t_n
        self.o_u2 = self.device.malloc(um1)   # u at t_{n-1}
        self.o_u3 = self.device.malloc(np.zeros_like(u0))

        bh = self.block[0]
        while h % bh:
            bh -= 1
        defines = dict(w=w, h=h, bh=bh,
                       r=self.r, dt=float(self.dt), dx=float(self.dx),
                       weights=self.weights, dtype=str(self.dtype))
        self.fd2d = self.device.build_kernel(fd2d_builder, defines)

    # paper: timestep()
    def timestep(self):
        self.current_time += self.dt
        self.fd2d(self.o_u1, self.o_u2, self.o_u3)
        # Rotate solutions (paper's swap chain): u1 <- u_{n+1}, u2 <- u_n
        self.o_u2.swap(self.o_u3)
        self.o_u1.swap(self.o_u2)

    def run(self, nsteps: int):
        for _ in range(nsteps):
            self.timestep()
        self.o_u1.data.block_until_ready()
        return self

    @property
    def solution(self) -> np.ndarray:
        return self.o_u1.to_host()  # u at current_time (after rotation)

    def analytic(self) -> np.ndarray:
        x = np.linspace(-1, 1, self.w, endpoint=False)
        y = np.linspace(-1, 1, self.h, endpoint=False)
        X, Y = np.meshgrid(x, y)
        return (np.cos(np.pi * X) * np.cos(np.pi * Y)
                * np.cos(self.omega * self.current_time)).astype(self.dtype)
