"""Host-side spectral numerics (numpy, float64) for the paper's SEM/DG apps.

Gauss-Lobatto-Legendre quadrature, Jacobi polynomials, 1D/2D Vandermonde and
differentiation matrices, and Warp&Blend triangle nodes — following
Hesthaven & Warburton, "Nodal Discontinuous Galerkin Methods" (paper ref [14])
and Deville/Fischer/Mund (paper ref [7]). These are trace-time constants
(OCCA 'defines'-level data) consumed by the kernels.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = [
    "jacobi_p", "grad_jacobi_p", "jacobi_gq", "jacobi_gl",
    "gll_nodes_weights", "dmatrix_1d", "vandermonde_1d",
    "triangle_nodes", "vandermonde_2d", "dmatrices_2d", "fd_second_derivative_weights",
]


# ---------------------------------------------------------------------------
# Jacobi polynomials (orthonormal on [-1,1] w.r.t. (1-x)^a (1+x)^b)
# ---------------------------------------------------------------------------

def jacobi_p(x: np.ndarray, alpha: float, beta: float, n: int) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    pl = np.zeros((n + 1,) + x.shape)
    gamma0 = (2 ** (alpha + beta + 1) / (alpha + beta + 1)
              * math.gamma(alpha + 1) * math.gamma(beta + 1)
              / math.gamma(alpha + beta + 1))
    pl[0] = 1.0 / math.sqrt(gamma0)
    if n == 0:
        return pl[0]
    gamma1 = (alpha + 1) * (beta + 1) / (alpha + beta + 3) * gamma0
    pl[1] = ((alpha + beta + 2) * x / 2 + (alpha - beta) / 2) / math.sqrt(gamma1)
    if n == 1:
        return pl[1]
    aold = 2.0 / (2 + alpha + beta) * math.sqrt(
        (alpha + 1) * (beta + 1) / (alpha + beta + 3))
    for i in range(1, n):
        h1 = 2 * i + alpha + beta
        anew = 2.0 / (h1 + 2) * math.sqrt(
            (i + 1) * (i + 1 + alpha + beta) * (i + 1 + alpha) * (i + 1 + beta)
            / ((h1 + 1) * (h1 + 3)))
        bnew = -(alpha ** 2 - beta ** 2) / (h1 * (h1 + 2))
        pl[i + 1] = 1.0 / anew * (-aold * pl[i - 1] + (x - bnew) * pl[i])
        aold = anew
    return pl[n]


def grad_jacobi_p(x: np.ndarray, alpha: float, beta: float, n: int) -> np.ndarray:
    if n == 0:
        return np.zeros_like(np.asarray(x, dtype=np.float64))
    return math.sqrt(n * (n + alpha + beta + 1)) * jacobi_p(x, alpha + 1, beta + 1, n - 1)


def jacobi_gq(alpha: float, beta: float, n: int):
    """Gauss quadrature nodes/weights via Golub-Welsch."""
    if n == 0:
        x = np.array([-(alpha - beta) / (alpha + beta + 2)])
        w = np.array([2.0])
        return x, w
    h1 = 2 * np.arange(n + 1) + alpha + beta
    d0 = -(alpha ** 2 - beta ** 2) / (h1 + 2) / h1
    if alpha + beta == 0:
        d0[0] = 0.0
    i = np.arange(1, n + 1)
    d1 = (2.0 / (h1[:-1] + 2)
          * np.sqrt(i * (i + alpha + beta) * (i + alpha) * (i + beta)
                    / (h1[:-1] + 1) / (h1[:-1] + 3)))
    J = np.diag(d0) + np.diag(d1, 1) + np.diag(d1, -1)
    x, V = np.linalg.eigh(J)
    mu0 = (2 ** (alpha + beta + 1) * math.gamma(alpha + 1) * math.gamma(beta + 1)
           / math.gamma(alpha + beta + 2))
    w = (V[0, :] ** 2) * mu0
    return x, w


def jacobi_gl(alpha: float, beta: float, n: int) -> np.ndarray:
    """Gauss-Lobatto nodes (includes endpoints)."""
    if n == 1:
        return np.array([-1.0, 1.0])
    xint, _ = jacobi_gq(alpha + 1, beta + 1, n - 2)
    return np.concatenate([[-1.0], xint, [1.0]])


# ---------------------------------------------------------------------------
# 1D GLL quadrature + differentiation (SEM)
# ---------------------------------------------------------------------------

def _legendre(x: np.ndarray, n: int) -> np.ndarray:
    """Un-normalized Legendre P_n via recurrence."""
    p0 = np.ones_like(x)
    if n == 0:
        return p0
    p1 = x.copy()
    for k in range(1, n):
        p0, p1 = p1, ((2 * k + 1) * x * p1 - k * p0) / (k + 1)
    return p1


def gll_nodes_weights(n: int):
    """N+1 Gauss-Lobatto-Legendre nodes/weights on [-1,1] (degree N basis)."""
    x = jacobi_gl(0.0, 0.0, n)
    w = 2.0 / (n * (n + 1) * _legendre(x, n) ** 2)
    return x, w


def dmatrix_1d(n: int, x: np.ndarray | None = None) -> np.ndarray:
    """Spectral differentiation matrix on GLL nodes (Lagrange basis)."""
    if x is None:
        x, _ = gll_nodes_weights(n)
    ln = _legendre(x, n)
    D = np.zeros((n + 1, n + 1))
    for i in range(n + 1):
        for j in range(n + 1):
            if i != j:
                D[i, j] = ln[i] / (ln[j] * (x[i] - x[j]))
    D[0, 0] = -n * (n + 1) / 4.0
    D[n, n] = n * (n + 1) / 4.0
    return D


def vandermonde_1d(n: int, r: np.ndarray) -> np.ndarray:
    V = np.zeros((len(r), n + 1))
    for j in range(n + 1):
        V[:, j] = jacobi_p(r, 0.0, 0.0, j)
    return V


# ---------------------------------------------------------------------------
# Triangle nodal basis (DG): Warp & Blend nodes + Koornwinder basis
# ---------------------------------------------------------------------------

_ALPHA_OPT = [0.0000, 0.0000, 1.4152, 0.1001, 0.2751, 0.9800, 1.0999,
              1.2832, 1.3648, 1.4773, 1.4959, 1.5743, 1.5770, 1.6223, 1.6258]


def _warp_factor(n: int, rout: np.ndarray) -> np.ndarray:
    lglr = jacobi_gl(0.0, 0.0, n)
    req = np.linspace(-1.0, 1.0, n + 1)
    veq = vandermonde_1d(n, req)
    nr = len(rout)
    pmat = np.zeros((n + 1, nr))
    for i in range(n + 1):
        pmat[i, :] = jacobi_p(rout, 0.0, 0.0, i)
    lmat = np.linalg.solve(veq.T, pmat)
    warp = lmat.T @ (lglr - req)
    zerof = (np.abs(rout) < 1.0 - 1e-10).astype(np.float64)
    sf = 1.0 - (zerof * rout) ** 2
    return warp / sf + warp * (zerof - 1.0)


def triangle_nodes(n: int):
    """Warp&Blend nodes on the reference triangle; returns (r, s)."""
    alpha = _ALPHA_OPT[n - 1] if n < 16 else 5.0 / 3.0
    np_ = (n + 1) * (n + 2) // 2
    L1 = np.zeros(np_)
    L3 = np.zeros(np_)
    sk = 0
    for i in range(n + 1):
        for j in range(n + 1 - i):
            L1[sk] = i / n
            L3[sk] = j / n
            sk += 1
    L2 = 1.0 - L1 - L3
    x = -L2 + L3
    y = (-L2 - L3 + 2 * L1) / math.sqrt(3.0)

    blend1 = 4 * L2 * L3
    blend2 = 4 * L1 * L3
    blend3 = 4 * L1 * L2
    warpf1 = _warp_factor(n, L3 - L2)
    warpf2 = _warp_factor(n, L1 - L3)
    warpf3 = _warp_factor(n, L2 - L1)
    warp1 = blend1 * warpf1 * (1 + (alpha * L1) ** 2)
    warp2 = blend2 * warpf2 * (1 + (alpha * L2) ** 2)
    warp3 = blend3 * warpf3 * (1 + (alpha * L3) ** 2)
    x = x + 1 * warp1 + math.cos(2 * math.pi / 3) * warp2 + math.cos(4 * math.pi / 3) * warp3
    y = y + 0 * warp1 + math.sin(2 * math.pi / 3) * warp2 + math.sin(4 * math.pi / 3) * warp3

    # xy -> rs (barycentric inversion)
    L1b = (math.sqrt(3.0) * y + 1.0) / 3.0
    L2b = (-3.0 * x - math.sqrt(3.0) * y + 2.0) / 6.0
    L3b = (3.0 * x - math.sqrt(3.0) * y + 2.0) / 6.0
    r = -L2b + L3b - L1b
    s = -L2b - L3b + L1b
    return r, s


def _rs_to_ab(r: np.ndarray, s: np.ndarray):
    denom = np.where(np.abs(s - 1.0) > 1e-12, 1.0 - s, 1.0)
    a = np.where(np.abs(s - 1.0) > 1e-12, 2.0 * (1.0 + r) / denom - 1.0, -1.0)
    return a, s


def _simplex_2d_p(a, b, i, j):
    h1 = jacobi_p(a, 0.0, 0.0, i)
    h2 = jacobi_p(b, 2.0 * i + 1.0, 0.0, j)
    return math.sqrt(2.0) * h1 * h2 * (1 - b) ** i


def _grad_simplex_2d_p(a, b, i, j):
    fa = jacobi_p(a, 0.0, 0.0, i)
    dfa = grad_jacobi_p(a, 0.0, 0.0, i)
    gb = jacobi_p(b, 2.0 * i + 1.0, 0.0, j)
    dgb = grad_jacobi_p(b, 2.0 * i + 1.0, 0.0, j)
    # r-derivative
    dmodedr = dfa * gb
    if i > 0:
        dmodedr = dmodedr * (0.5 * (1 - b)) ** (i - 1)
    # s-derivative
    dmodeds = dfa * (gb * (0.5 * (1 + a)))
    if i > 0:
        dmodeds = dmodeds * (0.5 * (1 - b)) ** (i - 1)
    tmp = dgb * (0.5 * (1 - b)) ** i
    if i > 0:
        tmp = tmp - 0.5 * i * gb * (0.5 * (1 - b)) ** (i - 1)
    dmodeds = dmodeds + fa * tmp
    return 2 ** (i + 0.5) * dmodedr, 2 ** (i + 0.5) * dmodeds


def vandermonde_2d(n: int, r: np.ndarray, s: np.ndarray) -> np.ndarray:
    np_ = (n + 1) * (n + 2) // 2
    V = np.zeros((len(r), np_))
    a, b = _rs_to_ab(r, s)
    sk = 0
    for i in range(n + 1):
        for j in range(n + 1 - i):
            V[:, sk] = _simplex_2d_p(a, b, i, j)
            sk += 1
    return V


def dmatrices_2d(n: int, r: np.ndarray, s: np.ndarray):
    """Nodal differentiation matrices Dr, Ds on the reference triangle."""
    np_ = (n + 1) * (n + 2) // 2
    V = vandermonde_2d(n, r, s)
    Vr = np.zeros((len(r), np_))
    Vs = np.zeros((len(r), np_))
    a, b = _rs_to_ab(r, s)
    sk = 0
    for i in range(n + 1):
        for j in range(n + 1 - i):
            Vr[:, sk], Vs[:, sk] = _grad_simplex_2d_p(a, b, i, j)
            sk += 1
    Vinv = np.linalg.inv(V)
    return Vr @ Vinv, Vs @ Vinv, V


# ---------------------------------------------------------------------------
# Finite-difference stencil weights (order-2r central second derivative)
# ---------------------------------------------------------------------------

def fd_second_derivative_weights(r: int) -> np.ndarray:
    """Central FD weights for d2/dx2 with radius r (unit spacing)."""
    k = np.arange(-r, r + 1, dtype=np.float64)
    A = np.vander(k, 2 * r + 1, increasing=True).T  # A[m, j] = k_j^m
    b = np.zeros(2 * r + 1)
    b[2] = 2.0  # match x^2 -> second derivative = 2
    return np.linalg.solve(A, b)


# ---------------------------------------------------------------------------
# DG surface machinery: face masks + LIFT matrix (Hesthaven-Warburton)
# ---------------------------------------------------------------------------

def face_mask(n: int, r: np.ndarray, s: np.ndarray):
    """Node indices on the 3 faces of the reference triangle: s=-1, r+s=0,
    r=-1. Returns (3, Nfp) int array ordered along each face."""
    tol = 1e-10
    f0 = np.where(np.abs(s + 1) < tol)[0]
    f1 = np.where(np.abs(r + s) < tol)[0]
    f2 = np.where(np.abs(r + 1) < tol)[0]
    f0 = f0[np.argsort(r[f0])]
    f1 = f1[np.argsort(-s[f1])]      # along the hypotenuse from (1,-1) to (-1,1)
    f2 = f2[np.argsort(-s[f2])]
    return np.stack([f0, f1, f2])


def lift_matrix(n: int, r: np.ndarray, s: np.ndarray, V: np.ndarray,
                fmask: np.ndarray) -> np.ndarray:
    """LIFT = V V^T Emat: surface integral lifting (Np, 3*Nfp)."""
    np_ = len(r)
    nfp = n + 1
    emat = np.zeros((np_, 3 * nfp))
    for f in range(3):
        idx = fmask[f]
        # affine 1D parameterization along the face (r on f0, s on f1/f2)
        face_r = r[idx] if f == 0 else s[idx]
        v1d_face = vandermonde_1d(n, face_r)
        mass_edge = np.linalg.inv(v1d_face @ v1d_face.T)
        emat[idx, f * nfp:(f + 1) * nfp] = mass_edge
    return V @ (V.T @ emat)
