"""Paper §4.3 — discontinuous-Galerkin shallow-water VOLUME kernel (the
kernel the paper profiles in Figs. 5-6), in the unified kernel language.

rhs_vol = -(dF/dx + dG/dy) + S on nodal triangles, with affine per-element
geometric factors and bathymetry source  S = (0, -g h B_x, -g h B_y).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Device, Spec, Tile
from .numerics import dmatrices_2d, triangle_nodes

__all__ = [
    "dg_volume_builder", "dg_surface_builder", "DGVolume", "SWESolver",
    "make_tri_mesh", "build_connectivity", "volume_ref", "surface_ref",
    "dg_flops_per_element", "dg_bytes_per_element", "GRAV",
]

GRAV = 9.81


def dg_volume_builder(D):
    """Defines: E, np_ (nodes/element), eb, g, dtype."""
    dtype = jnp.dtype(D.dtype)
    np_, eb, g = D.np_, D.eb, D.g

    def body(ctx, q, geom, db, dr, ds, out):
        Q = q[...]                          # (eb, np_, 3)
        Ge = geom[...]                      # (eb, 4): rx, sx, ry, sy
        dB = db[...]                        # (eb, np_, 2): B_x, B_y
        Dr = ctx.cache(dr)                  # (np_, np_) shared
        Ds = ctx.cache(ds)
        ctx.barrier()

        h, hu, hv = Q[..., 0], Q[..., 1], Q[..., 2]
        u = hu / h
        v = hv / h
        gh2 = 0.5 * g * h * h
        F = jnp.stack([hu, hu * u + gh2, hu * v], axis=-1)
        G = jnp.stack([hv, hu * v, hv * v + gh2], axis=-1)

        DrF = jnp.einsum("nm,emf->enf", Dr, F)
        DsF = jnp.einsum("nm,emf->enf", Ds, F)
        DrG = jnp.einsum("nm,emf->enf", Dr, G)
        DsG = jnp.einsum("nm,emf->enf", Ds, G)
        rx = Ge[:, 0][:, None, None]
        sx = Ge[:, 1][:, None, None]
        ry = Ge[:, 2][:, None, None]
        sy = Ge[:, 3][:, None, None]
        dFdx = rx * DrF + sx * DsF
        dGdy = ry * DrG + sy * DsG

        zeros = jnp.zeros_like(h)
        S = jnp.stack([zeros, -g * h * dB[..., 0], -g * h * dB[..., 1]], axis=-1)
        out[...] = (-(dFdx + dGdy) + S).astype(dtype)

    return Spec(
        "dg_swe_volume",
        grid=(D.E // eb,),
        inputs=[
            Tile("q", (D.E, np_, 3), dtype, block=(eb, np_, 3),
                 index=lambda e: (e, 0, 0)),
            Tile("geom", (D.E, 4), dtype, block=(eb, 4), index=lambda e: (e, 0)),
            Tile("db", (D.E, np_, 2), dtype, block=(eb, np_, 2),
                 index=lambda e: (e, 0, 0)),
            Tile("dr", (np_, np_), dtype),
            Tile("ds", (np_, np_), dtype),
        ],
        outputs=[Tile("out", (D.E, np_, 3), dtype, block=(eb, np_, 3),
                      index=lambda e: (e, 0, 0))],
        body=body,
    )


def volume_ref(Q, geom, dB, Dr, Ds, g=GRAV):
    """Independent pure-jnp oracle."""
    h, hu, hv = Q[..., 0], Q[..., 1], Q[..., 2]
    u, v = hu / h, hv / h
    gh2 = 0.5 * g * h * h
    F = jnp.stack([hu, hu * u + gh2, hu * v], -1)
    G = jnp.stack([hv, hu * v, hv * v + gh2], -1)
    dFdx = (geom[:, 0][:, None, None] * jnp.einsum("nm,emf->enf", Dr, F)
            + geom[:, 1][:, None, None] * jnp.einsum("nm,emf->enf", Ds, F))
    dGdy = (geom[:, 2][:, None, None] * jnp.einsum("nm,emf->enf", Dr, G)
            + geom[:, 3][:, None, None] * jnp.einsum("nm,emf->enf", Ds, G))
    S = jnp.stack([jnp.zeros_like(h), -g * h * dB[..., 0], -g * h * dB[..., 1]], -1)
    return -(dFdx + dGdy) + S


def dg_flops_per_element(np_: int) -> int:
    return 4 * 2 * np_ * np_ * 3 + 30 * np_


def dg_bytes_per_element(np_: int, itemsize: int) -> int:
    return (3 + 3 + 2) * np_ * itemsize + 4 * itemsize


def make_tri_mesh(nx: int, ny: int, n: int, *, seed: int = 0, jitter: float = 0.0):
    """Structured triangulation of [-1,1]^2 (2 triangles per quad) with nodal
    coordinates and affine geometric factors. Returns dict of arrays."""
    r, s = triangle_nodes(n)
    Dr, Ds, V = dmatrices_2d(n, r, s)
    np_ = len(r)

    xv = np.linspace(-1, 1, nx + 1)
    yv = np.linspace(-1, 1, ny + 1)
    rng = np.random.RandomState(seed)
    VX, VY = np.meshgrid(xv, yv, indexing="ij")
    if jitter:
        intx = slice(1, nx), slice(1, ny)
        VX = VX.copy(); VY = VY.copy()
        VX[1:nx, 1:ny] += jitter * (2 / nx) * (rng.rand(nx - 1, ny - 1) - 0.5)
        VY[1:nx, 1:ny] += jitter * (2 / ny) * (rng.rand(nx - 1, ny - 1) - 0.5)

    tris = []
    for i in range(nx):
        for j in range(ny):
            v00 = (i, j); v10 = (i + 1, j); v01 = (i, j + 1); v11 = (i + 1, j + 1)
            tris.append((v00, v10, v11))
            tris.append((v00, v11, v01))
    E = len(tris)
    x = np.zeros((E, np_))
    y = np.zeros((E, np_))
    geom = np.zeros((E, 4))
    Js = np.zeros(E)
    for e, (a, b, c) in enumerate(tris):
        xa, ya = VX[a], VY[a]
        xb, yb = VX[b], VY[b]
        xc, yc = VX[c], VY[c]
        # affine map from reference (r,s) in [-1,1] triangle
        x[e] = 0.5 * (-(r + s) * xa + (1 + r) * xb + (1 + s) * xc)
        y[e] = 0.5 * (-(r + s) * ya + (1 + r) * yb + (1 + s) * yc)
        xr, xs = 0.5 * (xb - xa), 0.5 * (xc - xa)
        yr, ys = 0.5 * (yb - ya), 0.5 * (yc - ya)
        J = xr * ys - xs * yr
        assert J > 0, "negative element Jacobian"
        geom[e] = (ys / J, -yr / J, -xs / J, xr / J)  # rx, sx, ry, sy
        Js[e] = J
    return dict(x=x, y=y, geom=geom, J=Js, Dr=Dr, Ds=Ds, V=V, np_=np_, E=E,
                r=r, s=s)


class DGVolume:
    """Host driver for the DG SWE volume kernel.

    ``eb=None`` (default) adopts the persisted ``dg_volume`` autotune winner
    for this shape/backend when one exists, else the op default fitted to E."""

    def __init__(self, *, model: str = "jnp", nx: int = 8, ny: int = 8, n: int = 3,
                 eb: int | None = None, dtype="float32", bathymetry=None,
                 jitter: float = 0.2, seed: int = 0):
        self.device = Device(model)
        m = make_tri_mesh(nx, ny, n, seed=seed, jitter=jitter)
        self.mesh = m
        self.n, self.np_, self.E = n, m["np_"], m["E"]
        self.dtype = np.dtype(dtype)

        if bathymetry is None:
            B = np.zeros((self.E, self.np_))
        else:
            B = bathymetry(m["x"], m["y"])
        dBdr = B @ m["Dr"].T
        dBds = B @ m["Ds"].T
        dBdx = m["geom"][:, 0][:, None] * dBdr + m["geom"][:, 1][:, None] * dBds
        dBdy = m["geom"][:, 2][:, None] * dBdr + m["geom"][:, 3][:, None] * dBds
        self.B = B
        self.dB = np.stack([dBdx, dBdy], axis=-1)

        self.o_geom = self.device.malloc(m["geom"].astype(self.dtype))
        self.o_db = self.device.malloc(self.dB.astype(self.dtype))
        self.o_dr = self.device.malloc(m["Dr"].astype(self.dtype))
        self.o_ds = self.device.malloc(m["Ds"].astype(self.dtype))

        from repro.kernels.apps import dg_volume as dgv_op  # late: avoid cycle
        E, np_ = self.E, self.np_
        shapes = (jax.ShapeDtypeStruct((E, np_, 3), self.dtype),
                  jax.ShapeDtypeStruct((E, 4), self.dtype),
                  jax.ShapeDtypeStruct((E, np_, 2), self.dtype),
                  jax.ShapeDtypeStruct((np_, np_), self.dtype),
                  jax.ShapeDtypeStruct((np_, np_), self.dtype))
        if eb is None:
            params = dgv_op.cached_winner(
                shapes, backend=self.device.backend,
                interpret=self.device.interpret) or {}
        else:
            params = dict(eb=eb)
        defines = dgv_op.derive_defines(shapes, {**dgv_op.defaults, **params})
        self.eb = defines["eb"]
        self.kernel = self.device.build_kernel(dg_volume_builder, defines)

    def rhs_volume(self, Q):
        if not (isinstance(Q, jax.Array) and Q.dtype == self.dtype):
            Q = jnp.asarray(Q, self.dtype)  # skip when already device-typed:
        (out,) = self.kernel.run(Q, self.o_geom.data,  # per-call asarray costs
                                 self.o_db.data,       # ~2x the kernel itself
                                 self.o_dr.data, self.o_ds.data)
        return out


# ===========================================================================
# Surface kernel + full SWE solver (paper §4.3 completed: volume + surface
# + LSERK time integration with reflective-wall boundaries)
# ===========================================================================

from .numerics import face_mask, lift_matrix  # noqa: E402


def build_connectivity(nx, ny, n, mesh, seed=0):
    """Face-to-face node maps for the structured triangulation.

    Returns vmapM/vmapP as (E, 3, Nfp) int32 GLOBAL node ids (element-major
    node numbering) with vmapP == vmapM on boundary faces (wall sentinel
    handled via the bc mask), plus per-face normals and Fscale.
    """
    r, s = mesh["r"], mesh["s"]
    nq = n + 1
    fmask = face_mask(n, r, s)
    E, np_ = mesh["E"], mesh["np_"]
    x, y = mesh["x"], mesh["y"]

    # per-face outward normals / surface jacobians from the inverse metric:
    # reference-face normals f0=(0,-1) (s=-1), f1=(1,1) (r+s=0), f2=(-1,0)
    J = mesh["J"]
    rx, sx, ry, sy = (mesh["geom"][:, i] for i in range(4))
    nrm = np.zeros((E, 3, 2))
    sJ = np.zeros((E, 3))
    for f, (nr_, ns_) in enumerate(((0.0, -1.0), (1.0, 1.0), (-1.0, 0.0))):
        nxv = nr_ * rx + ns_ * sx
        nyv = nr_ * ry + ns_ * sy
        mag = np.sqrt(nxv ** 2 + nyv ** 2)
        nrm[:, f, 0] = nxv / mag
        nrm[:, f, 1] = nyv / mag
        sJ[:, f] = mag * J
    fscale = sJ / J[:, None]

    # connectivity by matching face node coordinates
    vmapM = np.zeros((E, 3, nq), np.int64)
    vmapP = np.zeros((E, 3, nq), np.int64)
    for e in range(E):
        for f in range(3):
            vmapM[e, f] = e * np_ + fmask[f]
    # face centers for matching
    fx = x.reshape(E, np_)[:, fmask]          # (E, 3, nfp)
    fy = y.reshape(E, np_)[:, fmask]
    centers = {}
    for e in range(E):
        for f in range(3):
            key = (round(float(fx[e, f].mean()), 8), round(float(fy[e, f].mean()), 8))
            centers.setdefault(key, []).append((e, f))
    boundary = np.zeros((E, 3), bool)
    for key, faces in centers.items():
        if len(faces) == 1:
            e, f = faces[0]
            vmapP[e, f] = vmapM[e, f]
            boundary[e, f] = True
            continue
        (e1, f1), (e2, f2) = faces
        # match nodes by coordinates
        for (ea, fa, eb, fb) in ((e1, f1, e2, f2), (e2, f2, e1, f1)):
            xa, ya = fx[ea, fa], fy[ea, fa]
            xb, yb = fx[eb, fb], fy[eb, fb]
            d2 = (xa[:, None] - xb[None, :]) ** 2 + (ya[:, None] - yb[None, :]) ** 2
            match = d2.argmin(axis=1)
            assert (np.sort(match) == np.arange(nq)).all()
            vmapP[ea, fa] = eb * np_ + fmask[fb][match]
    return dict(fmask=fmask, vmapM=vmapM.astype(np.int32),
                vmapP=vmapP.astype(np.int32), normals=nrm, fscale=fscale,
                boundary=boundary,
                lift=lift_matrix(n, r, s, mesh["V"], fmask))


def dg_surface_builder(D):
    """Surface kernel: numerical flux (local Lax-Friedrichs) + LIFT.

    The face-neighbor gather (the 'communication') happens OUTSIDE the
    kernel (GPU-DG practice); the kernel consumes pre-gathered face traces.
    Defines: E, np_, nfp3, eb, g, dtype.
    """
    dtype = jnp.dtype(D.dtype)
    np_, nfp3, eb, g = D.np_, D.nfp3, D.eb, D.g

    def body(ctx, qm, qp, nrm, lift, out):
        QM = qm[...]                      # (eb, 3nfp, 3)
        QP = qp[...]
        Ge = nrm[...]                     # (eb, 3nfp, 3): nx, ny, fscale
        L = ctx.cache(lift)               # (np_, 3nfp) shared
        ctx.barrier()
        nx_, ny_, fsc = Ge[..., 0], Ge[..., 1], Ge[..., 2]

        def flux(Q):
            h, hu, hv = Q[..., 0], Q[..., 1], Q[..., 2]
            u, v = hu / h, hv / h
            gh2 = 0.5 * g * h * h
            Fn = jnp.stack([hu * nx_ + hv * ny_,
                            (hu * u + gh2) * nx_ + hu * v * ny_,
                            hu * v * nx_ + (hv * v + gh2) * ny_], -1)
            lam = jnp.abs(u * nx_ + v * ny_) + jnp.sqrt(g * h)
            return Fn, lam

        FM, lamM = flux(QM)
        FP, lamP = flux(QP)
        C = jnp.maximum(lamM, lamP)[..., None]
        fstar = 0.5 * (FM + FP) + 0.5 * C * (QM - QP)
        dflux = (FM - fstar) * fsc[..., None]              # (eb, 3nfp, 3)
        out[...] = jnp.einsum("nf,efq->enq", L, dflux).astype(dtype)

    return Spec(
        "dg_swe_surface",
        grid=(D.E // eb,),
        inputs=[
            Tile("qm", (D.E, nfp3, 3), dtype, block=(eb, nfp3, 3),
                 index=lambda e: (e, 0, 0)),
            Tile("qp", (D.E, nfp3, 3), dtype, block=(eb, nfp3, 3),
                 index=lambda e: (e, 0, 0)),
            Tile("nrm", (D.E, nfp3, 3), dtype, block=(eb, nfp3, 3),
                 index=lambda e: (e, 0, 0)),
            Tile("lift", (D.np_, nfp3), dtype),
        ],
        outputs=[Tile("out", (D.E, D.np_, 3), dtype, block=(eb, D.np_, 3),
                      index=lambda e: (e, 0, 0))],
        body=body,
    )


def surface_ref(QM, QP, nrm, lift, g=GRAV):
    """Independent pure-jnp oracle for the surface-flux kernel: local
    Lax-Friedrichs numerical flux on pre-gathered face traces + LIFT."""
    nx_, ny_, fsc = nrm[..., 0], nrm[..., 1], nrm[..., 2]

    def flux(Q):
        h, hu, hv = Q[..., 0], Q[..., 1], Q[..., 2]
        u, v = hu / h, hv / h
        gh2 = 0.5 * g * h * h
        Fn = jnp.stack([hu * nx_ + hv * ny_,
                        (hu * u + gh2) * nx_ + hu * v * ny_,
                        hu * v * nx_ + (hv * v + gh2) * ny_], -1)
        lam = jnp.abs(u * nx_ + v * ny_) + jnp.sqrt(g * h)
        return Fn, lam

    FM, lamM = flux(QM)
    FP, lamP = flux(QP)
    C = jnp.maximum(lamM, lamP)[..., None]
    fstar = 0.5 * (FM + FP) + 0.5 * C * (QM - QP)
    dflux = (FM - fstar) * fsc[..., None]
    return jnp.einsum("nf,efq->enq", lift, dflux)


# low-storage 5-stage RK (Carpenter/Kennedy)
_LSERK_A = (0.0, -567301805773 / 1357537059087, -2404267990393 / 2016746695238,
            -3550918686646 / 2091501179385, -1275806237668 / 842570457699)
_LSERK_B = (1432997174477 / 9575080441755, 5161836677717 / 13612068292357,
            1720146321549 / 2090206949498, 3134564353537 / 4481467310338,
            2277821191437 / 14882151754819)


class SWESolver(DGVolume):
    """Full shallow-water solver: volume + surface kernels + LSERK."""

    def __init__(self, **kw):
        super().__init__(**kw)
        m = self.mesh
        nx = int(np.sqrt(self.E // 2))
        self.conn = build_connectivity(nx, nx, self.n, m)
        nfp3 = 3 * (self.n + 1)
        self.nfp3 = nfp3
        nrm = np.repeat(self.conn["normals"], self.n + 1, axis=1)  # (E,3nfp,2)
        fsc = np.repeat(self.conn["fscale"], self.n + 1, axis=1)   # (E,3nfp)
        self.o_nrm = self.device.malloc(
            np.concatenate([nrm, fsc[..., None]], -1).astype(self.dtype))
        self.o_lift = self.device.malloc(self.conn["lift"].astype(self.dtype))
        self.vmapM = jnp.asarray(self.conn["vmapM"].reshape(self.E, nfp3))
        self.vmapP = jnp.asarray(self.conn["vmapP"].reshape(self.E, nfp3))
        self.bnd = jnp.asarray(
            np.repeat(self.conn["boundary"], self.n + 1, axis=1))  # (E,3nfp)
        self.nrm_j = jnp.asarray(nrm)

        from repro.kernels.apps import dg_surface as dgs_op  # late: avoid cycle
        shapes = (jax.ShapeDtypeStruct((self.E, nfp3, 3), self.dtype),
                  jax.ShapeDtypeStruct((self.E, nfp3, 3), self.dtype),
                  jax.ShapeDtypeStruct((self.E, nfp3, 3), self.dtype),
                  jax.ShapeDtypeStruct((self.np_, nfp3), self.dtype))
        params = dgs_op.cached_winner(
            shapes, backend=self.device.backend,
            interpret=self.device.interpret) or dict(eb=self.eb)
        defines = dgs_op.derive_defines(shapes, {**dgs_op.defaults, **params})
        self.surf_kernel = self.device.build_kernel(dg_surface_builder, defines)

    def rhs(self, Q):
        Qf = Q.reshape(self.E * self.np_, 3)
        QM = Qf[self.vmapM]                        # (E, 3nfp, 3)
        QP = Qf[self.vmapP]
        # reflective wall: mirror the normal momentum on boundary faces
        nx_, ny_ = self.nrm_j[..., 0], self.nrm_j[..., 1]
        qn = QM[..., 1] * nx_ + QM[..., 2] * ny_
        wall = jnp.stack([QM[..., 0],
                          QM[..., 1] - 2 * qn * nx_,
                          QM[..., 2] - 2 * qn * ny_], -1)
        QP = jnp.where(self.bnd[..., None], wall, QP)
        if QM.dtype != self.dtype:  # gathers preserve dtype; cast only if not
            QM, QP = QM.astype(self.dtype), QP.astype(self.dtype)
        (surf,) = self.surf_kernel.run(QM, QP,
                                       self.o_nrm.data, self.o_lift.data)
        return self.rhs_volume(Q) + surf

    def step(self, Q, dt):
        res = jnp.zeros_like(Q)
        for a, b in zip(_LSERK_A, _LSERK_B):
            res = a * res + dt * self.rhs(Q)
            Q = Q + b * res
        return Q

    def mass(self, Q):
        """Total water volume (exact nodal quadrature via the mass matrix)."""
        V = self.mesh["V"]
        M = np.linalg.inv(V @ V.T)
        w = jnp.asarray((M @ np.ones(self.np_)) * 1.0)
        return jnp.einsum("en,n,e->", Q[..., 0], w, jnp.asarray(self.mesh["J"]))
