"""Paper §4.2 — spectral-element screened-Coulomb operator, unified kernel.

Discrete operator  A u = K u + alpha M u  on hexahedral elements with GLL
tensor-product bases:  K u = D_r^T (G . D u)  with per-node symmetric
geometric factors G (kappa * J * w * (grad r_p . grad r_q)) and lumped mass
M = J * w.  One kernel source; jnp / loops / pallas expansions.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Device, Spec, Tile
from .numerics import dmatrix_1d, gll_nodes_weights

__all__ = [
    "sem_builder", "SEMOperator", "make_box_mesh", "geometric_factors",
    "apply_ref", "sem_flops_per_element", "sem_bytes_per_element",
    "gather", "scatter_add",
]


# ---------------------------------------------------------------------------
# kernel (one source, three backends)
# ---------------------------------------------------------------------------

def sem_builder(D):
    """Defines: E, nq (=N+1), eb (elements/block), dtype."""
    dtype = jnp.dtype(D.dtype)
    nq, eb = D.nq, D.eb

    def body(ctx, u, geo, dmat, out):
        U = u[...]                     # (eb, nq, nq, nq)
        G = geo[...]                   # (eb, 7, nq, nq, nq)
        Dm = ctx.cache(dmat)           # (nq, nq) shared across the block
        ctx.barrier()
        # local derivatives (tensor contractions -> MXU)
        ur = jnp.einsum("am,embc->eabc", Dm, U)
        us = jnp.einsum("bm,eamc->eabc", Dm, U)
        ut = jnp.einsum("cm,eabm->eabc", Dm, U)
        # geometric factors (symmetric 3x3 per node, kappa*J*w folded in)
        wr = G[:, 0] * ur + G[:, 1] * us + G[:, 2] * ut
        ws = G[:, 1] * ur + G[:, 3] * us + G[:, 4] * ut
        wt = G[:, 2] * ur + G[:, 4] * us + G[:, 5] * ut
        # weak derivatives (transposed contractions) + lumped mass
        au = (jnp.einsum("ma,embc->eabc", Dm, wr)
              + jnp.einsum("mb,eamc->eabc", Dm, ws)
              + jnp.einsum("mc,eabm->eabc", Dm, wt)
              + G[:, 6] * U)
        out[...] = au.astype(dtype)

    return Spec(
        "sem_ax",
        grid=(D.E // eb,),
        inputs=[
            Tile("u", (D.E, nq, nq, nq), dtype, block=(eb, nq, nq, nq),
                 index=lambda e: (e, 0, 0, 0)),
            Tile("geo", (D.E, 7, nq, nq, nq), dtype, block=(eb, 7, nq, nq, nq),
                 index=lambda e: (e, 0, 0, 0, 0)),
            Tile("dmat", (nq, nq), dtype),               # whole-array (shared)
        ],
        outputs=[Tile("out", (D.E, nq, nq, nq), dtype, block=(eb, nq, nq, nq),
                      index=lambda e: (e, 0, 0, 0))],
        body=body,
    )


def apply_ref(u, geo, dmat):
    """Independent pure-jnp oracle (whole-array einsum)."""
    ur = jnp.einsum("am,embc->eabc", dmat, u)
    us = jnp.einsum("bm,eamc->eabc", dmat, u)
    ut = jnp.einsum("cm,eabm->eabc", dmat, u)
    wr = geo[:, 0] * ur + geo[:, 1] * us + geo[:, 2] * ut
    ws = geo[:, 1] * ur + geo[:, 3] * us + geo[:, 4] * ut
    wt = geo[:, 2] * ur + geo[:, 4] * us + geo[:, 5] * ut
    return (jnp.einsum("ma,embc->eabc", dmat, wr)
            + jnp.einsum("mb,eamc->eabc", dmat, ws)
            + jnp.einsum("mc,eabm->eabc", dmat, wt)
            + geo[:, 6] * u)


def sem_flops_per_element(nq: int) -> int:
    return 12 * nq ** 4 + 22 * nq ** 3


def sem_bytes_per_element(nq: int, itemsize: int) -> int:
    return (1 + 7 + 1) * nq ** 3 * itemsize


# ---------------------------------------------------------------------------
# mesh + geometric factors (host-side, float64 -> cast)
# ---------------------------------------------------------------------------

def make_box_mesh(ex: int, ey: int, ez: int, n: int, *, deform: float = 0.0,
                  seed: int = 0):
    """Structured hex mesh of [-1,1]^3, optionally smoothly deformed.

    Returns nodal coords x,y,z of shape (E, nq,nq,nq) and the local->global
    dof map (E, nq,nq,nq) int32 for continuous (C0) assembly.
    """
    nq = n + 1
    gll, _ = gll_nodes_weights(n)
    E = ex * ey * ez

    # global 1D node lines per direction (elements share boundary nodes)
    def line(ne):
        pts = []
        edges = np.linspace(-1, 1, ne + 1)
        for e in range(ne):
            a, b = edges[e], edges[e + 1]
            pts.append((a + b) / 2 + (b - a) / 2 * gll)
        return np.array(pts)  # (ne, nq)

    lx, ly, lz = line(ex), line(ey), line(ez)
    x = np.zeros((E, nq, nq, nq))
    y = np.zeros((E, nq, nq, nq))
    z = np.zeros((E, nq, nq, nq))
    gid = np.zeros((E, nq, nq, nq), dtype=np.int64)
    ngx, ngy, ngz = ex * n + 1, ey * n + 1, ez * n + 1
    e = 0
    for kz in range(ez):
        for ky in range(ey):
            for kx in range(ex):
                # index convention: u[a,b,c] ~ (r,s,t) ~ (x,y,z)
                X = lx[kx][:, None, None]
                Y = ly[ky][None, :, None]
                Z = lz[kz][None, None, :]
                x[e] = np.broadcast_to(X, (nq, nq, nq))
                y[e] = np.broadcast_to(Y, (nq, nq, nq))
                z[e] = np.broadcast_to(Z, (nq, nq, nq))
                ia = kx * n + np.arange(nq)
                ib = ky * n + np.arange(nq)
                ic = kz * n + np.arange(nq)
                gid[e] = (ia[:, None, None] * ngy * ngz
                          + ib[None, :, None] * ngz + ic[None, None, :])
                e += 1
    if deform:
        # smooth, invertible-for-small-amplitude deformation
        x2 = x + deform * np.sin(np.pi * x) * np.cos(np.pi * y) * np.cos(np.pi * z)
        y2 = y + deform * np.cos(np.pi * x) * np.sin(np.pi * y) * np.cos(np.pi * z)
        z2 = z + deform * np.cos(np.pi * x) * np.cos(np.pi * y) * np.sin(np.pi * z)
        x, y, z = x2, y2, z2
    nglob = ngx * ngy * ngz
    return (x, y, z), gid.astype(np.int32), nglob


def geometric_factors(coords, n: int, *, kappa=None, alpha: float = 1.0):
    """Per-node symmetric factors G (E,7,nq,nq,nq): 6 stiffness + 1 mass."""
    x, y, z = coords
    nq = n + 1
    D = dmatrix_1d(n)
    _, w = gll_nodes_weights(n)
    w3 = w[:, None, None] * w[None, :, None] * w[None, None, :]

    def deriv(f, axis):
        return np.einsum("am,embc->eabc" if axis == 0 else
                         ("bm,eamc->eabc" if axis == 1 else "cm,eabm->eabc"), D, f)

    xr, xs, xt = deriv(x, 0), deriv(x, 1), deriv(x, 2)
    yr, ys, yt = deriv(y, 0), deriv(y, 1), deriv(y, 2)
    zr, zs, zt = deriv(z, 0), deriv(z, 1), deriv(z, 2)
    J = (xr * (ys * zt - yt * zs) - yr * (xs * zt - xt * zs)
         + zr * (xs * yt - xt * ys))
    assert np.all(J > 0), "mesh deformation too large (negative Jacobian)"
    rx = (ys * zt - yt * zs) / J
    ry = -(xs * zt - xt * zs) / J
    rz = (xs * yt - xt * ys) / J
    sx = -(yr * zt - yt * zr) / J
    sy = (xr * zt - xt * zr) / J
    sz = -(xr * yt - xt * yr) / J
    tx = (yr * zs - ys * zr) / J
    ty = -(xr * zs - xs * zr) / J
    tz = (xr * ys - xs * yr) / J

    if kappa is None:
        kap = np.ones_like(J)
    else:
        kap = kappa(x, y, z)
    scale = kap * J * w3[None]
    G = np.stack([
        scale * (rx * rx + ry * ry + rz * rz),
        scale * (rx * sx + ry * sy + rz * sz),
        scale * (rx * tx + ry * ty + rz * tz),
        scale * (sx * sx + sy * sy + sz * sz),
        scale * (sx * tx + sy * ty + sz * tz),
        scale * (tx * tx + ty * ty + tz * tz),
        alpha * J * w3[None],
    ], axis=1)
    return G, J * w3[None]


# --- continuous (C0) gather/scatter — paper ref [10] global-local numbering --

def gather(u_glob, gid):
    return u_glob[gid]


def scatter_add(u_loc, gid, nglob):
    import jax.ops  # noqa: F401
    return jnp.zeros(nglob, u_loc.dtype).at[gid.reshape(-1)].add(u_loc.reshape(-1))


class SEMOperator:
    """Host driver: builds the kernel once per (backend, defines) and applies
    the assembled (gather-scatter) operator to global dof vectors.

    ``eb=None`` (default) adopts the persisted ``sem_apply`` autotune winner
    for this shape/backend when one exists, else the op default fitted to E;
    an explicit ``eb`` pins the block."""

    def __init__(self, *, model: str = "jnp", ex: int = 2, ey: int = 2, ez: int = 2,
                 n: int = 4, eb: int | None = None, deform: float = 0.15,
                 alpha: float = 1.0, kappa=None, dtype="float32", seed: int = 0):
        self.device = Device(model)
        self.n, self.nq = n, n + 1
        coords, self.gid, self.nglob = make_box_mesh(ex, ey, ez, n, deform=deform,
                                                     seed=seed)
        self.E = self.gid.shape[0]
        G, self.mass = geometric_factors(coords, n, kappa=kappa, alpha=alpha)
        self.dtype = np.dtype(dtype)
        self.o_geo = self.device.malloc(G.astype(self.dtype))
        self.o_dmat = self.device.malloc(dmatrix_1d(n).astype(self.dtype))

        from repro.kernels.apps import sem_apply as sem_op  # late: avoid cycle
        nq = self.nq
        shapes = (jax.ShapeDtypeStruct((self.E, nq, nq, nq), self.dtype),
                  jax.ShapeDtypeStruct((self.E, 7, nq, nq, nq), self.dtype),
                  jax.ShapeDtypeStruct((nq, nq), self.dtype))
        if eb is None:
            params = sem_op.cached_winner(
                shapes, backend=self.device.backend,
                interpret=self.device.interpret) or {}
        else:
            params = dict(eb=eb)
        defines = sem_op.derive_defines(shapes, {**sem_op.defaults, **params})
        self.eb = defines["eb"]
        self.kernel = self.device.build_kernel(sem_builder, defines)
        self.gid_j = jnp.asarray(self.gid)

    def apply_local(self, u_local):
        if not isinstance(u_local, jax.Array):
            u_local = jnp.asarray(u_local)  # per-call asarray on a jax array
        (out,) = self.kernel.run(u_local, self.o_geo.data,   # costs ~2x the
                                 self.o_dmat.data)           # kernel itself
        return out

    def apply_global(self, u_glob):
        u_loc = gather(u_glob, self.gid_j)
        au_loc = self.apply_local(u_loc)
        return scatter_add(au_loc, self.gid_j, self.nglob)
