"""``op.tune`` CLI — fleet-wide kernel pre-tuning.

Sweeps registered ops' tuning knobs on real shapes and persists the winners
under ``$REPRO_CACHE_DIR`` (default ``~/.cache/repro-occa``); every later
``launch.serve`` / ``launch.train`` on the same hardware adopts them for
free at warmup (``launch.tuning.adopt`` — a pure cache lookup, zero builds).

  # everything a serving + training deployment of an arch will hit
  PYTHONPATH=src python -m repro.tune_cli --arch llama3_2_1b --reduced \\
      --batch 4 --prompt-len 16 --max-len 64 --seq-len 64

  # one op on its example shapes (a smoke-sized sweep)
  PYTHONPATH=src python -m repro.tune_cli --op matmul --backend jnp

  # the paper's app workloads (fd2d / sem_apply / dg_volume / dg_surface)
  # at the benchmark smoke shapes — the drivers then adopt the winners
  PYTHONPATH=src python -m repro.tune_cli --apps

  # what is tunable
  PYTHONPATH=src python -m repro.tune_cli --list

  # audit persisted winners: ops gone from the registry, or defines that now
  # fail the kernel static analyzer (repro.core.analyze); --evict drops them
  PYTHONPATH=src python -m repro.tune_cli --lint [--evict]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["main"]


def _materialize(struct, rng, vocab: int):
    """A ShapeDtypeStruct probe -> a real array (labels get valid token ids)."""
    dtype = jnp.dtype(struct.dtype)
    if dtype == jnp.int32:
        return jnp.asarray(
            rng.randint(0, max(int(vocab), 1), struct.shape), jnp.int32)
    return jnp.asarray(rng.standard_normal(struct.shape), jnp.float32
                       ).astype(dtype)


def _tune_probe(op, args, params, *, backend, repeats, cache):
    r = op.tune(tuple(args), backend=backend, repeats=repeats, cache=cache,
                **params)
    if r.cached:
        state = "cache hit"
    else:
        pruned = r.pruned
        invalid = len(r.skipped) - len(pruned)
        state = (f"{len(r.trials)} trials, {len(pruned)} pruned, "
                 f"{invalid} skipped")
    winner = {k: r[k] for k in sorted(op.sweep)}
    print(f"[tune] {op.name}: winner {winner} "
          f"({state}, best {r.best_seconds * 1e6:.0f} us)")
    if not r.cached:
        for cand, reason in r.pruned:
            over = {k: cand[k] for k in sorted(op.sweep)}
            print(f"[tune]   pruned {over}: {reason}")
    return winner


def _app_probes():
    """(op name, real args, params) probes for the paper's app workloads at
    the benchmark smoke shapes — built THROUGH the drivers, so the tuned
    cache keys are exactly the (shape, dtype, param) tuples the drivers'
    ``cached_winner`` lookups produce at construction time."""
    from repro.apps import dg_swe, sem
    from repro.apps import fd2d as fd_app

    rng = np.random.RandomState(0)
    app = fd_app.FDWave(model="jnp", width=32, height=32, radius=1)
    yield ("fd2d", (app.o_u1.data, app.o_u2.data),
           dict(weights=app.weights, dx=float(app.dx), dt=float(app.dt)))
    for n in (1, 2):
        nq = n + 1
        op = sem.SEMOperator(model="jnp", ex=2, ey=2, ez=2, n=n, deform=0.1)
        u = jnp.asarray(rng.standard_normal((op.E, nq, nq, nq)), jnp.float32)
        yield ("sem_apply", (u, op.o_geo.data, op.o_dmat.data), {})
        vol = dg_swe.DGVolume(model="jnp", nx=4, ny=4, n=n, jitter=0.1)
        Q = jnp.asarray(np.stack([
            2.0 + 0.1 * rng.standard_normal((vol.E, vol.np_)),
            0.3 * rng.standard_normal((vol.E, vol.np_)),
            0.3 * rng.standard_normal((vol.E, vol.np_))], -1), jnp.float32)
        yield ("dg_volume", (Q, vol.o_geom.data, vol.o_db.data,
                             vol.o_dr.data, vol.o_ds.data), {})
        sol = dg_swe.SWESolver(model="jnp", nx=4, ny=4, n=n, jitter=0.0)
        Qf = Q.reshape(sol.E * sol.np_, 3)
        yield ("dg_surface", (Qf[sol.vmapM], Qf[sol.vmapP],
                              sol.o_nrm.data, sol.o_lift.data), {})


def _tune_apps(ops, *, backend, repeats, cache) -> int:
    backends = (("jnp", "loops", "pallas") if backend == "auto" else (backend,))
    probes = list(_app_probes())
    for be in backends:
        print(f"[tune] apps backend={be}")
        for name, arrays, params in probes:
            try:
                _tune_probe(ops[name], arrays, params, backend=be,
                            repeats=repeats, cache=cache)
            except ValueError as e:
                print(f"[tune] {name}: skipped ({e})")
    return 0


def _lint_cache(ops, *, evict: bool) -> int:
    """Audit every persisted autotune winner under ``$REPRO_CACHE_DIR``:
    flag entries whose op left the registry, whose stored defines no longer
    parse/build, or whose winner defines now fail the static analyzer —
    including the cost model's VMEM budget (``analyze_spec`` reports
    ``VMEM_OVERFLOW`` under the current ``$REPRO_VMEM_BUDGET``, so a stale
    winner tuned under a larger budget cannot resurrect oversized tiles).
    ``evict=True`` deletes flagged entries. Returns a process exit code
    (1 when problems remain on disk)."""
    import ast
    import json

    from repro.core import analyze_spec, tune_cache_dir
    from repro.core.analyze import AnalysisError
    from repro.core.lang import defines_namespace

    root = tune_cache_dir() / "autotune"
    entries = sorted(root.glob("*.json")) if root.is_dir() else []
    bad = 0
    for path in entries:
        try:
            entry = json.loads(path.read_text())
        except (OSError, ValueError):
            entry, problem = {}, "corrupt JSON"
        else:
            problem = None
        name = entry.get("op", "?")
        op = ops.get(name)
        if problem is None and op is None:
            problem = "op no longer registered"
        if problem is None:
            try:
                # base defines are persisted as reprs (the cache-key payload);
                # the winner holds the swept keys as real JSON values
                defines = {k: ast.literal_eval(v)
                           for k, v in entry.get("defines", {}).items()}
                cand = dict(defines, **entry.get("winner", {}))
                spec = op.builder(defines_namespace(cand))
                findings = analyze_spec(spec, defines_namespace(cand)).findings
                if findings:
                    problem = "; ".join(str(f) for f in findings)
            except AnalysisError as e:
                problem = str(e)
            except Exception as e:
                problem = f"winner no longer builds ({type(e).__name__}: {e})"
        if problem is None:
            continue
        bad += 1
        action = "evicting" if evict else "stale"
        print(f"[lint] {action} {path.name} (op {name!r}): {problem}")
        if evict:
            try:
                path.unlink()
            except OSError:
                pass
    print(f"[lint] {len(entries)} cached winners, {bad} stale"
          f"{' (evicted)' if evict and bad else ''}"
          f"{'; re-run with --evict to drop them' if bad and not evict else ''}")
    return 0 if (bad == 0 or evict) else 1


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--list", action="store_true",
                    help="list registered ops and their tuning sweeps")
    ap.add_argument("--lint", action="store_true",
                    help="audit persisted winners against the registry and "
                         "the kernel static analyzer")
    ap.add_argument("--evict", action="store_true",
                    help="with --lint: delete the flagged cache entries")
    ap.add_argument("--op", default=None,
                    help="tune ONE op on its declared example shapes")
    ap.add_argument("--apps", action="store_true",
                    help="tune the paper's app workloads (fd2d, sem_apply, "
                         "dg_volume, dg_surface) at the benchmark smoke "
                         "shapes; --backend auto sweeps jnp+loops+pallas")
    ap.add_argument("--arch", default=None,
                    help="tune every op a serving+training deployment of "
                         "this arch hits (repro.launch.tuning probe shapes)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--serve", action="store_true",
                    help="with --arch: only the serving probes")
    ap.add_argument("--train", action="store_true",
                    help="with --arch: only the train-step probes")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="with --arch: also pre-tune ring attention for an "
                         "N-way model mesh — the probe is the PER-SHARD "
                         "shape (prompt-len / N) and the persisted winner is "
                         "keyed on the shard extent (ring_steps=N)")
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--no-cache", action="store_true",
                    help="sweep without persisting winners (a dry run)")
    args = ap.parse_args(argv)

    import repro.kernels  # noqa: F401 — registers the op families
    from repro.core import registered_ops

    ops = registered_ops()
    if args.lint:
        return _lint_cache(ops, evict=args.evict)
    if args.evict:
        ap.error("--evict only makes sense with --lint")
    if args.list:
        from repro.lint_kernels import cost_op

        for name in sorted(ops):
            op = ops[name]
            sweep = {k: op.sweep[k] for k in sorted(op.sweep)}
            print(f"{name}: sweep={sweep or '(none)'}")
            if not op.sweep:
                continue
            try:  # static prune preview at the op's example shapes
                c = cost_op(ops[name], np.random.RandomState(0))
            except Exception:
                continue
            total = c["sweep_kept"] + len(c["sweep_pruned"])
            print(f"  static prune preview (example shapes): "
                  f"{len(c['sweep_pruned'])}/{total} candidates pruned")
            for p in c["sweep_pruned"]:
                print(f"    {p['overrides']}: {p['reason']}")
        return 0

    cache = not args.no_cache
    if args.apps:
        return _tune_apps(ops, backend=args.backend, repeats=args.repeats,
                          cache=cache)
    if args.op is not None:
        op = ops.get(args.op)
        if op is None:
            ap.error(f"unknown op {args.op!r}; known: {sorted(ops)}")
        if not op.sweep:
            ap.error(f"op {args.op!r} declares no tuning sweep")
        ex_args, ex_params = op.example(np.random.RandomState(0))
        try:
            _tune_probe(op, tuple(jnp.asarray(a) for a in ex_args), ex_params,
                        backend=args.backend, repeats=args.repeats, cache=cache)
        except ValueError as e:
            # example shapes are smoke-sized; sweep candidates may not tile
            # them — real deployments tune through --arch (real shapes)
            print(f"[tune] {op.name}: {e} — the example shapes are smoke-"
                  "sized; tune real shapes via --arch")
            return 1
        return 0

    if args.arch is None:
        ap.error("pass --list, --op NAME or --arch NAME")

    from repro.configs import get_config, reduced as reduce_cfg
    from repro.launch.tuning import mesh_probes, serving_probes, train_probes

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    max_len = args.max_len or (args.prompt_len + 32)
    probes = {}
    both = not (args.serve ^ args.train)
    if args.serve or both:
        probes.update(serving_probes(cfg, args.batch, args.prompt_len, max_len))
    if args.train or both:
        probes.update(train_probes(cfg, args.batch, args.seq_len))
    if args.mesh:
        try:
            probes.update(mesh_probes(cfg, args.batch, args.prompt_len,
                                      shards=args.mesh))
        except ValueError as e:
            ap.error(str(e))

    print(f"[tune] arch={args.arch} backend={args.backend} "
          f"probes={sorted(probes)} (device={jax.default_backend()})")
    rng = np.random.RandomState(0)
    for name in sorted(probes):
        op = ops.get(name)
        if op is None or not op.sweep:
            continue
        structs, params = probes[name]
        real = tuple(_materialize(s, rng, cfg.vocab_size) for s in structs)
        try:
            _tune_probe(op, real, params, backend=args.backend,
                        repeats=args.repeats, cache=cache)
        except ValueError as e:
            print(f"[tune] {name}: skipped ({e})")
    from repro.core import tune_cache_dir
    if cache:
        print(f"[tune] winners persisted under {tune_cache_dir()} — serving "
              "and training warmup adopt them automatically")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
