"""Mamba layers: mamba1 (falcon-mamba) and mamba2/SSD (zamba2).

Training uses memory-sane chunked scans (lax.scan over time chunks — nothing
(B, L, D, N)-shaped is ever materialized); mamba1 can route through the
fused Pallas ssm_scan kernel. Decode is a single-step state update.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import selective_scan_assoc, ssm_scan
from repro.parallel.context import shard_activation

from .common import dense_init, kernel_backend, silu, softplus

__all__ = [
    "mamba1_init", "mamba1_forward", "mamba1_cache_init", "mamba1_decode",
    "mamba2_init", "mamba2_forward", "mamba2_cache_init", "mamba2_decode",
    "ssd_ref",
]


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, C); w: (K, C); b: (C,)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    L = x.shape[1]
    for j in range(k):
        y = y + w[j] * jax.lax.dynamic_slice_in_dim(pad, j, L, axis=1)
    return y + b


def _rms_nw(x, eps=1e-6):
    """Weightless RMS normalization (falcon-mamba dt/B/C norm)."""
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)).astype(x.dtype)


# ===========================================================================
# mamba1
# ===========================================================================

def mamba1_init(rng, cfg, dtype):
    d, di = cfg.d_model, cfg.resolved_d_inner
    n, kc, r = cfg.ssm_state, cfg.ssm_conv, cfg.resolved_dt_rank
    keys = jax.random.split(rng, 6)
    dt_w = dense_init(keys[3], (r, di), jnp.float32, scale=r ** -0.5)
    # dt bias init so softplus(bias) spans [1e-3, 1e-1] (mamba convention)
    u = jax.random.uniform(keys[4], (di,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    kx, kz = jax.random.split(keys[0])
    return {
        # x and z projections are SEPARATE weights: a fused (d, 2*di) weight
        # sharded over the model axis puts xi on shards 0..7 and z on 8..15,
        # and GSPMD reshards both halves with collective-permutes (§Perf it2)
        "in_x": dense_init(kx, (d, di), dtype),
        "in_z": dense_init(kz, (d, di), dtype),
        "conv_w": dense_init(keys[1], (kc, di), jnp.float32, scale=kc ** -0.5),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(keys[2], (di, r + 2 * n), dtype),
        "dt_w": dt_w,
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                          (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[5], (di, d), dtype),
    }


def _mamba1_dtbc(params, xi, cfg):
    n, r = cfg.ssm_state, cfg.resolved_dt_rank
    dbc = xi @ params["x_proj"]
    dt_r, Bm, Cm = (dbc[..., :r], dbc[..., r:r + n], dbc[..., r + n:])
    if cfg.ssm_bcdt_norm:
        dt_r, Bm, Cm = _rms_nw(dt_r), _rms_nw(Bm), _rms_nw(Cm)
    dt = softplus(dt_r @ params["dt_w"] + params["dt_bias"])
    return dt, Bm, Cm


def _chunked_scan_jnp(x, dt, A, Bm, Cm, D, *, chunk=128):
    """lax.scan over time chunks, associative scan within each chunk."""
    b, L, dm = x.shape
    n = A.shape[1]
    chunk = min(chunk, L)
    while L % chunk:
        chunk -= 1
    nc = L // chunk

    def body(h, args):
        xc, dtc, bc, cc = args
        y, hT = selective_scan_assoc(xc, dtc, A, bc, cc, D, h0=h)
        return hT, y

    resh = lambda a: a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    hT, ys = jax.lax.scan(body, jnp.zeros((b, dm, n), jnp.float32),
                          (resh(x), resh(dt), resh(Bm), resh(Cm)))
    y = ys.swapaxes(0, 1).reshape(b, L, dm)
    return y, hT


def mamba1_forward(params, x, cfg):
    """x: (B, L, d_model) -> (B, L, d_model)."""
    di = cfg.resolved_d_inner
    xi = x @ params["in_x"]
    z = x @ params["in_z"]
    xi = shard_activation(xi, "act_btf")
    xi = silu(_causal_conv(xi, params["conv_w"], params["conv_b"]).astype(xi.dtype))
    dt, Bm, Cm = _mamba1_dtbc(params, xi, cfg)
    A = -jnp.exp(params["A_log"])
    if kernel_backend() == "pallas":
        y = ssm_scan(xi, dt, A, Bm, Cm, params["D"])
    else:
        y, _ = _chunked_scan_jnp(xi, dt, A, Bm, Cm, params["D"])
    y = y * silu(z)
    y = shard_activation(y, "act_btf")
    out = (y @ params["out_proj"]).astype(x.dtype)
    return shard_activation(out, "act_btd")


def mamba1_cache_init(cfg, batch, dtype):
    di, n, kc = cfg.resolved_d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, kc - 1, di), dtype),
        "h": jnp.zeros((batch, di, n), jnp.float32),
    }


def mamba1_decode(params, x, cache, cfg):
    """x: (B, 1, d_model) single-step update."""
    di = cfg.resolved_d_inner
    xi = x @ params["in_x"]                                  # (B,1,di)
    z = x @ params["in_z"]
    win = jnp.concatenate([cache["conv"], xi.astype(cache["conv"].dtype)], axis=1)
    conv_out = (win * params["conv_w"]).sum(axis=1, keepdims=True) + params["conv_b"]
    xi = silu(conv_out.astype(xi.dtype))
    dt, Bm, Cm = _mamba1_dtbc(params, xi, cfg)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)                      # (B,di,N)
    dBx = (dt[:, 0, :, None] * Bm[:, 0, None, :] * xi[:, 0, :, None]).astype(jnp.float32)
    h = dA * cache["h"] + dBx
    y = (h * Cm[:, 0, None, :]).sum(-1) + params["D"] * xi[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)) * silu(z)
    new_cache = {"conv": win[:, 1:], "h": h}
    return y @ params["out_proj"], new_cache


# ===========================================================================
# mamba2 (SSD) — zamba2 backbone; ngroups=1, scalar A per head
# ===========================================================================

def mamba2_init(rng, cfg, dtype):
    d, di = cfg.d_model, cfg.resolved_d_inner
    n, kc, p = cfg.ssm_state, cfg.ssm_conv, cfg.ssm_head_dim
    h = di // p
    keys = jax.random.split(rng, 4)
    conv_dim = di + 2 * n
    u = jax.random.uniform(keys[2], (h,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    kz, kxbc, kdt = jax.random.split(keys[0], 3)
    return {
        # separate projections (see mamba1_init: avoids cross-shard slicing)
        "in_z": dense_init(kz, (d, di), dtype),
        "in_xbc": dense_init(kxbc, (d, di + 2 * n), dtype),
        "in_dt": dense_init(kdt, (d, h), dtype),
        "conv_w": dense_init(keys[1], (kc, conv_dim), jnp.float32, scale=kc ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.ones((h,), jnp.float32) * 1.0
                         + jax.random.uniform(keys[2], (h,), jnp.float32) * 15.0),
        "dt_bias": dt0 + jnp.log(-jnp.expm1(-dt0)),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[3], (di, d), dtype),
    }


def _ssd_chunk(S, xc, dtc, A, bc, cc):
    """One SSD chunk. S: (B,H,P,N) carry; xc: (B,c,H,P); dtc: (B,c,H);
    bc/cc: (B,c,N). Returns (S', y (B,c,H,P))."""
    a = dtc * A                                              # (B,c,H) (negative)
    cs = jnp.cumsum(a, axis=1)                               # inclusive
    # intra-chunk: G[b,h,i,j] = exp(cs_i - cs_j) dt_j (C_i . B_j), j <= i
    scores = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))              # (B,c,c)
    decay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])   # (B,i,j,H)
    c_len = xc.shape[1]
    tri = jnp.tril(jnp.ones((c_len, c_len), bool))
    G = jnp.where(tri[None, :, :, None], scores[:, :, :, None] * decay
                  * dtc[:, None, :, :], 0.0)                 # (B,i,j,H)
    y_intra = jnp.einsum("bijh,bjhp->bihp", G, xc.astype(jnp.float32))
    # inter-chunk: exp(cs_i) * C_i . S
    y_inter = jnp.exp(cs)[..., None] * jnp.einsum(
        "bin,bhpn->bihp", cc.astype(jnp.float32), S)
    # state update
    w = jnp.exp(cs[:, -1:, :] - cs) * dtc                    # (B,c,H)
    S_new = (jnp.exp(cs[:, -1])[:, :, None, None] * S
             + jnp.einsum("bjh,bjn,bjhp->bhpn", w, bc.astype(jnp.float32),
                          xc.astype(jnp.float32)))
    return S_new, y_intra + y_inter


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential oracle. x: (B,L,H,P); dt: (B,L,H); A: (H,); Bm/Cm: (B,L,N)."""
    b, L, h, p = x.shape
    n = Bm.shape[-1]

    def step(S, args):
        xt, dtt, bt, ct = args
        dA = jnp.exp(dtt * A)                                # (B,H)
        S = dA[:, :, None, None] * S + dtt[:, :, None, None] * \
            jnp.einsum("bn,bhp->bhpn", bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, S)
        return S, y

    S0 = jnp.zeros((b, h, p, n), jnp.float32)
    sw = lambda a: a.swapaxes(0, 1).astype(jnp.float32)
    _, ys = jax.lax.scan(step, S0, (sw(x), sw(dt), sw(Bm), sw(Cm)))
    return ys.swapaxes(0, 1)                                  # (B,L,H,P)


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk=128, h0=None):
    b, L, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, L)
    while L % chunk:
        chunk -= 1
    nc = L // chunk
    S0 = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0

    def body(S, args):
        xc, dtc, bc, cc = args
        return _ssd_chunk(S, xc, dtc, A, bc, cc)

    resh = lambda a: a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    ST, ys = jax.lax.scan(body, S0, (resh(x.astype(jnp.float32)),
                                     resh(dt.astype(jnp.float32)),
                                     resh(Bm), resh(Cm)))
    return ys.swapaxes(0, 1).reshape(b, L, h, p), ST


def mamba2_forward(params, x, cfg, *, return_state=False, h0=None):
    b, L, _ = x.shape
    di, n, p = cfg.resolved_d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = di // p
    z = x @ params["in_z"]
    xBC = x @ params["in_xbc"]
    dt = (x @ params["in_dt"]).astype(jnp.float32)            # (B,L,H)
    xBC = silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]).astype(xBC.dtype))
    xi = xBC[..., :di]
    Bm = xBC[..., di:di + n]
    Cm = xBC[..., di + n:]
    dt = softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    if kernel_backend() == "pallas" and not return_state:
        # mamba2 maps EXACTLY onto the fused per-channel kernel (ngroups=1):
        # per-head dt/A/D broadcast to their channels, B/C stay shared —
        # the same recurrence the SSD form factorizes per head.
        dt_ch = jnp.repeat(dt, p, axis=-1)                     # (B,L,di)
        A_ch = jnp.broadcast_to(jnp.repeat(A, p)[:, None], (di, n))
        y = ssm_scan(xi, dt_ch, A_ch, Bm, Cm, jnp.repeat(params["D"], p))
        y = y.reshape(b, L, di).astype(x.dtype)                # D-skip in-kernel
        ST = None
    else:
        xh = xi.reshape(b, L, h, p)
        y, ST = ssd_chunked(xh, dt, A, Bm, Cm, h0=h0)
        y = y + params["D"][:, None] * xh.astype(jnp.float32)
        y = y.reshape(b, L, di).astype(x.dtype)
    # gated RMSNorm (mamba2)
    y = y * silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + cfg.norm_eps)
         * params["norm_w"]).astype(x.dtype)
    y = shard_activation(y, "act_btf")
    out = (y @ params["out_proj"]).astype(x.dtype)
    out = shard_activation(out, "act_btd")
    if return_state:
        return out, ST
    return out


def mamba2_cache_init(cfg, batch, dtype):
    di, n, kc, p = (cfg.resolved_d_inner, cfg.ssm_state, cfg.ssm_conv,
                    cfg.ssm_head_dim)
    h = di // p
    return {
        "conv": jnp.zeros((batch, kc - 1, di + 2 * n), dtype),
        "h": jnp.zeros((batch, h, p, n), jnp.float32),
    }


def mamba2_decode(params, x, cache, cfg):
    b = x.shape[0]
    di, n, p = cfg.resolved_d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = di // p
    z = x @ params["in_z"]
    xBC = x @ params["in_xbc"]
    dt = (x @ params["in_dt"]).astype(jnp.float32)
    win = jnp.concatenate([cache["conv"], xBC.astype(cache["conv"].dtype)], axis=1)
    conv_out = (win * params["conv_w"]).sum(axis=1, keepdims=True) + params["conv_b"]
    xBC = silu(conv_out.astype(xBC.dtype))
    xi = xBC[..., :di]
    Bm = xBC[..., di:di + n].astype(jnp.float32)
    Cm = xBC[..., di + n:].astype(jnp.float32)
    dt = softplus(dt + params["dt_bias"])[:, 0]               # (B,H)
    A = -jnp.exp(params["A_log"])
    xh = xi.reshape(b, h, p).astype(jnp.float32)
    dA = jnp.exp(dt * A)                                      # (B,H)
    S = dA[:, :, None, None] * cache["h"] + dt[:, :, None, None] * \
        jnp.einsum("bn,bhp->bhpn", Bm[:, 0], xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], S) + params["D"][:, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype) * silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + cfg.norm_eps)
         * params["norm_w"]).astype(x.dtype)
    new_cache = {"conv": win[:, 1:], "h": S}
    return y @ params["out_proj"], new_cache
