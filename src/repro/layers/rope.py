"""Rotary and sinusoidal position embeddings."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope", "sinusoidal_embedding"]


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, D) with even D; positions: (S,) or (B, S) or scalar."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    pos = jnp.asarray(positions, jnp.float32)
    angles = pos[..., None] * freqs                    # (..., S, D/2)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    while cos.ndim < x.ndim:                           # broadcast to (B,H,S,D/2)
        cos = cos[None]
        sin = sin[None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions, d_model: int):
    """(S,) -> (S, d_model) classic transformer sinusoids."""
    pos = jnp.asarray(positions, jnp.float32)[..., None]
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
