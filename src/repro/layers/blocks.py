"""Block compositions: pre-norm transformer blocks (dense/MoE, GQA/MLA) and
mamba blocks, each with train / prefill / decode entry points.

Every entry point returns a uniform aux vector [moe_lb_loss, moe_z_loss]
(zeros for non-MoE blocks) so layer stacks scan homogeneously.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.parallel.context import shard_activation

from . import attention as attn
from . import mamba as mb
from .common import rmsnorm
from .mlp import mlp_forward, mlp_init
from .moe import moe_forward, moe_init

__all__ = [
    "tblock_init", "tblock_forward", "tblock_prefill", "tblock_decode",
    "tblock_cache_init", "tblock_paged_decode", "tblock_paged_cache_init",
    "mamba_block_init", "mamba_block_forward", "mamba_block_prefill",
    "mamba_block_decode", "mamba_block_cache_init",
    "ZERO_AUX",
]

ZERO_AUX = jnp.zeros(2, jnp.float32)


def _aux_vec(aux: dict | None):
    if not aux:
        return ZERO_AUX
    return jnp.stack([aux["moe_lb_loss"], aux["moe_z_loss"]]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# transformer block (attention + mlp/moe)
# ---------------------------------------------------------------------------

def tblock_init(rng, cfg, dtype, *, moe: bool):
    import jax
    k0, k1 = jax.random.split(rng)
    params = {
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.attn_type == "mla":
        params["attn"] = attn.mla_init(k0, cfg, dtype)
    else:
        params["attn"] = attn.gqa_init(k0, cfg, dtype)
    if moe:
        params["moe"] = moe_init(k1, cfg, dtype)
    else:
        params["mlp"] = mlp_init(k1, cfg.d_model, cfg.d_ff, dtype)
    return params


def _ffn(params, x, cfg, moe, dispatch):
    h = rmsnorm(x, params["norm2"], eps=cfg.norm_eps)
    if moe:
        y, aux = moe_forward(params["moe"], h, cfg, dispatch=dispatch)
        return y, _aux_vec(aux)
    return mlp_forward(params["mlp"], h), ZERO_AUX


def tblock_forward(params, x, cfg, *, moe=False, prefix_len=0,
                   dispatch="einsum", positions=None):
    h = rmsnorm(x, params["norm1"], eps=cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = attn.mla_forward(params["attn"], h, cfg, positions=positions)
    else:
        a = attn.gqa_forward(params["attn"], h, cfg, positions=positions,
                             prefix_len=prefix_len)
    x = x + a
    x = shard_activation(x, "act_btd")
    y, aux = _ffn(params, x, cfg, moe, dispatch)
    return x + y, aux


def tblock_cache_init(cfg, batch, max_len, dtype):
    if cfg.attn_type == "mla":
        return attn.mla_cache_init(cfg, batch, max_len, dtype)
    return attn.gqa_cache_init(cfg, batch, max_len, dtype)


def tblock_prefill(params, x, cfg, *, moe=False, max_len=None, prefix_len=0,
                   dispatch="einsum", cache_dtype=None):
    s = x.shape[1]
    max_len = max_len or s
    cache_dtype = cache_dtype or x.dtype
    h = rmsnorm(x, params["norm1"], eps=cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, latent = attn.mla_forward(params["attn"], h, cfg, return_latent=True)
        cache = attn.mla_cache_init(cfg, x.shape[0], max_len, cache_dtype)
        cache = attn.mla_prefill_cache(cache, latent, cfg)
    else:
        a, kv = attn.gqa_forward(params["attn"], h, cfg, prefix_len=prefix_len,
                                 return_kv=True)
        cache = attn.gqa_cache_init(cfg, x.shape[0], max_len, cache_dtype)
        cache = attn.gqa_prefill_cache(cache, kv[0].astype(cache_dtype),
                                       kv[1].astype(cache_dtype), cfg)
    x = x + a
    y, aux = _ffn(params, x, cfg, moe, dispatch)
    return x + y, aux, cache


def tblock_decode(params, x, cache, cfg, *, moe=False, dispatch="einsum"):
    h = rmsnorm(x, params["norm1"], eps=cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_decode(params["attn"], h, cache, cfg)
    else:
        a, cache = attn.gqa_decode(params["attn"], h, cache, cfg)
    x = x + a
    y, aux = _ffn(params, x, cfg, moe, dispatch)
    return x + y, cache


def tblock_paged_decode(params, x, cache, cfg, *, moe=False, dispatch="einsum",
                        table, lens, pos_pages, page_ids, offs):
    """``tblock_decode`` over a paged KV pool (GQA only — MLA's latent cache
    is gated off upstream by ``LM.init_paged_cache``)."""
    h = rmsnorm(x, params["norm1"], eps=cfg.norm_eps)
    a, cache = attn.gqa_paged_decode(params["attn"], h, cache, cfg,
                                     table=table, lens=lens,
                                     pos_pages=pos_pages,
                                     page_ids=page_ids, offs=offs)
    x = x + a
    y, aux = _ffn(params, x, cfg, moe, dispatch)
    return x + y, cache


def tblock_paged_cache_init(cfg, num_pages, page_size, dtype):
    return attn.gqa_paged_cache_init(cfg, num_pages, page_size, dtype)


# ---------------------------------------------------------------------------
# mamba blocks (mamba1 / mamba2)
# ---------------------------------------------------------------------------

def mamba_block_init(rng, cfg, dtype):
    init = mb.mamba1_init if cfg.ssm_type == "mamba1" else mb.mamba2_init
    return {
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
        "mixer": init(rng, cfg, dtype),
    }


def mamba_block_forward(params, x, cfg):
    h = rmsnorm(x, params["norm"], eps=cfg.norm_eps)
    if cfg.ssm_type == "mamba1":
        y = mb.mamba1_forward(params["mixer"], h, cfg)
    else:
        y = mb.mamba2_forward(params["mixer"], h, cfg)
    return x + y, ZERO_AUX


def mamba_block_cache_init(cfg, batch, dtype):
    init = mb.mamba1_cache_init if cfg.ssm_type == "mamba1" else mb.mamba2_cache_init
    return init(cfg, batch, dtype)


def mamba_block_prefill(params, x, cfg, *, cache_dtype=None):
    """Forward + cache extraction (final ssm state + conv tail)."""
    import jax.numpy as jnp_

    cache_dtype = cache_dtype or x.dtype
    h = rmsnorm(x, params["norm"], eps=cfg.norm_eps)
    p = params["mixer"]
    di = cfg.resolved_d_inner
    kc = cfg.ssm_conv
    if cfg.ssm_type == "mamba1":
        xi = h @ p["in_x"]
        z = h @ p["in_z"]
        conv_tail = xi[:, -(kc - 1):, :].astype(cache_dtype)
        xi = mb.silu(mb._causal_conv(xi, p["conv_w"], p["conv_b"]).astype(xi.dtype))
        dt, Bm, Cm = mb._mamba1_dtbc(p, xi, cfg)
        A = -jnp_.exp(p["A_log"])
        y, hT = mb._chunked_scan_jnp(xi, dt, A, Bm, Cm, p["D"])
        y = y * mb.silu(z)
        out = x + (y @ p["out_proj"])
        cache = {"conv": conv_tail, "h": hT}
        return out, ZERO_AUX, cache
    # mamba2
    xBC_raw = h @ p["in_xbc"]
    conv_tail = xBC_raw[:, -(kc - 1):, :].astype(cache_dtype)
    y, ST = _mamba2_forward_with_state(p, h, cfg)
    out = x + y
    cache = {"conv": conv_tail, "h": ST}
    return out, ZERO_AUX, cache


def _mamba2_forward_with_state(p, h, cfg):
    out, ST = mb.mamba2_forward(p, h, cfg, return_state=True)
    return out, ST


def mamba_block_decode(params, x, cache, cfg):
    h = rmsnorm(x, params["norm"], eps=cfg.norm_eps)
    if cfg.ssm_type == "mamba1":
        y, cache = mb.mamba1_decode(params["mixer"], h, cache, cfg)
    else:
        y, cache = mb.mamba2_decode(params["mixer"], h, cache, cfg)
    return x + y, cache
