"""Attention layers: GQA (covers MHA/MQA/SWA/prefix-LM) and MLA (deepseek).

Each variant provides init / forward (train+prefill) / cache init / decode.
The perf-critical realization is selected at run time via
``use_kernel_backend``: "pallas" -> repro.kernels flash kernels, "jnp" ->
oracle paths (mha_ref for short, mha_chunked for long sequences). Decode
under "pallas" runs the registered ``flash_decode`` op against the
preallocated cache for EVERY layout — dynamic ``kv_len`` masks the unfilled
tail, and rolling-window caches pass their rotated-slot position map as the
``slot_pos`` input tile; only the "jnp" path uses masked grouped einsums.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (decode_attention, decode_ref,
                                           flash_attention, mha_chunked,
                                           mha_ref, paged_decode_attention,
                                           paged_decode_ref,
                                           ring_flash_attention)
from repro.parallel.context import current_rules, shard_activation
from repro.parallel.rules import ring_axis_for

from .common import dense_init, kernel_backend, rmsnorm
from .rope import apply_rope

__all__ = [
    "gqa_init", "gqa_forward", "gqa_cache_init", "gqa_prefill_cache",
    "gqa_decode", "gqa_paged_cache_init", "gqa_paged_decode",
    "mla_init", "mla_forward", "mla_cache_init", "mla_prefill_cache",
    "mla_decode",
]

_CHUNKED_THRESHOLD = 8192  # jnp path switches to q-block-chunked beyond this


def _ring_target(seq_len):
    """(mesh, axis) when the ambient rules declare sequence-parallel ring
    attention for this sequence length, else (None, None). Callers opt in
    via ``Rules(ring_axis=...)`` (e.g. ``build_prefill_step(ring=True)``);
    the divisibility guard keeps ragged shapes on the GSPMD path."""
    rules = current_rules()
    if rules is None or rules.ring_axis is None or rules.mesh is None:
        return None, None
    ax = ring_axis_for(rules.mesh, seq_len, model_axis=rules.ring_axis)
    if ax is None:
        return None, None
    return rules.mesh, ax


# ===========================================================================
# GQA (MHA when Hk == H, MQA when Hk == 1, SWA via cfg.window)
# ===========================================================================

def gqa_init(rng, cfg, dtype):
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    return {
        "wq": dense_init(k0, (d, h * hd), dtype),
        "wk": dense_init(k1, (d, hk * hd), dtype),
        "wv": dense_init(k2, (d, hk * hd), dtype),
        "wo": dense_init(k3, (h * hd, d), dtype),
    }


def _qkv(params, x, cfg):
    b, s, d = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(b, s, hk, hd).transpose(0, 2, 1, 3)
    v = (x @ params["wv"]).reshape(b, s, hk, hd).transpose(0, 2, 1, 3)
    return q, k, v


def gqa_forward(params, x, cfg, *, positions=None, prefix_len=0,
                return_kv=False):
    """Full-sequence (train / prefill) attention. x: (B, S, d_model)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, cfg)
    if positions is None:
        positions = jnp.arange(s)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, "act_bhsd")
    k = shard_activation(k, "act_bhsd")

    ring_mesh, ring_ax = _ring_target(s)
    if ring_mesh is not None:
        # declared ring schedule: kv chunks rotate by ppermute inside
        # shard_map — no GSPMD-inferred collectives around the kernel
        o = ring_flash_attention(
            q, k, shard_activation(v, "act_bhsd"), mesh=ring_mesh,
            mesh_axis=ring_ax, causal=True, window=cfg.window,
            prefix_len=prefix_len,
            backend="auto" if kernel_backend() == "pallas" else "jnp")
    elif kernel_backend() == "pallas":
        o = flash_attention(q, k, v, causal=True, window=cfg.window,
                            prefix_len=prefix_len)
    elif s > _CHUNKED_THRESHOLD:
        o = mha_chunked(q, k, v, causal=True, window=cfg.window,
                        prefix_len=prefix_len)
    else:
        o = mha_ref(q, k, v, causal=True, window=cfg.window,
                    prefix_len=prefix_len)
    y = o.transpose(0, 2, 1, 3).reshape(b, s, -1) @ params["wo"]
    if return_kv:
        return y, (k, v)
    return y


def gqa_cache_init(cfg, batch, max_len, dtype):
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    m = min(max_len, cfg.window) if cfg.window else max_len
    cache = {
        "k": jnp.zeros((batch, hk, m, hd), dtype),
        "v": jnp.zeros((batch, hk, m, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.window:
        cache["slot_pos"] = jnp.full((m,), -1, jnp.int32)
    return cache


def gqa_prefill_cache(cache, k, v, cfg):
    """Fill cache from prefill k/v (B, Hk, S, hd); returns updated cache."""
    s = k.shape[2]
    m = cache["k"].shape[2]
    if cfg.window and s > m:
        # rolling window keeps the last W tokens; slot = pos % W
        last_pos = jnp.arange(s - m, s)
        slots = last_pos % m
        kk = k[:, :, -m:]
        vv = v[:, :, -m:]
        cache = dict(cache)
        cache["k"] = cache["k"].at[:, :, slots].set(kk)
        cache["v"] = cache["v"].at[:, :, slots].set(vv)
        cache["slot_pos"] = cache["slot_pos"].at[slots].set(last_pos)
        cache["pos"] = jnp.asarray(s, jnp.int32)
        return cache
    cache = dict(cache)
    n = min(s, m)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k[:, :, :n], (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v[:, :, :n], (0, 0, 0, 0))
    if cfg.window:
        cache["slot_pos"] = cache["slot_pos"].at[:n].set(jnp.arange(n))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return cache


def gqa_decode(params, x, cache, cfg):
    """One-token decode. x: (B, 1, d_model). Returns (y, new_cache)."""
    b = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pos = cache["pos"]                      # tokens already in cache
    q, k1, v1 = _qkv(params, x, cfg)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k1 = apply_rope(k1, pos, cfg.rope_theta)

    m = cache["k"].shape[2]
    cache = dict(cache)
    if cfg.window:
        slot = pos % m
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k1, (0, 0, slot, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v1, (0, 0, slot, 0))
        cache["slot_pos"] = jax.lax.dynamic_update_slice(
            cache["slot_pos"], pos[None], (slot,))
        kv_len = pos + 1
    else:
        # clamp so the traced write stays in bounds; decoding PAST the cache
        # is rejected host-side (LM.decode_step / launch.serve.generate)
        write = jnp.minimum(pos, m - 1)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k1, (0, 0, write, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v1, (0, 0, write, 0))
        kv_len = write + 1
    cache["pos"] = pos + 1

    if kernel_backend() == "pallas":
        # the registered flash_decode op on EVERY cache layout: one compiled
        # kernel for the whole decode loop, the growing valid length passed
        # as a traced kv_len. Rolling-window caches store ROTATED slots
        # (slot = pos % W); their data-dependent mask rides in as the
        # slot_pos input tile — the grouped-einsum fallback is gone.
        o = decode_attention(
            q, cache["k"], cache["v"], kv_len=kv_len,
            window=cfg.window if cfg.window else None,
            slot_pos=cache["slot_pos"] if cfg.window else None,
            sm_scale=hd ** -0.5)
    else:
        # the slot_pos-aware oracle covers BOTH layouts with one grouped
        # masked einsum (no kv replication in HBM; the cache is consumed in
        # its storage dtype) — positional caches pass the identity map
        o = decode_ref(q, cache["k"], cache["v"], kv_len=kv_len,
                       window=cfg.window if cfg.window else None,
                       slot_pos=(cache["slot_pos"] if cfg.window
                                 else jnp.arange(m)),
                       sm_scale=hd ** -0.5)
    y = o.transpose(0, 2, 1, 3).reshape(b, 1, -1) @ params["wo"]
    return y, cache


def gqa_paged_cache_init(cfg, num_pages, page_size, dtype):
    """Per-layer paged KV pools. Page 0 is the NULL page: inactive batch
    slots' block tables point at it and their per-step writes land there,
    so one compiled decode step serves any mix of live/idle slots."""
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "kp": jnp.zeros((num_pages, hk, page_size, hd), dtype),
        "vp": jnp.zeros((num_pages, hk, page_size, hd), dtype),
    }


def gqa_paged_decode(params, x, cache, cfg, *, table, lens, pos_pages,
                     page_ids, offs):
    """One-token decode over a PAGED cache. x: (B, 1, d_model).

    The KV pools are shared by every sequence; ``table`` ((B, n_seq_pages)
    i32) names each sequence's pages in logical order, ``lens`` ((B,) i32)
    its current length (the new token's position), ``pos_pages`` ((P, page)
    i32) the pool-slot -> absolute-position map (already including the new
    token), and ``page_ids``/``offs`` ((B,) each) the pool coordinates of
    the write — derived once per step by the model, not per layer. Returns
    (y, new {kp, vp}); attention reads KV exclusively through the block
    table (``flash_decode_paged``'s tile-indexed index maps — no contiguous
    gather on any backend)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k1, v1 = _qkv(params, x, cfg)
    if cfg.pos_embed == "rope":
        p = lens[:, None, None]                 # per-sequence positions
        q = apply_rope(q, p, cfg.rope_theta)
        k1 = apply_rope(k1, p, cfg.rope_theta)
    kp, vp = cache["kp"], cache["vp"]
    kp = kp.at[page_ids, :, offs].set(k1[:, :, 0].astype(kp.dtype))
    vp = vp.at[page_ids, :, offs].set(v1[:, :, 0].astype(vp.dtype))
    kv_len = lens + 1
    if kernel_backend() == "pallas":
        o = paged_decode_attention(q, kp, vp, block_table=table,
                                   kv_len=kv_len, pos_pages=pos_pages,
                                   window=cfg.window if cfg.window else None,
                                   sm_scale=hd ** -0.5)
    else:
        o = paged_decode_ref(q, kp, vp, block_table=table, kv_len=kv_len,
                             pos_pages=pos_pages,
                             window=cfg.window if cfg.window else None,
                             sm_scale=hd ** -0.5)
    y = o.transpose(0, 2, 1, 3).reshape(b, 1, -1) @ params["wo"]
    return y, {"kp": kp, "vp": vp}


# ===========================================================================
# MLA (deepseek-v2): latent-compressed KV; absorbed decode
# ===========================================================================

def mla_init(rng, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, dv, lora = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    return {
        "wq": dense_init(k0, (d, h * (nope + rope)), dtype),
        "wkv_a": dense_init(k1, (d, lora + rope), dtype),
        "kv_norm": jnp.ones((lora,), jnp.float32),
        "wkv_b": dense_init(k2, (lora, h * (nope + dv)), dtype),
        "wo": dense_init(k3, (h * dv, d), dtype),
    }


def _mla_qkr(params, x, cfg, positions):
    """Project to per-head q and the shared latent (c_kv, k_rope)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    lora = cfg.kv_lora_rank
    q = (x @ params["wq"]).reshape(b, s, h, nope + rope).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv_a = x @ params["wkv_a"]                          # (B,S,lora+rope)
    c_kv = rmsnorm(kv_a[..., :lora], params["kv_norm"], eps=cfg.norm_eps)
    k_rope = kv_a[..., None, lora:].transpose(0, 2, 1, 3)  # (B,1,S,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(params, x, cfg, *, positions=None, return_latent=False):
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, cfg, positions)
    kv = (c_kv @ params["wkv_b"]).reshape(b, s, h, nope + dv).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)       # (B,H,S,nope+rope)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, h, s, rope))], axis=-1)
    q = shard_activation(q, "act_bhsd")
    k = shard_activation(k, "act_bhsd")
    if kernel_backend() == "pallas":
        o = flash_attention(q, k, v, causal=True)
    elif s > _CHUNKED_THRESHOLD:
        o = mha_chunked(q, k, v, causal=True)
    else:
        o = mha_ref(q, k, v, causal=True)
    y = o.transpose(0, 2, 1, 3).reshape(b, s, -1) @ params["wo"]
    if return_latent:
        return y, (c_kv, k_rope[:, 0])                   # (B,S,lora), (B,S,rope)
    return y


def mla_cache_init(cfg, batch, max_len, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_prefill_cache(cache, latent, cfg):
    c_kv, k_rope = latent
    s = c_kv.shape[1]
    cache = dict(cache)
    cache["ckv"] = jax.lax.dynamic_update_slice(
        cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, 0, 0))
    cache["krope"] = jax.lax.dynamic_update_slice(
        cache["krope"], k_rope.astype(cache["krope"].dtype), (0, 0, 0))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return cache


def mla_decode(params, x, cache, cfg):
    """Absorbed-matmul decode: scores/outputs computed in latent space —
    the cache stays (lora+rope)-wide, W_uk/W_uv are folded into q / output."""
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope, dv, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                            cfg.kv_lora_rank)
    pos = cache["pos"]
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, cfg, pos)
    # write the new token's latent into the cache
    m = cache["ckv"].shape[1]
    write = jnp.minimum(pos, m - 1)
    cache = dict(cache)
    cache["ckv"] = jax.lax.dynamic_update_slice(
        cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, write, 0))
    cache["krope"] = jax.lax.dynamic_update_slice(
        cache["krope"], k_rope[:, 0].astype(cache["krope"].dtype), (0, write, 0))
    cache["pos"] = pos + 1

    wkv_b = params["wkv_b"].reshape(lora, h, nope + dv)
    w_uk = wkv_b[..., :nope]                              # (lora, H, nope)
    w_uv = wkv_b[..., nope:]                              # (lora, H, dv)
    # absorb W_uk into q: q_lat (B,H,lora). The latent cache is consumed in
    # its storage dtype (f32 MXU accumulation) — no f32 cache copy.
    cache_dt = cache["ckv"].dtype
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, :, 0], w_uk,
                       preferred_element_type=jnp.float32)
    sm_scale = (nope + rope) ** -0.5
    s = (jnp.einsum("bhl,bml->bhm", q_lat.astype(cache_dt), cache["ckv"],
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bmr->bhm", q_rope[:, :, 0].astype(cache_dt),
                      cache["krope"], preferred_element_type=jnp.float32))
    s = s * sm_scale
    mask = jnp.arange(m) <= write
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhm,bml->bhl", p.astype(cache_dt), cache["ckv"],
                       preferred_element_type=jnp.float32)  # (B,H,lora)
    o = jnp.einsum("bhl,lhd->bhd", o_lat.astype(x.dtype), w_uv,
                   preferred_element_type=jnp.float32)
    y = o.reshape(b, 1, h * dv).astype(x.dtype) @ params["wo"]
    return y, cache
