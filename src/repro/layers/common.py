"""Shared layer utilities: init, norms, kernel-backend selection."""

from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas_op
from repro.kernels.rmsnorm.ref import rmsnorm_ref

__all__ = [
    "dense_init", "rms_init", "rmsnorm", "kernel_backend", "use_kernel_backend",
    "silu", "softplus",
]

# Which realization the perf-critical ops use: "jnp" (XLA-fused reference,
# used for the multi-pod dry-run) or "pallas" (TPU kernels; interpret on CPU).
_kernel_backend: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_kernel_backend", default="jnp")


def kernel_backend() -> str:
    return _kernel_backend.get()


@contextlib.contextmanager
def use_kernel_backend(name: str):
    assert name in ("jnp", "pallas"), name
    tok = _kernel_backend.set(name)
    try:
        yield
    finally:
        _kernel_backend.reset(tok)


def dense_init(rng, shape, dtype, *, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(rng, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


def rms_init(shape):
    return jnp.ones(shape, jnp.float32)


def rmsnorm(x, w, *, eps=1e-6):
    if kernel_backend() == "pallas":
        return rmsnorm_pallas_op(x, w, eps=eps)
    return rmsnorm_ref(x, w, eps=eps)


def silu(x):
    return x * jax.nn.sigmoid(x)


def softplus(x):
    return jax.nn.softplus(x)
