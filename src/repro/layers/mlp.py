"""SwiGLU MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.context import shard_activation

from .common import dense_init, silu

__all__ = ["mlp_init", "mlp_forward"]


def mlp_init(rng, d_model: int, d_ff: int, dtype):
    k0, k1, k2 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k0, (d_model, d_ff), dtype),
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
    }


def mlp_forward(params, x):
    h = silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard_activation(h, "act_btf")
    return h @ params["w_down"]
