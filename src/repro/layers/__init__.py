"""Layer library: attention (GQA/MQA/SWA/MLA), SwiGLU, MoE, mamba1/2, norms."""

from . import attention, blocks, common, mamba, mlp, moe, rope  # noqa: F401
