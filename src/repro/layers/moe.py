"""Mixture-of-Experts with grouped GShard-style capacity dispatch.

Tokens are dispatched within groups (the batch dim) so the one-hot dispatch
tensor is (G, Tg, E, C) with per-group capacity — shardable over the data
axes and bounded in memory. Two dispatch realizations:

  "einsum"  — GShard/Switch one-hot einsum (baseline; paper-era standard)
  "gather"  — sort-free take-along-axis dispatch (beyond-paper optimization;
              ~zero dispatch FLOPs, used in the §Perf hillclimb)

Aux losses (load-balance + router z-loss) are returned for the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.context import shard_activation

from .common import dense_init, silu

__all__ = ["moe_init", "moe_forward"]


def moe_init(rng, cfg, dtype):
    d = cfg.d_model
    dff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    k0, k1, k2, k3, k4 = jax.random.split(rng, 5)
    params = {
        "router": dense_init(k0, (d, e), jnp.float32),
        "w_gate": dense_init(k1, (e, d, dff), dtype),
        "w_up": dense_init(k2, (e, d, dff), dtype),
        "w_down": dense_init(k3, (e, dff, d), dtype),
    }
    if cfg.n_shared_experts:
        sdff = dff * cfg.n_shared_experts
        s0, s1, s2 = jax.random.split(k4, 3)
        params["shared"] = {
            "w_gate": dense_init(s0, (d, sdff), dtype),
            "w_up": dense_init(s1, (d, sdff), dtype),
            "w_down": dense_init(s2, (sdff, d), dtype),
        }
    return params


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.n_experts_per_tok * cfg.capacity_factor
            / cfg.n_experts)
    return max(4, -(-c // 4) * 4)


def _router(params, x, cfg):
    """x: (G, T, d) -> gates (G,T,k), idx (G,T,k), aux losses."""
    logits = (x.astype(jnp.float32) @ params["router"])          # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.n_experts_per_tok)     # (G,T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # aux: load balance (Switch) + z-loss
    e = cfg.n_experts
    me = probs.mean(axis=(0, 1))                                 # (E,)
    top1 = jax.nn.one_hot(idx[..., 0], e).mean(axis=(0, 1))
    lb_loss = e * jnp.sum(me * top1)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gate, idx, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _dispatch_einsum(params, x, gate, idx, cfg):
    g, t, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    c = _capacity(t, cfg)
    dtype = x.dtype

    # position of each (token, choice) within its expert, priority by token id
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # (G,T,k,E)
    flat = onehot.reshape(g, t * k, e)                           # choice-major per token
    pos = jnp.cumsum(flat, axis=1) * flat - 1                    # (G,T*k,E)
    keep = (pos >= 0) & (pos < c)
    posc = jnp.clip(pos, 0, c - 1)
    # dispatch (G,T,k,E,C) -> combine over k
    disp = (jax.nn.one_hot(posc, c, dtype=dtype)
            * keep.astype(dtype)[..., None])                     # (G,T*k,E,C)
    disp = disp.reshape(g, t, k, e, c)
    combine = jnp.einsum("gtkec,gtk->gtec", disp, gate.astype(dtype))
    dispatch = disp.sum(axis=2)                                  # (G,T,E,C)

    ein = jnp.einsum("gtec,gtd->gecd", dispatch, x)              # (G,E,C,d)
    h = silu(jnp.einsum("gecd,edf->gecf", ein, params["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", ein, params["w_up"])
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])      # (G,E,C,d)
    y = jnp.einsum("gtec,gecd->gtd", combine, out)
    return y


def _dispatch_gather(params, x, gate, idx, cfg):
    """Index-based dispatch: scatter (token, gate) into (E, C) slot tables,
    gather expert inputs, scatter-add outputs. Same capacity/drop semantics
    as the einsum path but with ~zero dispatch FLOPs."""
    g, t, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    c = _capacity(t, cfg)

    def per_group(xg, gateg, idxg):
        flat_e = idxg.reshape(t * k)                              # expert of choice j
        flat_g = gateg.reshape(t * k)
        token_of = jnp.arange(t * k) // k
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (T*k, E)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                                  flat_e[:, None], axis=1)[:, 0]  # (T*k,)
        keep = pos < c
        slot = jnp.where(keep, flat_e * c + pos, e * c)           # OOB == dropped

        slot_token = jnp.zeros((e * c,), jnp.int32).at[slot].set(token_of, mode="drop")
        slot_gate = jnp.zeros((e * c,), jnp.float32).at[slot].set(flat_g, mode="drop")
        slot_valid = jnp.zeros((e * c,), x.dtype).at[slot].set(1.0, mode="drop")

        ein = (xg[slot_token] * slot_valid[:, None]).reshape(e, c, d)
        h = silu(jnp.einsum("ecd,edf->ecf", ein, params["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", ein, params["w_up"])
        out = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(e * c, d)
        out = out * (slot_gate[:, None].astype(out.dtype) * slot_valid[:, None])
        return jnp.zeros_like(xg).at[slot_token].add(out)

    return jax.vmap(per_group)(x, gate, idx)


def moe_forward(params, x, cfg, *, dispatch="einsum"):
    """x: (B, S, d) -> (y, aux). Tokens are dispatched within groups of
    ~cfg.moe_group_size (dispatch-tensor size and FLOPs scale with group
    size, so groups stay near 1k tokens — the GShard regime)."""
    b, s, d = x.shape
    gs = min(cfg.moe_group_size, s)
    while s % gs:
        gs -= 1
    xg = x.reshape(b * (s // gs), gs, d)
    gate, idx, aux = _router(params, xg, cfg)
    if dispatch == "gather":
        y = _dispatch_gather(params, xg, gate, idx, cfg)
    else:
        y = _dispatch_einsum(params, xg, gate, idx, cfg)
    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        sh = params["shared"]
        hs = silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        hs = shard_activation(hs, "act_btf")
        y = y + hs @ sh["w_down"]
    y = shard_activation(y, "act_btd")
    return y, aux
