"""Distributed step builders: sharded train / prefill / serve steps.

Builds the pjit-able step functions plus the NamedShardings for params,
optimizer state, batches and KV/SSM caches, wiring in the ambient
activation-sharding rules. Used by the launcher and by the multi-pod
dry-run (which lowers exactly these functions).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel import rules as R
from repro.parallel.context import Rules, use_rules

__all__ = [
    "axis_names", "make_shardings", "cache_pspecs", "paged_cache_pspecs",
    "build_train_step", "build_prefill_step", "build_serve_step",
    "build_paged_serve_step",
]


def axis_names(mesh: Mesh):
    names = mesh.axis_names
    batch_axes = tuple(n for n in names if n in ("pod", "data"))
    return batch_axes, "model"


def _named(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _join(a, b):
    """Combine two axis selections for one dim into a tuple spec entry."""
    if a is None:
        return b
    if b is None:
        return a
    at = a if isinstance(a, tuple) else (a,)
    bt = b if isinstance(b, tuple) else (b,)
    return at + bt


def make_shardings(model, mesh: Mesh, *, fsdp: bool = False,
                   ring: bool = False):
    """Returns (param_shardings, pspecs, rules, params_shape) for a model on
    a mesh. ``params_shape`` is the abstract init tree — step builders reuse
    it rather than re-tracing ``model.init`` a second time.

    ``fsdp=True`` additionally shards each param's largest replicated dim over
    the data axis (ZeRO-3 via GSPMD: XLA all-gathers weights per layer).
    ``ring=True`` declares sequence-parallel ring attention over the model
    axis: activations shard their sequence dim and attention runs the
    declared ``shard_map`` ring schedule when the length divides the axis."""
    batch_axes, model_axis = axis_names(mesh)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = R.param_specs(params_shape, model.cfg, mesh, model_axis=model_axis)
    if fsdp and "data" in mesh.axis_names:
        pspecs = R.zero1_specs(pspecs, params_shape, mesh, data_axis="data")
    ring_axis = model_axis if ring and mesh.shape[model_axis] > 1 else None
    rules = Rules(batch_axes=batch_axes, model_axis=model_axis, mesh=mesh,
                  ring_axis=ring_axis)
    return _named(mesh, pspecs), pspecs, rules, params_shape


# ---------------------------------------------------------------------------
# cache partition specs (per stack kind; base ranks are kind-specific)
# ---------------------------------------------------------------------------

def cache_pspecs(model, mesh: Mesh, batch: int, max_len: int,
                 kind: str = "decode"):
    """kind="decode": layouts optimized for per-token reads (seq-sharded
    fallback for kv_heads < model axis — §Perf it2). kind="prefill": the
    natural layout of the freshly computed k/v (head/head-dim sharded) —
    bulk-writing a 32k cache into the seq-sharded layout costs a full
    reshard per layer; the one-time handoff reshard at prefill->decode is
    the cheaper place to pay it (measured: v2 prefill regression)."""
    cfg = model.cfg
    batch_axes, m = axis_names(mesh)
    bsize = math.prod(mesh.shape[a] for a in batch_axes)
    b_ax = batch_axes if batch % bsize == 0 else None
    # if batch can't shard (long_500k B=1), shard the sequence dim instead
    seq_ax = None if b_ax is not None else batch_axes

    def div(dim, axis):
        if axis is None:
            return None
        axes = axis if isinstance(axis, tuple) else (axis,)
        size = math.prod(mesh.shape[a] for a in axes)
        return axis if dim % size == 0 else None

    hk, hd = max(cfg.n_kv_heads, 1), cfg.resolved_head_dim
    win = min(max_len, cfg.window) if cfg.window else max_len
    msize = mesh.shape[m]

    def attn_spec():
        if cfg.attn_type == "mla":
            lora = cfg.kv_lora_rank
            return {
                "ckv": P(b_ax, div(max_len, seq_ax), div(lora, m)),
                "krope": P(b_ax, div(max_len, seq_ax), None),
                "pos": P(),
            }
        # kv heads < model axis (GQA/MQA/MHA with few kv heads): for DECODE,
        # shard the cache SEQUENCE dim over the model axis — replicated 32k
        # caches would blow HBM, and head_dim sharding forces GSPMD to
        # replicate the cache around the decode einsums (involuntary full
        # rematerialization; measured in §Perf it2). Softmax over the
        # seq-sharded scores uses cheap partial-max/sum reductions. For
        # PREFILL, keep the computed k/v's natural layout (head-dim sharded).
        hd_ax = None
        if hk % msize == 0:
            head_ax, kseq_ax = m, div(win, seq_ax)
        elif kind == "decode":
            head_ax = None
            kseq_ax = _join(div(win, seq_ax), m if win % msize == 0 else None)
        else:  # prefill
            head_ax = None
            kseq_ax = div(win, seq_ax)
            hd_ax = m if hd % msize == 0 else None
        d = {
            "k": P(b_ax, head_ax, kseq_ax, hd_ax),
            "v": P(b_ax, head_ax, kseq_ax, hd_ax),
            "pos": P(),
        }
        if cfg.window:
            d["slot_pos"] = P(kseq_ax)
        return d

    def mamba_spec():
        if cfg.ssm_type == "mamba1":
            di = cfg.resolved_d_inner
            return {
                "conv": P(b_ax, None, div(di, m)),
                "h": P(b_ax, div(di, m), None),
            }
        di, n, p = cfg.resolved_d_inner, cfg.ssm_state, cfg.ssm_head_dim
        h = di // p
        return {
            "conv": P(b_ax, None, div(di + 2 * n, m)),
            "h": P(b_ax, div(h, m), None, None),
        }

    def prefixed(tree, n_extra):
        return jax.tree.map(lambda s: P(*([None] * n_extra + list(s))), tree,
                            is_leaf=lambda x: isinstance(x, P))

    stacks = []
    for spec in model.program:
        if spec.kind == "zamba_group":
            stacks.append({
                "mamba": prefixed(mamba_spec(), 2),
                "attn": prefixed(attn_spec(), 1),
            })
        elif spec.kind in ("mamba1", "mamba2"):
            stacks.append(prefixed(mamba_spec(), 1))
        else:
            stacks.append(prefixed(attn_spec(), 1))
    return {"pos": P(), "stacks": stacks}


def batch_pspecs(batch_shapes, mesh):
    batch_axes, _ = axis_names(mesh)
    return jax.tree.map(lambda s: P(*([batch_axes] + [None] * (len(s.shape) - 1))),
                        batch_shapes)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def build_train_step(model, optimizer, mesh: Mesh, *, zero1: bool = False,
                     fsdp: bool = False, accum_steps: int = 1,
                     batch_shapes=None):
    """Returns (jitted step, shardings dict). step(params, opt, batch) ->
    (params, opt, loss, metrics)."""
    param_sh, pspecs, act_rules, params_shape = make_shardings(
        model, mesh, fsdp=fsdp)
    if (zero1 or fsdp) and "data" in mesh.axis_names:
        moment_pspecs = R.zero1_specs(pspecs, params_shape, mesh,
                                      data_axis="data")
    else:
        moment_pspecs = pspecs
    opt_sh = {
        "m": _named(mesh, moment_pspecs),
        "v": _named(mesh, moment_pspecs),
        "step": NamedSharding(mesh, P()),
    }

    def loss_fn(params, batch):
        with use_rules(act_rules):
            return model.loss(params, batch)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {"ce": loss, "moe_lb": 0.0, "moe_z": 0.0}
        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, loss, metrics

    if batch_shapes is None:
        batch_sh = None
        jit_step = jax.jit(step, donate_argnums=(0, 1))
    else:
        batch_sh = _named(mesh, batch_pspecs(batch_shapes, mesh))
        jit_step = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, NamedSharding(mesh, P()), None),
            donate_argnums=(0, 1),
        )
    return jit_step, {"params": param_sh, "opt": opt_sh, "batch": batch_sh,
                      "pspecs": pspecs, "rules": act_rules}


def build_prefill_step(model, mesh: Mesh, *, batch: int, max_len: int,
                       batch_shapes=None, fsdp: bool = False,
                       ring: bool = False):
    """``ring=True`` opts prefill attention into the declared sequence-
    parallel ring schedule (see ``make_shardings``)."""
    param_sh, pspecs, act_rules, _ = make_shardings(model, mesh, fsdp=fsdp,
                                                    ring=ring)
    c_pspecs = cache_pspecs(model, mesh, batch, max_len, kind="prefill")
    cache_sh = _named(mesh, c_pspecs)

    def prefill(params, batch_):
        with use_rules(act_rules):
            return model.prefill(params, batch_["tokens"],
                                 prefix_embeddings=batch_.get("prefix_embeddings"),
                                 max_len=max_len)

    if batch_shapes is None:
        jit_fn = jax.jit(prefill)
        batch_sh = None
    else:
        batch_sh = _named(mesh, batch_pspecs(batch_shapes, mesh))
        jit_fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh),
                         out_shardings=(None, cache_sh))
    return jit_fn, {"params": param_sh, "batch": batch_sh, "cache": cache_sh,
                    "pspecs": pspecs, "rules": act_rules}


def build_serve_step(model, mesh: Mesh, *, batch: int, max_len: int,
                     greedy: bool = True):
    """One-token decode step over a sharded cache.

    ``greedy=True`` (the default — DEPRECATION: flipped from False, the
    served configuration is greedy + fused head; pass greedy=False
    explicitly for host-side sampling) routes through ``model.greedy_step``
    -> (next_token, logits, cache): with a fused LM head the argmax comes
    out of the logits kernel itself, so the host loop feeds tokens straight
    back without a device round-trip. ``greedy=False`` steps via
    ``model.decode_step`` -> (logits, cache), leaving sampling to the
    host."""
    param_sh, pspecs, act_rules, _ = make_shardings(model, mesh)
    c_pspecs = cache_pspecs(model, mesh, batch, max_len)
    cache_sh = _named(mesh, c_pspecs)
    batch_axes, _ = axis_names(mesh)
    bsize = math.prod(mesh.shape[a] for a in batch_axes)
    tok_sh = NamedSharding(mesh, P(batch_axes if batch % bsize == 0 else None,
                                   None))

    if greedy:
        def serve(params, cache, tokens):
            with use_rules(act_rules):
                nxt, logits, new_cache = model.greedy_step(
                    params, tokens, cache)
                return nxt, logits, new_cache
        out_sh = (None, None, cache_sh)
    else:
        def serve(params, cache, tokens):
            with use_rules(act_rules):
                return model.decode_step(params, tokens, cache)
        out_sh = (None, cache_sh)

    jit_fn = jax.jit(serve, in_shardings=(param_sh, cache_sh, tok_sh),
                     out_shardings=out_sh, donate_argnums=(1,))
    return jit_fn, {"params": param_sh, "cache": cache_sh, "tokens": tok_sh,
                    "cache_pspecs": c_pspecs, "pspecs": pspecs,
                    "rules": act_rules, "greedy": greedy}


def paged_cache_pspecs(model, mesh: Mesh, batch: int):
    """Partition specs for a paged decode cache. The page axis of the pools
    is a POOL dimension (any sequence's page can live anywhere), so it never
    shards over batch axes; kv heads shard over the model axis when they
    divide it, else the pool replicates (paged decode targets serving
    batches, where the pool is small next to the params). Tables, lengths
    and the position map are host-managed control state: replicated."""
    cfg = model.cfg
    _, m = axis_names(mesh)
    msize = mesh.shape[m]
    hk = max(cfg.n_kv_heads, 1)
    head_ax = m if hk % msize == 0 else None
    pool = {"kp": P(None, None, head_ax, None, None),
            "vp": P(None, None, head_ax, None, None)}
    return {"table": P(), "len": P(), "pos_pages": P(),
            "stacks": [dict(pool) for _ in model.program]}


def build_paged_serve_step(model, mesh: Mesh, *, batch: int,
                           greedy: bool = True):
    """One-token decode step over PAGED KV pools (the continuous-batching
    engine's inner loop). The cache (pools + block tables + lengths +
    pos_pages) is a single donated pytree; the host mutates only the control
    state (tables/lengths) between steps via the serving scheduler."""
    if not model.pageable:
        raise ValueError("build_paged_serve_step: model is not pageable "
                         "(see LM.pageable)")
    param_sh, pspecs, act_rules, _ = make_shardings(model, mesh)
    c_pspecs = paged_cache_pspecs(model, mesh, batch)
    cache_sh = _named(mesh, c_pspecs)
    tok_sh = NamedSharding(mesh, P(None, None))

    if greedy:
        def serve(params, cache, tokens):
            with use_rules(act_rules):
                return model.paged_greedy_step(params, tokens, cache)
        out_sh = (None, None, cache_sh)
    else:
        def serve(params, cache, tokens):
            with use_rules(act_rules):
                return model.paged_decode_step(params, tokens, cache)
        out_sh = (None, cache_sh)

    jit_fn = jax.jit(serve, in_shardings=(param_sh, cache_sh, tok_sh),
                     out_shardings=out_sh, donate_argnums=(1,))
    return jit_fn, {"params": param_sh, "cache": cache_sh, "tokens": tok_sh,
                    "cache_pspecs": c_pspecs, "pspecs": pspecs,
                    "rules": act_rules, "greedy": greedy}
