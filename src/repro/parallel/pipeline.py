"""Pipeline parallelism (GPipe) over a "pipe" mesh axis.

Stage weights live stage-sharded (leading dim = S over the pipe axis); M
microbatches stream through S stages with ``ppermute`` handoffs. The
schedule runs T = M + S - 1 ticks (bubble fraction (S-1)/T) inside a
``lax.scan``, so the whole pipeline is reverse-differentiable — backward
replays the schedule with reversed permutes (GPipe semantics, activations
rematerialized by the scan).

    y_mb = pipeline_apply(stage_fn, stage_params, x_mb, mesh=mesh)

``stage_fn(params_i, x) -> y`` must preserve x's shape/dtype (a residual
stack). Combine with DP/TP by adding the pipe axis to the mesh; stage
params specs get P("pipe", ...) prepended (see ``stage_param_specs``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "split_stages", "stage_param_specs"]


def split_stages(stacked_params, n_stages: int):
    """(L, ...) layer-stacked params -> (S, L/S, ...) stage-stacked."""
    def resh(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(resh, stacked_params)


def stage_param_specs(pspecs, axis: str = "pipe"):
    """Prepend the pipe axis to every stage-stacked param spec."""
    return jax.tree.map(lambda s: P(axis, *s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def pipeline_apply(stage_fn, stage_params, x_microbatches, *, mesh: Mesh,
                   axis: str = "pipe"):
    """Run (M, mb, ...) microbatches through S pipeline stages.

    stage_params: pytree with leading dim S, sharded P(axis, ...).
    Returns (M, mb, ...) outputs of the final stage (replicated over axis).
    """
    n_stages = mesh.shape[axis]
    m_micro = x_microbatches.shape[0]
    ticks = m_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def run(params_local, xs):
        # params_local: (1, ...) slice on this stage; xs: full (M, mb, ...)
        params_i = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(xs[0])

        def tick(buf, t):
            # stage 0 ingests microbatch t (clamped; masked past the end)
            feed = xs[jnp.clip(t, 0, m_micro - 1)]
            feed = jnp.where(t < m_micro, feed, zero)
            x_in = jnp.where(idx == 0, feed, buf)
            y = stage_fn(params_i, x_in)
            buf_next = jax.lax.ppermute(y, axis, perm)
            return buf_next, y

        _, ys = jax.lax.scan(tick, zero, jnp.arange(ticks))
        # the final stage emitted microbatch m at tick m + S - 1
        outs = ys[n_stages - 1:]
        # replicate the last stage's outputs to every pipe rank
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    pipe_spec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(run, mesh=mesh,
                   in_specs=(pipe_spec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_microbatches)
