from .context import Rules, shard_activation, use_rules  # noqa: F401
from .rules import batch_specs, param_specs, spec_bytes_per_device, zero1_specs  # noqa: F401
from .steps import (axis_names, build_prefill_step, build_serve_step,  # noqa: F401
                    build_train_step, cache_pspecs, make_shardings)
