"""Per-parameter PartitionSpec rules with divisibility checks.

Tensor-parallel layout over the "model" axis (Megatron conventions), DP over
("pod","data"). Stacked layer params (leading scan axes) get None-prefixed
specs. Any dim that does not divide its mesh axis falls back to replication
(e.g. KV heads < model axis — recorded in DESIGN.md). MoE experts shard over
"model" when divisible (EP, deepseek 64e) else expert FFN dims shard (TP-MoE,
mixtral 8e).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

__all__ = ["param_specs", "batch_specs", "zero1_specs",
           "spec_bytes_per_device", "ring_axis_for"]


def ring_axis_for(mesh, seq_len, *, model_axis="model"):
    """The mesh axis a sequence of ``seq_len`` can ring over, or None.

    Ring attention needs the model axis present, more than one shard, and an
    evenly divisible sequence (every shard runs the same kernel grid);
    callers use this to decide between the declared ring schedule and the
    plain GSPMD-sharded path."""
    if mesh is None:
        return None
    shape = dict(getattr(mesh, "shape", {}))
    n = int(shape.get(model_axis, 1))
    if n > 1 and seq_len % n == 0:
        return model_axis
    return None


# rule table: leaf name -> spec template for its BASE (unstacked) dims.
# "m" = model axis, None = replicated. Checked for divisibility at apply time.
_RULES_2D = {
    "embed": ("m", None),
    "head": (None, "m"),
    "wq": (None, "m"), "wk": (None, "m"), "wv": (None, "m"), "wo": ("m", None),
    "wkv_a": (None, None), "wkv_b": (None, "m"),
    "w_gate": (None, "m"), "w_up": (None, "m"), "w_down": ("m", None),
    "in_proj": (None, "m"), "out_proj": ("m", None),
    "in_x": (None, "m"), "in_z": (None, "m"),
    "in_xbc": (None, "m"), "in_dt": (None, "m"),
    "x_proj": ("m", None), "dt_w": (None, "m"),
    "conv_w": (None, "m"),
    "A_log": ("m", None),          # mamba1 (di, N)
    "router": (None, None),
}
_RULES_1D = {
    "conv_b": ("m",), "dt_bias": ("m",), "D": ("m",), "norm_w": ("m",),
    "A_log": ("m",),               # mamba2 (H,)
    "kv_norm": (None,),
    "norm": (None,), "norm1": (None,), "norm2": (None,), "final_norm": (None,),
    "embed": (None,),
}
# MoE expert stacks (E, d, f) / (E, f, d): EP over experts when divisible,
# else TP over the ffn dim.
_EXPERT_3D = {
    "w_gate": (("m", None, None), (None, None, "m")),
    "w_up": (("m", None, None), (None, None, "m")),
    "w_down": (("m", None, None), (None, "m", None)),
}


def _names_of(path):
    out = []
    for p in path:
        if isinstance(p, DictKey):
            out.append(str(p.key))
        elif isinstance(p, SequenceKey):
            out.append(f"[{p.idx}]")
    return out


def _apply_divisibility(template, shape, mesh, model_axis):
    spec = []
    msize = mesh.shape[model_axis]
    for dim, t in zip(shape, template):
        if t == "m" and dim % msize == 0:
            spec.append(model_axis)
        else:
            spec.append(None)
    return tuple(spec)


def param_specs(params, cfg, mesh, *, model_axis="model"):
    """Returns a pytree of PartitionSpec matching ``params``."""
    msize = mesh.shape[model_axis]

    def assign(path, leaf):
        names = _names_of(path)
        name = names[-1]
        nd = leaf.ndim

        # figure out base (unstacked) rank by peeling leading stack dims:
        # stacked layer params have 1 (stack) or 2 (zamba group) extra dims.
        in_stack = any(n == "stacks" for n in names)
        extra = 0
        base_shape = leaf.shape
        if in_stack:
            # zamba groups are (n, group, ...): detect via known base ranks
            for extra_try in (1, 2):
                base = leaf.shape[extra_try:]
                if name in _RULES_1D and len(base) == 1:
                    extra = extra_try
                    break
                if name in _RULES_2D and len(base) == 2:
                    extra = extra_try
                    break
                if name in _EXPERT_3D and len(base) == 3 and not (
                        name in _RULES_2D and len(base) == 2):
                    extra = extra_try
                    break
            else:
                extra = 1
            base_shape = leaf.shape[extra:]

        # MoE expert weights: base rank 3
        if name in _EXPERT_3D and len(base_shape) == 3:
            ep, tp = _EXPERT_3D[name]
            template = ep if base_shape[0] % msize == 0 else tp
            spec = _apply_divisibility(template, base_shape, mesh, model_axis)
            return P(*([None] * extra + list(spec)))

        if len(base_shape) == 1 and name in _RULES_1D:
            spec = _apply_divisibility(_RULES_1D[name], base_shape, mesh,
                                       model_axis)
            return P(*([None] * extra + list(spec)))

        if len(base_shape) == 2 and name in _RULES_2D:
            spec = _apply_divisibility(_RULES_2D[name], base_shape, mesh,
                                       model_axis)
            return P(*([None] * extra + list(spec)))

        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(assign, params)


def batch_specs(batch_shapes, *, batch_axes=("pod", "data")):
    """Shard every input's leading dim over the DP axes."""
    def assign(leaf):
        nd = len(leaf.shape)
        return P(*([batch_axes] + [None] * (nd - 1)))
    return jax.tree.map(assign, batch_shapes)


def zero1_specs(pspecs, params, mesh, *, data_axis="data"):
    """Optimizer-moment specs: param spec + shard the largest replicated dim
    over the data axis when divisible (ZeRO-1)."""
    dsize = mesh.shape[data_axis]

    def assign(spec, leaf):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        best, best_dim = -1, -1
        for i, (e, s) in enumerate(zip(entries, leaf.shape)):
            if e is None and s % dsize == 0 and s > best:
                best, best_dim = s, i
        if best_dim >= 0 and best >= 1024:
            entries[best_dim] = data_axis
        return P(*entries)

    return jax.tree.map(assign, pspecs, params,
                        is_leaf=lambda x: isinstance(x, P))


def spec_bytes_per_device(shapes, specs, mesh) -> int:
    """Bytes/device implied by the shardings (analytic memory check)."""
    total = 0
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for sds, spec in zip(flat_shapes, flat_specs):
        n = 1
        for i, d in enumerate(sds.shape):
            ax = spec[i] if i < len(spec) else None
            if ax is None:
                n *= d
            else:
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                n *= -(-d // size)
        total += n * sds.dtype.itemsize
    return total
