"""Ambient parallelism context: logical-axis activation sharding.

Models call ``shard_activation(x, kind)``; with no mesh configured this is a
no-op (CPU smoke tests), under ``use_rules(mesh_axes)`` it emits
``with_sharding_constraint`` with the mapped PartitionSpec. Kinds:

  "act_btd"  (batch, seq, d_model)       -> (batch_axes, seq_axes, None)
  "act_btf"  (batch, seq, features)      -> (batch_axes, None, "model")
  "act_bhsd" (batch, heads, seq, hd)     -> (batch_axes, "model", None, None)
  "act_bd"   (batch, d)                  -> (batch_axes, None)
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["shard_activation", "use_rules", "current_rules", "Rules"]


class Rules:
    def __init__(self, *, batch_axes=("pod", "data"), model_axis="model",
                 seq_axes=None, mesh=None, ring_axis=None):
        self.batch_axes = batch_axes
        self.model_axis = model_axis
        self.seq_axes = seq_axes
        self.mesh = mesh
        # sequence-parallel attention: when set, q/k/v shard their SEQUENCE
        # dim over this mesh axis and attention runs the declared ring
        # schedule (kernels.flash_attention.ring) instead of leaving GSPMD
        # to infer collectives around a head-sharded flash call
        self.ring_axis = ring_axis

    def spec(self, kind: str) -> Optional[P]:
        b, m, s = self.batch_axes, self.model_axis, self.seq_axes
        table = {
            "act_btd": P(b, s, None),
            "act_btf": P(b, None, m),
            "act_bhsd": (P(b, None, self.ring_axis, None) if self.ring_axis
                         else P(b, m, None, None)),
            "act_bd": P(b, None),
            "act_btv": P(b, None, m),
        }
        return table.get(kind)


_rules: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_parallel_rules", default=None)


def current_rules() -> Optional[Rules]:
    return _rules.get()


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    tok = _rules.set(rules)
    try:
        yield
    finally:
        _rules.reset(tok)


def shard_activation(x, kind: str):
    rules = _rules.get()
    if rules is None:
        return x
    spec = rules.spec(kind)
    if spec is None:
        return x
    if rules.mesh is not None:
        # drop axes that do not divide the dim — an invalid constraint would
        # either fail or push GSPMD into "involuntary full rematerialization"
        # (replicate-then-reshard), which shows up as huge collectives.
        entries = []
        for i, ax in enumerate(spec):
            if ax is None or i >= x.ndim:
                entries.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= rules.mesh.shape[a]
            entries.append(ax if x.shape[i] % size == 0 else None)
        spec = P(*entries)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # no mesh in scope (eager smoke test) — constraint is advisory
