from .adamw import AdamW, WarmupCosine, global_norm  # noqa: F401
