"""AdamW with global-norm clipping and a warmup-cosine schedule.

Pure pytree implementation (no optax dependency). Moment states are f32 and
carry their own PartitionSpecs (ZeRO-1 shards them over the data axis — see
parallel.rules.zero1_specs).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "WarmupCosine", "global_norm"]


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


@dataclasses.dataclass(frozen=True)
class WarmupCosine:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    final_frac: float = 0.1

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * step / max(self.warmup_steps, 1)
        denom = max(self.total_steps - self.warmup_steps, 1)
        t = jnp.clip((step - self.warmup_steps) / denom, 0.0, 1.0)
        cos = self.final_frac + (1 - self.final_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < self.warmup_steps, warm, self.peak_lr * cos)


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: WarmupCosine = WarmupCosine()
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}
