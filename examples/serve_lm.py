"""Batched serving example: train briefly, then serve batched requests with
prefill + jitted decode steps (greedy), reporting decode throughput.

  PYTHONPATH=src python examples/serve_lm.py
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import LM
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import generate
from repro.launch.train import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), vocab_size=2048)
    model = LM(cfg)
    mesh = make_local_mesh(model=1)

    # brief training so generations aren't pure noise
    loop = TrainLoop(model=model, mesh=mesh, global_batch=8, seq_len=64,
                     steps=args.train_steps, verbose=False)
    params = loop.run()["params"]

    # serve a batch of prompts drawn from the same distribution
    from repro.data import SyntheticLMData
    data = SyntheticLMData(vocab_size=cfg.vocab_size,
                           seq_len=args.prompt_len,
                           global_batch=args.batch, seed=123)
    prompts = data.batch(0)
    out, stats = generate(model, params, prompts, gen_tokens=args.gen,
                          mesh=mesh)
    print(f"[serve] prefill {stats['prefill_s']:.2f}s | "
          f"decode {stats['tokens_per_s']:.1f} tok/s "
          f"(batch={args.batch}, gen={out.shape[1]})")
    print("[serve] prompt -> continuation (first request):")
    print("   ", prompts[0, -8:].tolist(), "->", out[0, :12].tolist())
    assert np.isfinite(stats["tokens_per_s"]) and out.shape[0] == args.batch


if __name__ == "__main__":
    main()
