"""Paper §4.1 end-to-end: FD acoustic wave on every backend, with the host
API from listing 9 (setup / timestep / swap), validated against the analytic
standing wave.

  PYTHONPATH=src python examples/fd_wave.py [--backend jnp] [--size 256]
"""

import argparse
import time

import numpy as np

from repro.apps.fd2d import FDWave


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="all")
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    backends = ("jnp", "loops", "pallas") if args.backend == "all" \
        else (args.backend,)
    for backend in backends:
        n = args.size if backend == "jnp" else min(args.size, 96)
        steps = args.steps if backend == "jnp" else min(args.steps, 40)
        app = FDWave(model=backend, width=n, height=n, radius=2, cfl=0.3)
        t0 = time.time()
        app.run(steps)
        dt = time.time() - t0
        err = np.abs(app.solution - app.analytic()).max()
        mnodes = n * n * steps / dt / 1e6
        print(f"{backend:>7s}: {n}x{n}, {steps} steps, t={app.current_time:.3f} "
              f"max|err|={err:.2e}  {mnodes:8.1f} MNodes/s")
        assert err < 5e-2, f"{backend} diverged from analytic solution"
    print("FD wave equation: portable across backends, matches physics")


if __name__ == "__main__":
    main()
