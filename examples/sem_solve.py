"""Paper §4.2 end-to-end: screened-Coulomb solve with PCG on the SEM
operator (the paper's 'most computational-intensive routine' in the PCG
iteration is the kernel we benchmark), with gather-scatter C0 assembly and
a manufactured solution on the deformed box.

  PYTHONPATH=src python examples/sem_solve.py [--backend jnp] [--n 4]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.apps.sem import SEMOperator, gather, scatter_add


def pcg(apply_A, b, M_inv, *, tol=1e-8, maxiter=200):
    x = jnp.zeros_like(b)
    r = b - apply_A(x)
    z = M_inv * r
    p = z
    rz = jnp.vdot(r, z)
    for it in range(maxiter):
        Ap = apply_A(p)
        alpha = rz / jnp.vdot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        if float(jnp.linalg.norm(r)) < tol * float(jnp.linalg.norm(b)):
            return x, it + 1
        z = M_inv * r
        rz_new = jnp.vdot(r, z)
        p = z + (rz_new / rz) * p
        rz = rz_new
    return x, maxiter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--n", type=int, default=4)
    ap.add_argument("--elems", type=int, default=3)
    args = ap.parse_args()

    e = args.elems
    # -div(grad u) + u = f on [-1,1]^3 with homogeneous Neumann BC;
    # manufactured solution u* = cos(pi x) cos(pi y) cos(pi z).
    op = SEMOperator(model=args.backend, ex=e, ey=e, ez=e, n=args.n,
                     deform=0.0, alpha=1.0)

    # rebuild coordinates for the rhs (host-side)
    from repro.apps.sem import make_box_mesh
    (x, y, z), gid, nglob = make_box_mesh(e, e, e, args.n, deform=0.0)
    u_star = np.cos(np.pi * x) * np.cos(np.pi * y) * np.cos(np.pi * z)
    f = (3 * np.pi ** 2 + 1.0) * u_star

    # rhs = M f (lumped mass), assembled to global dofs
    rhs_loc = jnp.asarray((op.mass * f).astype(np.float32))
    rhs = scatter_add(rhs_loc, op.gid_j, op.nglob)

    # Jacobi preconditioner from the assembled lumped mass
    diag = scatter_add(jnp.asarray(op.mass.astype(np.float32)), op.gid_j,
                       op.nglob)
    M_inv = 1.0 / diag

    u, iters = pcg(op.apply_global, rhs, M_inv, tol=1e-7)
    u_loc = np.asarray(gather(u, op.gid_j))
    err = np.abs(u_loc - u_star).max()
    print(f"[sem] N={args.n}, E={op.E}, dofs={op.nglob}: PCG converged in "
          f"{iters} iters, max|u - u*| = {err:.3e}")
    assert err < 0.05, "SEM solve did not converge to the manufactured solution"


if __name__ == "__main__":
    main()
