"""End-to-end LM training driver: fault-tolerant loop, checkpoint + resume,
loss curve on the deterministic Markov corpus.

CPU-sized default (~15M params, a few hundred steps, minutes). On real
hardware pass --full for the ~1B-class config and a production mesh; the
same code path (sharded train step, ZeRO-1, remat) is what the multi-pod
dry-run lowers for 512 chips.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.models import LM
from repro.runtime import FailureInjector
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="use the full public config (real hardware)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(
            reduced(cfg), d_model=args.d_model, n_layers=args.layers,
            d_ff=4 * args.d_model, vocab_size=2048,
            head_dim=args.d_model // 4)
    model = LM(cfg)
    n_params = model.param_count(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))))
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.global_batch} x {args.seq_len}")

    injector = (FailureInjector([args.inject_failure])
                if args.inject_failure else None)
    loop = TrainLoop(model=model, mesh=make_local_mesh(model=1),
                     global_batch=args.global_batch, seq_len=args.seq_len,
                     steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=100, peak_lr=1e-3, injector=injector,
                     log_every=25)
    out = loop.run()
    h = out["history"]
    print(f"[example] loss {h[0]:.3f} -> {h[-1]:.3f} "
          f"({'improved' if h[-1] < h[0] - 0.5 else 'check hyperparams'})")
    assert h[-1] < h[0], "training must reduce loss"


if __name__ == "__main__":
    main()
