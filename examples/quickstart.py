"""Quickstart: declare an op ONCE, run it everywhere (the paper's core claim).

``define_op`` is the host API: you write (1) a kernel builder in the unified
language and (2) a pure oracle, and the front-end owns backend selection,
shape->defines derivation, the kernel build cache, autotuning and (when
declared) the custom VJP — the OCCA device/kernel/tuning surface as one
declaration.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import BACKENDS, Spec, Tile, define_op, get_op, registered_ops


# 1. Write the kernel ONCE (OCCA-style: grid of work-groups over tiles).
def axpby_builder(D):
    def body(ctx, x, y, out):
        # ctx.outer_id / ctx.lane_ids are the occaOuterId/occaInnerId analogues
        out[...] = D.alpha * x[...] + D.beta * y[...]

    return Spec(
        "axpby", grid=(D.n // D.bn,),
        inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,)),
                Tile("y", (D.n,), jnp.float32, block=(D.bn,))],
        outputs=[Tile("out", (D.n,), jnp.float32, block=(D.bn,))],
        body=body)


# 2. Write the oracle (what the kernel MUST compute, any backend).
def axpby_ref(x, y, *, alpha=2.0, beta=-0.5):
    return alpha * x + beta * y


# 3. Declare the op: shapes -> defines is the only host logic you write.
axpby = define_op(
    "axpby",
    builder=axpby_builder,
    ref=axpby_ref,
    derive_defines=lambda args, params: dict(
        n=args[0].size, bn=min(params["bn"], args[0].size),
        alpha=params["alpha"], beta=params["beta"]),
    defaults=dict(alpha=2.0, beta=-0.5, bn=4096),
    ref_params=("alpha", "beta"),
    sweep=dict(bn=[512, 2048, 4096, 16384]),
)


def main():
    # keep the demo's tune cache out of the user's real ~/.cache (CI runs
    # this script); export REPRO_CACHE_DIR yourself to see cross-process hits
    import os
    import tempfile
    os.environ.setdefault("REPRO_CACHE_DIR", tempfile.mkdtemp(prefix="repro-occa-"))

    rng = np.random.RandomState(0)
    x = rng.randn(1 << 16).astype(np.float32)
    y = rng.randn(1 << 16).astype(np.float32)
    want = axpby_ref(x, y)

    # 4. Same call site for every backend — the backend is a RUN-TIME knob
    #    ("auto" = pallas, interpret off-TPU). Kernel builds are cached.
    for backend in ("auto",) + BACKENDS:     # auto, jnp, loops, pallas
        got = np.asarray(axpby(x, y, backend=backend))
        np.testing.assert_allclose(got, want, rtol=1e-6)
        print(f"{backend:>7s}: OK  (max|err| = {np.abs(got - want).max():.2e})")

    # 5. The declaration registers the op: tooling can enumerate every op
    #    and its oracle (the registry-wide portability test does exactly this).
    import repro.kernels  # noqa: F401 — registers the library op families
    assert get_op("axpby") is axpby
    print("registry:", ", ".join(sorted(registered_ops())))

    # 6. Per-op autotuning: sweep the declared knobs on real args, validate
    #    every candidate against the oracle, persist the winner on disk
    #    (~/.cache/repro-occa) — a warm cache re-times NOTHING.
    best = axpby.tune((x, y), backend="jnp", repeats=1)
    print(f"tuned bn={best['bn']} "
          f"({'cache hit' if best.cached else f'{len(best.trials)} trials'}, "
          f"best {best.best_seconds * 1e6:.0f} us)")

    # 7. Custom-VJP ops: declare vjp=OpVJP(bwd=...) and the op becomes
    #    differentiable with the BACKWARD also built from unified-language
    #    kernels, run on the same backend as the forward. flash_attention is
    #    the full-size example: its bwd is ONE fused dq/dk/dv kernel whose
    #    outputs accumulate at different reduce granularities
    #    (Tile(reduce=...) — dq over k-blocks, dk/dv over q-blocks, one grid).
    import jax
    from repro.kernels.flash_attention import flash_attention

    q = rng.randn(1, 2, 64, 32).astype(np.float32)
    k = rng.randn(1, 2, 64, 32).astype(np.float32)
    v = rng.randn(1, 2, 64, 32).astype(np.float32)
    for backend in BACKENDS:
        dq = jax.grad(lambda q_: (flash_attention(
            q_, k, v, block_q=32, block_kv=32, backend=backend) ** 2).sum())(q)
        print(f"{backend:>7s}: flash_attention grad OK "
              f"(|dq| = {float(jnp.abs(dq).mean()):.3f})")

    # 8. DYNAMIC input tiles: run-time data the kernel reads WITHOUT
    #    recompiling — the decode-attention pattern. Two flavors:
    #      whole-array  (block=None) — visible to every grid cell; use for
    #                   scalars like flash_decode's (1,1) kv_len, which
    #                   drives a ctx.cell_when predicate so cache blocks past
    #                   the valid length are skipped at RUN time
    #      blocked      — streamed per grid cell like any data tile; use for
    #                   per-slot state like flash_decode's (1,S) slot_pos
    #                   map: a rolling-window cache stores ROTATED slots
    #                   (slot = pos % W), and the mask reads each slot's
    #                   absolute position instead of assuming order
    #    One compiled kernel then serves every step of a growing — even
    #    wrapping — cache. cell_when can still skip whole blocks whenever
    #    the predicate is computable from the dynamic scalars (here: while
    #    kv_len <= S the cache hasn't rotated, so past-the-query blocks
    #    never issue MXU work).
    from repro.kernels.flash_attention import decode_attention, decode_ref

    W = 16                                   # rolling cache of W slots
    t = 25                                   # decoded PAST the wrap (t > W)
    kc = rng.randn(1, 2, W, 32).astype(np.float32)
    vc = rng.randn(1, 2, W, 32).astype(np.float32)
    q1 = rng.randn(1, 2, 1, 32).astype(np.float32)
    slot_pos = np.full((W,), -1, np.int32)
    for p in range(t - W, t):
        slot_pos[p % W] = p                  # slot -> absolute position
    got = decode_attention(q1, kc, vc, window=W, kv_len=t, slot_pos=slot_pos,
                           backend="jnp")
    want = decode_ref(q1, kc, vc, window=W, kv_len=t, slot_pos=slot_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print(f"dynamic input tiles: rotated-cache decode OK "
          f"(wrap at {W}, step {t})")

    print("one declaration -> every backend, tuned, differentiable, "
          "identical results")


if __name__ == "__main__":
    main()
